"""Gateway admission layer, ModelRepo handles, partitioned block cache."""

import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import DeltaTensorStore
from repro.lake import InMemoryObjectStore, ReadExecutor
from repro.lake.io import BlockCache
from repro.serve import (Gateway, RetryAfter, TenantPolicy, jain_index,
                         load_weights, save_weights)


def _params(seed=0, leaves=3, shape=(16, 32)):
    rng = np.random.default_rng(seed)
    return {f"layer{i}": rng.standard_normal(shape).astype(np.float32)
            for i in range(leaves)}


def _store(**io_kw):
    return DeltaTensorStore(InMemoryObjectStore(), "weights",
                            io=ReadExecutor(max_workers=4, **io_kw))


class _GatedStore(InMemoryObjectStore):
    """Object store whose data-file gets can be held at a barrier.

    Log/commit reads pass through (catalog resolution and saves must not
    deadlock); only chunk-data gets block, so a test can freeze a weight
    load mid-flight, land a re-save, then release the load.
    """

    def __init__(self):
        super().__init__()
        self.release = threading.Event()
        self.release.set()
        self.entered = threading.Event()

    def get(self, key, *args, **kwargs):
        if "/part-" in key and not self.release.is_set():
            self.entered.set()
            assert self.release.wait(10), "gated store never released"
        return super().get(key, *args, **kwargs)


# -- ModelRepo handle API -----------------------------------------------------

def test_model_repo_roundtrip_and_pinning():
    store = _store()
    params = _params(1)
    with store.models("m") as repo:
        assert not repo.exists()
        repo.save(params)
        assert repo.exists()
        assert sorted(repo.leaf_ids()) == [f"m/layer{i}" for i in range(3)]
        v1 = repo.version
        loaded = repo.load(params)
        for k in params:
            np.testing.assert_array_equal(loaded[k], params[k])

        # a re-save through ANOTHER handle must not move this repo's pin
        bumped = {k: v + 1 for k, v in params.items()}
        with store.models("m") as w:
            w.save(bumped)
        assert repo.version == v1
        stale = repo.load(params)
        np.testing.assert_array_equal(stale["layer0"], params["layer0"])
        repo.refresh()
        assert repo.version != v1
        np.testing.assert_array_equal(repo.load(params)["layer0"],
                                      bumped["layer0"])
    assert repo.closed


def test_model_repo_variant_delta_roundtrip():
    store = _store()
    base = _params(2)
    with store.models("base") as repo:
        repo.save(base)
        ft = {k: v.copy() for k, v in base.items()}
        ft["layer1"] = ft["layer1"] * 2.0
        with repo.open_variant("ft") as var:
            assert var.prefix == "base~ft" and var.base is repo
            var.save(ft)
            got = var.load(base)
        for k in ft:
            np.testing.assert_array_equal(got[k], ft[k])
        # the variant reads back through a fresh handle too (no base repo)
        with store.models("base~ft") as again:
            np.testing.assert_array_equal(again.load(base)["layer1"],
                                          ft["layer1"])


def test_model_repo_empty_store_load_raises():
    store = _store()
    with store.models("nothing") as repo:
        with pytest.raises(KeyError):
            repo.load(_params())


def test_weight_shims_behavior_identical_and_deprecated():
    """save_weights/load_weights == ModelRepo.save/load, plus a warning."""
    params = _params(3)
    store_a, store_b = _store(), _store()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        shim_tids = save_weights(store_a, params, prefix="w")
        shim_loaded = load_weights(store_a, params, prefix="w")
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)

    with store_b.models("w") as repo:
        repo_tids = repo.save(params)
        repo_loaded = repo.load(params)
    assert sorted(shim_tids) == sorted(repo_tids)
    for k in params:
        np.testing.assert_array_equal(shim_loaded[k], repo_loaded[k])


def test_load_weights_threads_io_through():
    """The io= override must be the executor that does the fetching."""
    store = _store()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        save_weights(store, _params(4), prefix="w")
        other = ReadExecutor(max_workers=2)
        before = other.stats.gets
        load_weights(store, _params(4), prefix="w", io=other)
        assert other.stats.gets > before  # historically silently ignored


# -- partitioned block cache --------------------------------------------------

def test_cache_partition_budgets_under_concurrent_eviction():
    cache = BlockCache(capacity_bytes=4096)
    cache.add_partition("hot", 2048, pinned=True)
    hot_keys = [(1, f"hot{i}") for i in range(4)]
    for k in hot_keys:
        cache.put(k, b"h" * 512, partition="hot")

    stop = threading.Event()

    def churn(tid):
        i = 0
        while not stop.is_set():
            cache.put((tid, f"blk{i % 64}"), b"d" * 256)
            cache.get((tid, f"blk{(i * 7) % 64}"))
            i += 1

    threads = [threading.Thread(target=churn, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    stop.set()
    for t in threads:
        t.join()

    parts = cache.partitions()
    assert parts["default"]["nbytes"] <= 4096
    assert parts["default"]["evictions"] > 0
    # the pinned class never lost a resident to the churn next door
    assert parts["hot"]["evictions"] == 0
    for k in hot_keys:
        assert cache.get(k) == b"h" * 512


def test_cache_pinned_partition_rejects_overflow_and_never_demotes():
    cache = BlockCache(capacity_bytes=4096)
    cache.add_partition("hot", 1024, pinned=True)
    cache.put((1, "a"), b"x" * 600, partition="hot")
    cache.put((1, "b"), b"y" * 600, partition="hot")   # over budget: rejected
    parts = cache.partitions()
    assert parts["hot"]["blocks"] == 1 and parts["hot"]["evictions"] == 0
    assert cache.get((1, "b")) is None

    # a default-class reader is served in place; the block stays pinned
    assert cache.get((1, "a"), partition="default") == b"x" * 600
    assert cache.partitions()["hot"]["blocks"] == 1
    # but an unpinned block IS promoted into the class a reader names
    cache.put((1, "c"), b"z" * 100)
    assert cache.get((1, "c"), partition="hot") == b"z" * 100
    assert cache.partitions()["hot"]["blocks"] == 2


def test_read_many_routes_blocks_into_named_partition():
    store = _store(cache_bytes=1 << 20)
    store.io.cache.add_partition("hot", 1 << 20)
    with store.models("m") as repo:
        repo.save(_params(5))
        repo.load(_params(5), cache_partition="hot")
    parts = store.io.cache.partitions()
    assert parts["hot"]["blocks"] > 0
    assert parts["hot"]["nbytes"] > 0


# -- gateway: coalescing ------------------------------------------------------

def test_coalesced_coldstart_byte_identical_across_mid_load_resave():
    obj = _GatedStore()
    store = DeltaTensorStore(obj, "weights", io=ReadExecutor(max_workers=4))
    params = _params(6)
    with store.models("m") as repo:
        repo.save(params)

    with Gateway(store, max_inflight=4) as gw:
        obj.release.clear()
        f1 = gw.load_model("a", "m", params)
        assert obj.entered.wait(10)       # flight is mid-load, frozen
        f2 = gw.load_model("b", "m", params)   # joins the same flight
        assert f2 is f1

        # re-save lands while the flight is frozen mid-load
        bumped = {k: v + 1 for k, v in params.items()}
        with store.models("m") as w:
            w.save(bumped)

        obj.release.set()
        t1, t2 = f1.result(30), f2.result(30)
        stats = gw.stats()
        # both waiters: byte-identical trees of the ORIGINAL generation
        for k in params:
            np.testing.assert_array_equal(t1[k], t2[k])
            np.testing.assert_array_equal(t1[k], params[k])
        assert stats["flights_created"] == 1
        assert stats["coalesced_hits"] == 1

        # a requester arriving after the re-save keys a fresh flight
        t3 = gw.load_model("c", "m", params).result(30)
        np.testing.assert_array_equal(t3["layer0"], bumped["layer0"])
        assert gw.stats()["flights_created"] == 2


def test_coalescing_fetches_once_for_n_tenants():
    store = _store()
    params = _params(7)
    with store.models("m") as repo:
        repo.save(params)
    solo = store.io.stats.gets

    with Gateway(store, max_inflight=8) as gw:
        before = store.io.stats.gets
        futs = [gw.load_model(f"t{i}", "m", params) for i in range(6)]
        trees = [f.result(30) for f in futs]
        gets = store.io.stats.gets - before
        assert gw.stats()["coalesced_hits"] == 5
    assert gets <= solo + len(params)  # ~one load's worth, not six
    for t in trees:
        np.testing.assert_array_equal(t["layer0"], params["layer0"])


# -- gateway: quotas, fairness, shedding, lifecycle ---------------------------

def test_quota_exhaustion_rejects_instead_of_deadlocking():
    store = _store()
    with store.models("m") as repo:
        repo.save(_params(8))
    release = threading.Event()
    with Gateway(store, max_inflight=2) as gw:
        gw.register("flood", TenantPolicy(max_inflight=1, queue_limit=3))
        accepted = [gw.submit("flood", lambda: release.wait(10))
                    for _ in range(4)]  # 1 inflight + 3 queued
        rejections = []
        for _ in range(5):
            with pytest.raises(RetryAfter) as exc:
                gw.submit("flood", lambda: None)
            rejections.append(exc.value)
        assert all(r.retry_after_s > 0 for r in rejections)
        release.set()
        for f in accepted:                     # nothing deadlocks
            assert f.result(10) is True
        stats = gw.tenant_stats()["flood"]
        assert stats["rejected"] == 5 and stats["completed"] == 4


def test_weighted_fair_queueing_dispatch_shares():
    """With one slot, a weight-3 tenant drains ~3x faster than weight-1."""
    store = _store()
    order = []
    hold = threading.Event()
    with Gateway(store, max_inflight=1) as gw:
        gw.register("light", TenantPolicy(weight=1.0, max_inflight=1))
        gw.register("heavy", TenantPolicy(weight=3.0, max_inflight=1))
        blocker = gw.submit("light", lambda: hold.wait(10))
        futs = [gw.submit("light", lambda i=i: order.append(("light", i)))
                for i in range(4)]
        futs += [gw.submit("heavy", lambda i=i: order.append(("heavy", i)))
                 for i in range(4)]
        hold.set()
        for f in futs:
            f.result(10)
    # among the first four dispatched after the blocker, the weight-3
    # tenant got at least three slots (FIFO would give it at most zero)
    first4 = [t for t, _ in order[:4]]
    assert first4.count("heavy") >= 3
    # per-tenant order stayed FIFO
    assert [i for t, i in order if t == "heavy"] == [0, 1, 2, 3]
    assert [i for t, i in order if t == "light"] == [0, 1, 2, 3]


def test_jain_index():
    assert jain_index([1, 1, 1, 1]) == pytest.approx(1.0)
    assert jain_index([1, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_index([]) is None
    assert jain_index([0, 0]) == pytest.approx(1.0)


def test_slo_report_and_latency_histograms():
    store = _store()
    with store.models("m") as repo:
        repo.save(_params(9))
    with Gateway(store, max_inflight=2) as gw:
        gw.register("t", TenantPolicy(p99_target_s=60.0))
        for _ in range(5):
            gw.read("t", "m/layer0").result(10)
        slo = gw.slo_report()["t"]
        assert slo["p99_s"] is not None and slo["target_s"] == 60.0
        assert slo["met"] is True
        assert slo["hedge_s"] == pytest.approx(30.0)  # derived: target / 2
        assert gw.tenant_stats()["t"]["latency"]["count"] == 5


def test_gateway_lifecycle_close_cancels_queued():
    store = _store()
    hold = threading.Event()
    gw = Gateway(store, max_inflight=1)
    running = gw.submit("t", lambda: hold.wait(10))
    queued = gw.submit("t", lambda: "never")
    gw.close()
    assert gw.closed
    with pytest.raises(RetryAfter):
        queued.result(10)
    with pytest.raises(RuntimeError):
        gw.submit("t", lambda: None)
    hold.set()
    assert running.result(10) is True   # in-flight work still completes
    gw.close()                          # idempotent


def test_serve_engine_lifecycle_owns_repo():
    store = _store()
    params = _params(10)
    with store.models("m") as writer:
        writer.save(params)
    repo = store.models("m")

    from repro.models import get_arch
    from repro.serve import Request, ServeEngine
    cfg = get_arch("granite-3-8b").reduced()
    with ServeEngine(params, cfg, n_slots=1, max_len=16, repo=repo) as eng:
        assert not eng.closed and not repo.closed
    assert eng.closed and repo.closed
    with pytest.raises(RuntimeError):
        eng.submit(Request(rid=0, prompt=np.zeros(2, np.int32)))
