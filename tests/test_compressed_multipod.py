"""Cross-pod gradient compression on the multi-pod mesh: HLO evidence.

Lowers the compressed train step on a (2, data, model) mesh in a subprocess
(needs >1 host devices) and checks that the cross-pod exchange happens on
the compressed (ids, blocks) payload — i.e. total all-gather bytes are a
small fraction of the dense gradient size.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import get_arch
from repro.train import optimizer as opt, trainer
from repro.analysis import hlo_cost

import dataclasses
cfg = dataclasses.replace(get_arch("granite-3-8b").reduced(),
                          d_model=256, d_ff=512, vocab_size=4096,
                          n_layers=2, head_dim=64)
ocfg = opt.OptConfig()
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
n_pods = 2

state = jax.eval_shape(lambda: trainer.init_compressed_state(
    cfg, jax.random.key(0), n_pods))
batch = {
    "tokens": jax.ShapeDtypeStruct((n_pods, 4, 32), jnp.int32),
    "labels": jax.ShapeDtypeStruct((n_pods, 4, 32), jnp.int32),
}
pod_first = jax.tree.map(
    lambda x: NamedSharding(
        mesh, P("pod", *([None] * (len(x.shape) - 1))) if len(x.shape) else P()),
    state)
b_sh = {k: NamedSharding(mesh, P("pod", "data", None)) for k in batch}

ratio = 0.05
step = trainer.make_compressed_train_step(cfg, ocfg, ratio=ratio, mesh=mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=(pod_first, b_sh)).lower(
        state, batch).compile()
    cost = hlo_cost.analyze(compiled.as_text())

n_params = sum(x.size for x in jax.tree.leaves(state.params)) // n_pods
dense_bytes = n_params * 4
print(json.dumps({
    "dense_grad_bytes": dense_bytes,
    "all_gather_bytes": cost.coll_bytes.get("all-gather", 0.0),
    "total_coll_bytes": cost.total_coll_bytes,
}))
"""


@pytest.mark.slow
def test_compressed_step_exchanges_small_payload():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # the pod-crossing all-gather moves (far) less than a dense f32 gradient
    assert rec["all_gather_bytes"] < 0.6 * rec["dense_grad_bytes"], rec
    assert rec["all_gather_bytes"] > 0, rec
