"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt). When it
is absent, importing through this module keeps the test modules collectable:
property tests decorated with ``@given`` turn into individually-skipped
tests instead of failing the whole module at import time, and every
non-property test in the module still runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any ``st.*`` strategy construction at decoration time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
