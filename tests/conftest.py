"""Test bootstrap: make ``repro`` importable without an install step.

The tier-1 command sets ``PYTHONPATH=src``; this keeps bare ``pytest`` (IDE
runs, CI matrices) working too. ``tests/__init__.py`` makes the directory a
package so cross-module helpers import relatively
(``from .test_encodings import sparse_tensor``).
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
