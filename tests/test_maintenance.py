"""Lifecycle subsystem: leases, catalog-aware compact/vacuum, spilled index.

The contract under test: maintenance may reclaim space aggressively, but a
snapshot pinned by any live lease (every open TensorRef, every checkpoint
retained by the checkpointer) keeps reading identical bytes — concurrently,
sharded or not — and a spilled catalog index is indistinguishable from a
walked snapshot except for the snapshot walks it skips.
"""

import threading

import numpy as np
import pytest

from repro.core import DeltaTensorStore, RetentionPolicy
from repro.lake import InMemoryObjectStore, LocalFSObjectStore, ReadExecutor
from repro.lake.io import _store_token
from repro.lake.table import DeltaTable


def _store(obj=None, cache_bytes=0, **kwargs):
    obj = obj or InMemoryObjectStore()
    return obj, DeltaTensorStore(
        obj, "t", io=ReadExecutor(max_workers=4, cache_bytes=cache_bytes),
        **kwargs)


def _data_keys(obj, root="t"):
    return [k for k in obj.list(f"{root}/")
            if "_delta_log" not in k and "/_catalog/" not in k]


# ---------------------------------------------------------------------------
# compact: commit-free no-op, fenced commit
# ---------------------------------------------------------------------------

def test_compact_noop_is_commit_free_and_falsy():
    obj = InMemoryObjectStore()
    t = DeltaTable.create(obj, "tbl", io=ReadExecutor(cache_bytes=0))
    for i in range(3):  # one file per partition group: nothing to merge
        t.append({"v": np.arange(4)}, partition_values={"tensor": f"t{i}"})
    v = t.version()
    res = t.compact()
    assert not res and res.files_compacted == 0 and res.version is None
    assert t.version() == v          # no OPTIMIZE commit was written
    # and a real compaction still reports what it did
    t.append({"v": np.arange(4)}, partition_values={"tensor": "t0"})
    res = t.compact()
    assert res and res.files_compacted == 2 and res.files_written == 1
    assert res.version == t.version()


# ---------------------------------------------------------------------------
# vacuum: retention horizon, leases, time travel
# ---------------------------------------------------------------------------

def test_vacuum_horizon_keeps_time_travel_inside_retention():
    obj, store = _store()
    x1 = np.arange(64, dtype=np.float32).reshape(8, 8)
    x2, x3 = x1 + 1, x1 + 2
    store.put(x1, layout="ftsf", tensor_id="a")
    v1 = store.version()
    store.put(x2, layout="ftsf", tensor_id="a", overwrite=True)
    v2 = store.version()
    store.put(x3, layout="ftsf", tensor_id="a", overwrite=True)

    # keep the last two versions: v2 must stay readable, v1 must not
    results = store.vacuum(keep_versions=2)
    assert sum(r.files_deleted for r in results) > 0
    np.testing.assert_array_equal(store.get("a", version=v2), x2)
    np.testing.assert_array_equal(store.get("a"), x3)
    with pytest.raises(Exception):
        store.get("a", version=v1)   # outside the horizon: bytes are gone


def test_leased_snapshot_survives_vacuum_then_release_frees_bytes():
    obj, store = _store()
    x1 = np.arange(256, dtype=np.float32).reshape(16, 16)
    x2 = x1 * -1.0
    store.put(x1, layout="ftsf", tensor_id="a")
    ref = store.open("a")                      # lease on v1
    store.put(x2, layout="ftsf", tensor_id="a", overwrite=True)

    res = store.vacuum()                       # default keep_versions=1
    assert sum(r.files_deleted for r in res) == 0   # leased: nothing freed
    np.testing.assert_array_equal(ref.read(), x1)

    ref.close()
    assert ref.closed and store.leases.active == 0
    res = store.vacuum()
    assert sum(r.files_deleted for r in res) > 0
    assert sum(r.bytes_reclaimed for r in res) > 0
    np.testing.assert_array_equal(store.get("a"), x2)


def test_ref_context_manager_and_gc_release_leases():
    _, store = _store()
    store.put(np.arange(8.0), layout="ftsf", tensor_id="a")
    with store.open("a") as ref:
        assert store.leases.active == 1
        ref.read()
    assert store.leases.active == 0
    ref2 = store.open("a")
    assert store.leases.active == 1
    del ref2                                   # finalizer backstop fires
    assert store.leases.active == 0


@pytest.mark.parametrize("shards", [1, 3])
def test_pinned_read_identical_under_concurrent_compact_vacuum(shards):
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t", shards=shards,
                             io=ReadExecutor(max_workers=4, cache_bytes=0))
    rng = np.random.default_rng(0)
    originals = {}
    for i in range(4):
        originals[f"t{i}"] = rng.standard_normal((16, 16)).astype(np.float32)
        store.put(originals[f"t{i}"], layout="ftsf", tensor_id=f"t{i}")
    refs = {tid: store.open(tid) for tid in originals}

    stop = threading.Event()
    errors = []

    def churn():
        try:
            for k in range(6):
                for i in range(4):
                    store.put(rng.standard_normal((16, 16)).astype(np.float32),
                              layout="ftsf", tensor_id=f"t{i}", overwrite=True)
                store.compact()
                store.vacuum(keep_versions=1)
        except BaseException as e:
            errors.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=churn)
    t.start()
    while not stop.is_set():
        for tid, x in originals.items():
            np.testing.assert_array_equal(refs[tid].read(), x)
    t.join(timeout=120)
    assert not errors
    # pinned reads still byte-identical after all maintenance completed
    for tid, x in originals.items():
        np.testing.assert_array_equal(refs[tid].read(), x)
    # releasing the pins lets the next vacuum actually reclaim the churn
    before = len(_data_keys(obj))
    for ref in refs.values():
        ref.close()
    store.vacuum(keep_versions=1)
    assert len(_data_keys(obj)) < before


def test_vacuum_dry_run_deletes_nothing_and_reports():
    obj, store = _store()
    store.put(np.arange(64.0), layout="ftsf", tensor_id="a")
    store.put(np.arange(64.0) + 1, layout="ftsf", tensor_id="a", overwrite=True)
    keys_before = set(obj.list("t/"))
    res = store.vacuum(dry_run=True)
    assert sum(r.files_deleted for r in res) > 0
    assert sum(r.bytes_reclaimed for r in res) > 0
    assert set(obj.list("t/")) == keys_before      # nothing actually deleted
    real = store.vacuum()
    assert [r.deleted_paths for r in real] == [r.deleted_paths for r in res]


def test_vacuum_ttl_retains_young_versions():
    obj, store = _store()
    store.put(np.arange(16.0), layout="ftsf", tensor_id="a")
    store.put(np.arange(16.0) + 1, layout="ftsf", tensor_id="a", overwrite=True)
    # everything committed milliseconds ago: a generous TTL retains it all
    res = store.vacuum(keep_versions=1, ttl_s=1e6, dry_run=True)
    assert sum(r.files_deleted for r in res) == 0
    # without the TTL the same policy would reclaim the overwritten files
    res = store.vacuum(keep_versions=1, dry_run=True)
    assert sum(r.files_deleted for r in res) > 0


def test_store_retention_policy_default_applies():
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t",
                             io=ReadExecutor(max_workers=2, cache_bytes=0),
                             retention=RetentionPolicy(keep_versions=10))
    store.put(np.arange(16.0), layout="ftsf", tensor_id="a")
    store.put(np.arange(16.0) + 1, layout="ftsf", tensor_id="a", overwrite=True)
    assert sum(r.files_deleted for r in store.vacuum(dry_run=True)) == 0
    assert sum(r.files_deleted
               for r in store.vacuum(keep_versions=1, dry_run=True)) > 0


def test_vacuum_spares_inflight_two_phase_uploads():
    obj = InMemoryObjectStore()
    t = DeltaTable.create(obj, "tbl", io=ReadExecutor(cache_bytes=0))
    t.append({"a": np.arange(3)})
    with t.guard_uploads() as g:
        add = t.append({"a": np.arange(7)}, commit=False, guard=g)
        # a concurrent vacuum (even from another client of the same store)
        # must not reclassify the in-flight upload as an orphan
        other = DeltaTable(obj, "tbl", io=ReadExecutor(cache_bytes=0))
        assert other.vacuum().files_deleted == 0
        t.commit_adds([add])
    assert sorted(t.read_all()["a"]) == sorted(list(range(3)) + list(range(7)))
    # guard closed: a genuinely orphaned upload is still vacuumable
    t.append({"a": np.arange(2)}, commit=False)
    assert t.vacuum().files_deleted == 1


def test_vacuum_during_open_write_batch_does_not_corrupt_commit():
    obj, store = _store()
    x = np.arange(256, dtype=np.float32)
    store.put(np.zeros(4, np.float32), layout="ftsf", tensor_id="seed")
    b = store.batch()
    b.put(x, layout="ftsf", tensor_id="a")     # uploaded, not yet committed
    assert sum(r.files_deleted for r in store.vacuum()) == 0
    b.commit()
    np.testing.assert_array_equal(store.get("a"), x)
    # an abandoned batch's uploads become orphans once its guards close
    b2 = store.batch()
    b2.put(x * 2, layout="ftsf", tensor_id="dead")
    b2.abandon()
    assert sum(r.files_deleted for r in store.vacuum()) > 0


# ---------------------------------------------------------------------------
# satellite: stale cache entries are evicted by maintenance
# ---------------------------------------------------------------------------

def test_vacuum_evicts_block_and_header_caches():
    obj = InMemoryObjectStore()
    io = ReadExecutor(max_workers=2, cache_bytes=8 << 20)
    store = DeltaTensorStore(obj, "t", io=io)
    x1 = np.arange(256, dtype=np.float32)
    store.put(x1, layout="ftsf", tensor_id="a")
    store._headers_by_path.clear()
    np.testing.assert_array_equal(store.get("a"), x1)  # warms both caches
    old_paths = [a["path"]
                 for a in store.catalog().entry("a").header_adds
                 + store.catalog().entry("a").chunk_adds]
    tok = _store_token(obj)
    assert any(io.cache.get((tok, f"t/{p}")) is not None for p in old_paths)
    assert any(p in store._headers_by_path for p in old_paths)

    store.put(x1 * 2, layout="ftsf", tensor_id="a", overwrite=True)
    res = store.vacuum()
    assert sorted(p for r in res for p in r.deleted_paths) == sorted(old_paths)
    for p in old_paths:
        assert io.cache.get((tok, f"t/{p}")) is None       # block cache clean
        assert p not in store._headers_by_path             # header cache clean
    np.testing.assert_array_equal(store.get("a"), x1 * 2)


def test_compact_evicts_rewritten_paths_from_caches():
    obj = InMemoryObjectStore()
    io = ReadExecutor(max_workers=2, cache_bytes=8 << 20)
    store = DeltaTensorStore(obj, "t", io=io)
    x = np.arange(1024, dtype=np.float32)
    store.put(x, layout="ftsf", tensor_id="a", target_file_bytes=1 << 9)
    np.testing.assert_array_equal(store.get("a"), x)
    results = store.compact()
    assert any(results)
    tok = _store_token(obj)
    for res in results:
        for p in res.removed_paths:
            assert io.cache.get((tok, f"t/{p}")) is None
            assert p not in store._headers_by_path
    np.testing.assert_array_equal(store.get("a"), x)


# ---------------------------------------------------------------------------
# spilled catalog index
# ---------------------------------------------------------------------------

def _fill(store, n=6):
    rng = np.random.default_rng(7)
    tensors = {}
    with store.batch() as b:
        for i in range(n):
            tensors[f"s{i}"] = rng.standard_normal((12, 12)).astype(np.float32)
            b.put(tensors[f"s{i}"], layout="ftsf", tensor_id=f"s{i}")
    return tensors


def test_spilled_index_catalog_equals_walked_bit_for_bit():
    obj = InMemoryObjectStore()
    writer = DeltaTensorStore(obj, "t", spill_threshold=4,
                              io=ReadExecutor(max_workers=2, cache_bytes=0))
    tensors = _fill(writer)
    assert list(obj.list("t/_catalog/"))       # the commit spilled an index

    walked_client = DeltaTensorStore(
        obj, "t", spill_threshold=None,        # disables index consultation
        io=ReadExecutor(max_workers=2, cache_bytes=0))
    spilled_client = DeltaTensorStore(
        obj, "t", spill_threshold=4,
        io=ReadExecutor(max_workers=2, cache_bytes=0))
    walked, spilled = walked_client.catalog(), spilled_client.catalog()

    assert walked_client.catalog_stats["snapshot_walks"] == 1
    assert walked_client.catalog_stats["index_loads"] == 0
    assert spilled_client.catalog_stats["snapshot_walks"] == 0
    assert spilled_client.catalog_stats["index_loads"] == 1

    assert spilled.version == walked.version
    assert spilled.tensors() == walked.tensors()
    for tid in walked:
        assert spilled.entry(tid) == walked.entry(tid)   # bit-for-bit adds
    for tid, x in tensors.items():
        np.testing.assert_array_equal(spilled_client.get(tid), x)


def test_spilled_index_transparent_fallback_when_absent():
    obj = InMemoryObjectStore()
    writer = DeltaTensorStore(obj, "t", spill_threshold=4,
                              io=ReadExecutor(max_workers=2, cache_bytes=0))
    tensors = _fill(writer)
    for key in list(obj.list("t/_catalog/")):
        obj.delete(key)                        # index lost/never written

    reader = DeltaTensorStore(obj, "t", spill_threshold=4,
                              io=ReadExecutor(max_workers=2, cache_bytes=0))
    cat = reader.catalog()
    assert reader.catalog_stats["snapshot_walks"] == 1   # fell back to walk
    assert reader.catalog_stats["index_loads"] == 0
    assert len(cat) == len(tensors)
    for tid, x in tensors.items():
        np.testing.assert_array_equal(reader.get(tid), x)


def test_spill_catalog_backfill_and_vacuum_prunes_old_indexes():
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t", spill_threshold=None,
                             io=ReadExecutor(max_workers=2, cache_bytes=0))
    _fill(store, n=3)
    assert not list(obj.list("t/_catalog/"))
    store.spill_catalog()                      # operator backfill
    assert len(list(obj.list("t/_catalog/"))) == 1
    store.put(np.arange(8.0), layout="ftsf", tensor_id="extra")
    store.spill_catalog()
    assert len(list(obj.list("t/_catalog/"))) == 2
    res = store.vacuum(keep_versions=1)        # old version out of retention
    assert sum(r.index_files_deleted for r in res) == 1
    assert len(list(obj.list("t/_catalog/"))) == 1


@pytest.mark.parametrize("shards", [3])
def test_spilled_index_sharded_store(shards):
    obj = InMemoryObjectStore()
    writer = DeltaTensorStore(obj, "t", shards=shards, spill_threshold=2,
                              io=ReadExecutor(max_workers=4, cache_bytes=0))
    rng = np.random.default_rng(7)
    tensors = {}
    with writer.batch() as b:
        # ids chosen so the blake2b router lands files on every shard
        for tid in ("sh1", "sh5", "sh2", "sh3", "sh0", "sh4"):
            assert writer.shard_of(tid) in range(shards)
            tensors[tid] = rng.standard_normal((12, 12)).astype(np.float32)
            b.put(tensors[tid], layout="ftsf", tensor_id=tid)
    assert {writer.shard_of(t) for t in tensors} == set(range(shards))
    reader = DeltaTensorStore(obj, "t", spill_threshold=2,
                              io=ReadExecutor(max_workers=4, cache_bytes=0))
    cat = reader.catalog()
    assert reader.catalog_stats["snapshot_walks"] == 0
    assert reader.catalog_stats["index_loads"] == shards
    assert cat.version_vector == writer.catalog().version_vector
    for tid, x in tensors.items():
        np.testing.assert_array_equal(reader.get(tid), x)


# ---------------------------------------------------------------------------
# checkpoint retention via leases
# ---------------------------------------------------------------------------

def _ckpt_state(step):
    return {"hot": np.full((24, 24), float(step), np.float32),
            "frozen": np.arange(64, dtype=np.float32)}


def test_checkpointer_keeps_last_k_and_gc_reclaims():
    from repro.train.checkpoint import DeltaCheckpointer

    obj = InMemoryObjectStore()
    ckpt = DeltaCheckpointer(obj, "ck", keep_checkpoints=2)
    for step in (1, 2, 3, 4):
        ckpt.save(step, _ckpt_state(step))
    # sliding lease window: only the newest two versions stay pinned
    assert ckpt.store.leases.active == 2

    bytes_before = sum(obj.head(k) for k in _data_keys(obj, "ck"))
    res = ckpt.gc()
    assert res["pruned_steps"] == [1, 2]
    assert res["bytes_reclaimed"] > 0
    assert ckpt.steps() == [3, 4]
    bytes_after = sum(obj.head(k) for k in _data_keys(obj, "ck"))
    assert bytes_after < bytes_before

    # kept checkpoints restore bit-for-bit, incl. the incrementally-reused
    # frozen leaf whose chunks were written at step 1 (referenced -> kept)
    step, state = ckpt.restore(_ckpt_state(0))
    assert step == 4
    np.testing.assert_array_equal(state["hot"], _ckpt_state(4)["hot"])
    np.testing.assert_array_equal(state["frozen"], _ckpt_state(0)["frozen"])
    with pytest.raises(KeyError):
        ckpt.restore(_ckpt_state(0), step=1)


def test_checkpointer_lease_blocks_external_prune_and_vacuum():
    from repro.train.checkpoint import DeltaCheckpointer

    obj = InMemoryObjectStore()
    ckpt = DeltaCheckpointer(obj, "ck", keep_checkpoints=2)
    for step in (1, 2, 3):
        ckpt.save(step, _ckpt_state(step))
    # another maintenance actor prunes+vacuums the shared store far more
    # aggressively than our retention window; our leases (visible through
    # the shared per-store registry) must keep steps 2 and 3 restorable
    other = DeltaCheckpointer(obj, "ck")
    assert other.prune(keep=1) == [1, 2]
    other.store.vacuum(keep_versions=1)

    step, state = ckpt.restore(_ckpt_state(0), step=2)  # pinned restore
    assert step == 2
    np.testing.assert_array_equal(state["hot"], _ckpt_state(2)["hot"])
    step, state = ckpt.restore(_ckpt_state(0))
    assert step == 3


def test_gc_dry_run_commits_and_deletes_nothing():
    from repro.train.checkpoint import DeltaCheckpointer

    obj = InMemoryObjectStore()
    ckpt = DeltaCheckpointer(obj, "ck", keep_checkpoints=1)
    for step in (1, 2, 3):
        ckpt.save(step, _ckpt_state(step))
    keys = set(obj.list("ck/"))
    version = ckpt.store.version()
    res = ckpt.gc(dry_run=True)
    assert res["pruned_steps"] == [] and res["files_compacted"] == 0
    assert set(obj.list("ck/")) == keys
    assert ckpt.store.version() == version


# ---------------------------------------------------------------------------
# gc CLI
# ---------------------------------------------------------------------------

def test_gc_cli_compact_vacuum_roundtrip(tmp_path):
    from repro.launch import gc as gc_mod

    obj = LocalFSObjectStore(str(tmp_path))
    store = DeltaTensorStore(obj, "tensors",
                             io=ReadExecutor(max_workers=2, cache_bytes=0))
    x = np.arange(512, dtype=np.float32)
    store.put(x, layout="ftsf", tensor_id="a", target_file_bytes=1 << 9)
    store.put(x * 3, layout="ftsf", tensor_id="a", overwrite=True,
              target_file_bytes=1 << 9)

    rc = gc_mod.main(["--dir", str(tmp_path), "--root", "tensors",
                      "--vacuum", "--dry-run"])
    assert rc == 0
    rc = gc_mod.main(["--dir", str(tmp_path), "--root", "tensors",
                      "--compact", "--vacuum", "--keep-versions", "1",
                      "--spill-index"])
    assert rc == 0
    fresh = DeltaTensorStore(obj, "tensors",
                             io=ReadExecutor(max_workers=2, cache_bytes=0))
    np.testing.assert_array_equal(fresh.get("a"), x * 3)
