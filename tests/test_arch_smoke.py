"""Per-architecture smoke tests on REDUCED configs (same family/topology,
tiny sizes): one forward/train step + one prefill/decode step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_arch, transformer
from repro.models.config import list_archs

ARCHS = list(list_archs())
SEQ = 32
BATCH = 2


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["encoder_frames"] = jnp.asarray(
            rng.standard_normal((BATCH, SEQ // cfg.encoder_seq_divisor,
                                 cfg.d_model)), jnp.float32)
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, rng)

    logits, _, aux = transformer.forward(
        params, cfg, batch["tokens"],
        image_embeds=batch.get("image_embeds"),
        encoder_frames=batch.get("encoder_frames"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one SGD step through value_and_grad (the real train_step path)
    def loss(p):
        return transformer.loss_fn(p, cfg, batch)[0]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    gnorm = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                              params, grads)
    val2 = float(loss(new_params))
    assert np.isfinite(val2)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_arch(arch).reduced()
    if not cfg.supports_decode:
        pytest.skip("encoder-only arch: no decode step")
    rng = np.random.default_rng(1)
    params = transformer.init_params(cfg, jax.random.key(1))
    batch = make_batch(cfg, rng)
    max_len = SEQ + 8
    caches = transformer.init_caches(cfg, BATCH, max_len)

    logits, caches, _ = transformer.prefill(
        params, cfg, batch["tokens"], caches,
        image_embeds=batch.get("image_embeds"),
        encoder_frames=batch.get("encoder_frames"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert int(caches["index"][0]) == SEQ

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for step in range(3):
        logits1, caches, _ = transformer.decode_step(
            params, cfg, tok, caches,
            image_embeds=batch.get("image_embeds"))
        assert logits1.shape == (BATCH, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits1, np.float32)).all()
        tok = jnp.argmax(logits1, axis=-1).astype(jnp.int32)
    assert int(caches["index"][0]) == SEQ + 3


@pytest.mark.parametrize("arch", ["glm4-9b", "zamba2-2.7b", "xlstm-1.3b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the parallel forward logits."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(2)
    params = transformer.init_params(cfg, jax.random.key(2))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)

    full_logits, _, _ = transformer.forward(params, cfg, tokens)

    caches = transformer.init_caches(cfg, 1, 16)
    _, caches, _ = transformer.prefill(params, cfg, tokens[:, :4], caches)
    outs = []
    for i in range(4, 8):
        lg, caches, _ = transformer.decode_step(params, cfg, tokens[:, i:i+1],
                                                caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits[:, 4:8], np.float32),
                               rtol=2e-2, atol=2e-2)
