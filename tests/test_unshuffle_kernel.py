"""Pallas byte-unshuffle kernel (interpret mode) vs the numpy plane transpose.

* kernel/oracle parity across every fixed-width dtype's itemsize;
* ragged widths exercise the wrapper's pad-and-crop path;
* `install_unshuffle_kernel(force=True)` routes `byte_unshuffle` through the
  kernel and must stay byte-identical to the pure-numpy fallback — including
  under the PR-5 shuffle∘unshuffle identity property.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from ._hypothesis_compat import given, settings, st  # skips property tests if hypothesis is missing

from repro.kernels import install_unshuffle_kernel, ops, ref, unshuffle_host
from repro.lake import byte_shuffle, byte_unshuffle, set_unshuffle_kernel

RNG = np.random.default_rng(11)

FIXED_WIDTH_DTYPES = ["int8", "uint8", "int16", "uint16", "int32", "uint32",
                      "int64", "uint64", "float16", "float32", "float64",
                      "complex64", "complex128", "bool"]


@pytest.fixture
def kernel_installed():
    """byte_unshuffle routed through the Pallas kernel for one test."""
    assert install_unshuffle_kernel(force=True)
    yield
    set_unshuffle_kernel(None)


def _planes(itemsize, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, (itemsize, n), dtype=np.uint8)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", FIXED_WIDTH_DTYPES)
def test_unshuffle_matches_numpy_every_fixed_width_dtype(dtype):
    it = np.dtype(dtype).itemsize
    planes = _planes(it, 1024, seed=it)
    got = ops.unshuffle(jnp.asarray(planes), use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), planes.T)


@pytest.mark.parametrize("n", [1, 3, 511, 512, 513, 1300])
def test_unshuffle_ragged_widths_pad_and_crop(n):
    # n not a multiple of the 512 tile: the ops wrapper pads and crops
    planes = _planes(4, n, seed=n)
    got = ops.unshuffle(jnp.asarray(planes), use_pallas=True)
    want = ref.unshuffle(jnp.asarray(planes))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), planes.T)


def test_unshuffle_host_returns_numpy():
    planes = _planes(8, 640, seed=2)
    out = unshuffle_host(planes, use_pallas=True)
    assert isinstance(out, np.ndarray)
    np.testing.assert_array_equal(out, planes.T)


# ---------------------------------------------------------------------------
# byte_unshuffle kernel hook
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", FIXED_WIDTH_DTYPES)
def test_byte_unshuffle_kernel_hook_byte_identical(dtype, kernel_installed):
    it = np.dtype(dtype).itemsize
    for n in (0, 1, it, 7 * it + 3, 4096):
        raw = RNG.integers(0, 256, n, dtype=np.uint8).tobytes()
        shuf = bytes(byte_shuffle(raw, it))
        got = byte_unshuffle(shuf, it)
        set_unshuffle_kernel(None)
        want = byte_unshuffle(shuf, it)
        install_unshuffle_kernel(force=True)
        assert bytes(got) == bytes(want) == raw


@settings(max_examples=50, deadline=None)
@given(st.binary(min_size=0, max_size=2048),
       st.integers(min_value=1, max_value=16))
def test_shuffle_unshuffle_identity_property_with_kernel(raw, itemsize):
    """PR-5 identity property holds with the Pallas kernel installed."""
    install_unshuffle_kernel(force=True)
    try:
        assert byte_unshuffle(byte_shuffle(raw, itemsize), itemsize) == raw
    finally:
        set_unshuffle_kernel(None)


def test_install_is_noop_off_tpu_without_force():
    from repro.lake import compression
    set_unshuffle_kernel(None)
    assert install_unshuffle_kernel() is ops._on_tpu()
    if not ops._on_tpu():
        assert compression.get_unshuffle_kernel() is None
