"""Handle API: Catalog, snapshot-pinned TensorRef, atomic WriteBatch."""

import numpy as np
import pytest

from repro.core import (BatchClosedError, DeltaTensorStore, SparseCOO,
                        TensorRef, get_codec)
from repro.lake import InMemoryObjectStore

from .test_encodings import sparse_tensor

LAYOUTS = ["ftsf", "coo", "csr", "csf", "bsgs"]


class CountingStore(InMemoryObjectStore):
    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.got_keys = []
        self.list_calls = 0

    def get(self, key):
        self.got_keys.append(key)
        return super().get(key)

    def list(self, prefix=""):
        self.list_calls += 1
        return super().list(prefix)

    def data_gets(self):
        # data files only: the delta log and the spilled catalog index
        # (probed once per cold catalog build) are metadata, not chunks
        return [k for k in self.got_keys
                if "_delta_log" not in k and "/_catalog/" not in k]


@pytest.fixture
def store():
    return DeltaTensorStore(InMemoryObjectStore(), "tensors")


# ---------------------------------------------------------------------------
# TensorRef: metadata, reads, numpy-style slicing
# ---------------------------------------------------------------------------

def test_ref_metadata_without_chunk_fetch():
    obj = CountingStore()
    store = DeltaTensorStore(obj, "t")
    x = np.arange(6 * 8, dtype=np.float32).reshape(6, 8)
    store.put(x, layout="ftsf", tensor_id="m", target_file_bytes=1 << 8)
    store._headers_by_path.clear()           # drop the post-commit seed
    obj.got_keys.clear()

    ref = store.open("m")
    assert obj.data_gets() == []             # opening fetches nothing
    assert ref.shape == (6, 8)
    assert ref.dtype == np.float32
    assert ref.layout == "ftsf"
    assert ref.nbytes > 0 and ref.n_chunk_files >= 2
    assert len(obj.data_gets()) == 1         # metadata cost: the header file only


@pytest.mark.parametrize("layout", LAYOUTS)
def test_ref_read_and_slice_parity(store, layout):
    x = sparse_tensor((12, 5, 6), density=0.15, seed=4)
    tid = store.put(x, layout=layout, target_file_bytes=2 << 10)
    ref = store.open(tid)
    np.testing.assert_array_equal(ref.read(), x)
    for spec in ([(3, 9)], [(0, 12), (2, 5)], [(11, 12)]):
        np.testing.assert_array_equal(ref.read_slice(spec),
                                      store.get_slice(tid, spec))


def test_ref_getitem_numpy_semantics(store):
    x = np.random.default_rng(3).standard_normal((7, 4, 5)).astype(np.float32)
    store.put(x, layout="ftsf", tensor_id="g", target_file_bytes=1 << 9)
    ref = store.open("g")
    np.testing.assert_array_equal(ref[2:5], x[2:5])
    np.testing.assert_array_equal(ref[3], x[3])
    np.testing.assert_array_equal(ref[-1], x[-1])
    np.testing.assert_array_equal(ref[1, 2], x[1, 2])
    np.testing.assert_array_equal(ref[..., 1:3], x[..., 1:3])
    np.testing.assert_array_equal(ref[2, ..., 4], x[2, ..., 4])
    np.testing.assert_array_equal(ref[:, 1:3, :], x[:, 1:3, :])
    np.testing.assert_array_equal(ref[...], x)
    with pytest.raises(IndexError):
        ref[1, 2, 3, 4]
    with pytest.raises(IndexError):
        ref[0:4:2]                            # strided slices unsupported
    with pytest.raises(IndexError):
        ref[99]


def test_ref_read_coo(store):
    x = sparse_tensor((9, 6, 4), density=0.1, seed=5)
    for layout in ("coo", "csf", "ftsf"):     # native, native, dense-fallback
        tid = store.put(x, layout=layout)
        coo = store.open(tid).read_coo()
        assert isinstance(coo, SparseCOO)
        np.testing.assert_array_equal(coo.to_dense(), x)


def test_read_async_matches_sync(store):
    x = sparse_tensor((16, 6, 5), density=0.2, seed=6)
    tid = store.put(x, layout="coo", target_file_bytes=2 << 10)
    ref = store.open(tid)
    futures = [ref.read_async(), ref.read_async([(4, 9)]), ref.read_coo_async()]
    np.testing.assert_array_equal(futures[0].result(), x)
    np.testing.assert_array_equal(futures[1].result(), x[4:9])
    np.testing.assert_array_equal(futures[2].result().to_dense(), x)


# ---------------------------------------------------------------------------
# snapshot pinning + time travel
# ---------------------------------------------------------------------------

def test_ref_time_travel_after_overwrite(store):
    x1 = np.arange(24, dtype=np.float32).reshape(4, 6)
    x2 = x1 * 10
    store.put(x1, layout="ftsf", tensor_id="t")
    v0 = store.version()
    store.put(x2, layout="ftsf", tensor_id="t", overwrite=True)
    np.testing.assert_array_equal(store.open("t").read(), x2)
    np.testing.assert_array_equal(store.open("t", version=v0).read(), x1)


def test_refs_from_one_snapshot_agree_under_concurrent_writes():
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t")
    writer = DeltaTensorStore(obj, "t")       # second client, same table
    x1 = np.ones((4, 4), np.float32)
    store.put(x1, layout="ftsf", tensor_id="w")

    cat = store.catalog()
    r1 = store.open("w")
    writer.put(x1 * 5, layout="ftsf", tensor_id="w", overwrite=True)  # concurrent
    r2 = cat.open("w")                        # same snapshot as r1
    assert r1.version == r2.version == cat.version
    np.testing.assert_array_equal(r1.read(), x1)
    np.testing.assert_array_equal(r2.read(), x1)   # both pinned pre-overwrite
    np.testing.assert_array_equal(store.open("w").read(), x1 * 5)  # unpinned


def test_pinned_ref_survives_delete(store):
    x = np.full((3, 3), 7.0, np.float32)
    store.put(x, layout="ftsf", tensor_id="d")
    ref = store.open("d")
    store.delete("d")
    with pytest.raises(KeyError):
        store.open("d")
    np.testing.assert_array_equal(ref.read(), x)   # old snapshot still readable


# ---------------------------------------------------------------------------
# WriteBatch: atomicity + header-cache hygiene
# ---------------------------------------------------------------------------

def test_batch_many_tensors_one_commit(store):
    v0 = store.version()
    with store.batch() as b:
        for i in range(5):
            b.put(np.full((4, 4), i, np.float32), layout="ftsf",
                  tensor_id=f"t{i}")
    assert b.version == v0 + 1                 # ONE commit for five tensors
    assert store.version() == v0 + 1
    assert [t for t, _ in store.list_tensors()] == [f"t{i}" for i in range(5)]
    for i in range(5):
        np.testing.assert_array_equal(store.open(f"t{i}").read(),
                                      np.full((4, 4), i, np.float32))


def test_batch_mixes_puts_overwrites_and_deletes(store):
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="keep")
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="kill")
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="replace")
    v = store.version()
    with store.batch() as b:
        b.put(np.zeros((3, 3)), layout="ftsf", tensor_id="new")
        b.put(np.full((2, 2), 9.0), layout="ftsf", tensor_id="replace",
              overwrite=True)
        b.delete("kill")
    assert store.version() == v + 1
    assert [t for t, _ in store.list_tensors()] == ["keep", "new", "replace"]
    np.testing.assert_array_equal(store.open("replace").read(),
                                  np.full((2, 2), 9.0))
    # the pre-batch state is one time-travel hop away
    assert [t for t, _ in store.list_tensors(version=v)] == \
        ["keep", "kill", "replace"]


def test_batch_exception_abandons_everything(store):
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="safe")
    v = store.version()
    with pytest.raises(RuntimeError, match="boom"):
        with store.batch() as b:
            b.put(np.zeros((4, 4)), layout="ftsf", tensor_id="phantom")
            raise RuntimeError("boom")
    assert store.version() == v                # no commit happened
    with pytest.raises(KeyError):
        store.open("phantom")
    assert [t for t, _ in store.list_tensors()] == ["safe"]


def test_abandoned_batch_leaves_no_stale_header(store):
    """Regression: put_deferred used to cache headers before any commit."""
    x1 = np.arange(12, dtype=np.float32).reshape(3, 4)
    store.put(x1, layout="ftsf", tensor_id="h")
    with pytest.raises(RuntimeError):
        with store.batch() as b:               # different shape, same id
            b.put(np.zeros((7, 7, 7), np.float32), layout="ftsf",
                  tensor_id="h", overwrite=True)
            raise RuntimeError("crash before commit")
    ref = store.open("h")
    assert ref.shape == (3, 4)                 # not the abandoned (7,7,7)
    np.testing.assert_array_equal(ref.read(), x1)


def test_put_deferred_alone_does_not_poison_reads(store):
    x1 = np.ones((2, 5), np.float32)
    store.put(x1, layout="ftsf", tensor_id="p")
    store.put_deferred(np.zeros((9, 9), np.float32), tensor_id="p",
                       layout="ftsf")         # uploaded, never committed
    assert store.open("p").shape == (2, 5)
    np.testing.assert_array_equal(store.get("p"), x1)


def test_batch_duplicate_and_existing_ids(store):
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="x")
    with pytest.raises(ValueError, match="already exists"):
        with store.batch() as b:
            b.put(np.ones((2, 2)), layout="ftsf", tensor_id="x")
    b = store.batch()
    b.put(np.ones((2, 2)), layout="ftsf", tensor_id="y")
    with pytest.raises(ValueError, match="staged twice"):
        b.put(np.ones((2, 2)), layout="ftsf", tensor_id="y")
    b.abandon()
    with pytest.raises(KeyError):
        store.batch().delete("nope")


def test_rejected_put_uploads_nothing():
    """A duplicate-id put must fail BEFORE paying any encode+upload."""
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t")
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="x")
    n_objects = len(list(obj.list("")))
    with pytest.raises(ValueError, match="already exists"):
        store.put(np.ones((64, 64)), layout="ftsf", tensor_id="x")
    assert len(list(obj.list(""))) == n_objects   # no orphaned part files


def test_batch_stages_against_pinned_base_snapshot():
    """Overwrite removes resolve against the batch's base, not a racing write."""
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t")
    racer = DeltaTensorStore(obj, "t")
    store.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id="w")
    b = store.batch()
    b.put(np.full((2, 2), 2.0, np.float32), layout="ftsf", tensor_id="w",
          overwrite=True)                        # pins the base here
    # a concurrent writer lands between staging and commit
    racer.put(np.full((2, 2), 9.0, np.float32), layout="ftsf", tensor_id="z")
    b.commit()
    np.testing.assert_array_equal(store.open("w").read(),
                                  np.full((2, 2), 2.0, np.float32))
    np.testing.assert_array_equal(store.open("z").read(),
                                  np.full((2, 2), 9.0, np.float32))


def test_batch_closed_after_commit(store):
    b = store.batch()
    b.put(np.ones((2, 2)), layout="ftsf", tensor_id="z")
    assert b.commit() == store.version()
    with pytest.raises(BatchClosedError):
        b.put(np.ones((2, 2)), layout="ftsf", tensor_id="z2")
    with pytest.raises(BatchClosedError):
        b.commit()


def test_empty_batch_commits_nothing(store):
    v = store.version()
    with store.batch():
        pass
    assert store.version() == v


# ---------------------------------------------------------------------------
# catalog: O(1) metadata per read
# ---------------------------------------------------------------------------

def test_repeated_reads_walk_snapshot_once():
    obj = CountingStore()
    store = DeltaTensorStore(obj, "t")
    x = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
    store.put(x, layout="ftsf", tensor_id="r", target_file_bytes=1 << 9)
    v = store.version()
    store.catalog_stats.update(builds=0, hits=0)

    for _ in range(10):
        np.testing.assert_array_equal(store.open("r", version=v).read(), x)
    assert store.catalog_stats["builds"] == 1      # ONE O(files) walk
    assert store.catalog_stats["hits"] == 9        # then O(1) lookups

    # and metadata ops share the same catalog — no extra walks, no listing
    lists_before = obj.list_calls
    assert store.shape_of("r", version=v) == (8, 16)
    assert store.tensor_bytes("r", version=v) > 0
    assert ("r", "ftsf") in store.list_tensors(version=v)
    assert store.catalog_stats["builds"] == 1
    assert obj.list_calls == lists_before


def test_catalog_inventory(store):
    store.put(np.ones((2, 2)), layout="ftsf", tensor_id="a")
    store.put(sparse_tensor((6, 6), density=0.1, seed=1), layout="coo",
              tensor_id="b")
    cat = store.catalog()
    assert len(cat) == 2 and "a" in cat and "zzz" not in cat
    assert list(cat) == ["a", "b"]
    assert cat.tensors() == [("a", "ftsf"), ("b", "coo")]
    assert cat.entry("a").layout == "ftsf"
    assert isinstance(cat.open("a"), TensorRef)
    with pytest.raises(KeyError):
        cat.entry("zzz")


# ---------------------------------------------------------------------------
# codec capability flags
# ---------------------------------------------------------------------------

def test_codec_capability_flags():
    assert get_codec("ftsf").supports_slice and not get_codec("ftsf").supports_coo
    for layout in ("coo", "csr", "csc", "csf"):
        assert get_codec(layout).supports_slice
        assert get_codec(layout).supports_coo
    assert not get_codec("bsgs").supports_coo  # dense round-trip, not native


def test_unsupported_slice_raises_before_any_fetch(monkeypatch):
    obj = CountingStore()
    store = DeltaTensorStore(obj, "t")
    x = np.arange(16, dtype=np.float32).reshape(4, 4)
    store.put(x, layout="ftsf", tensor_id="s")
    ref = store.open("s")
    monkeypatch.setattr(type(get_codec("ftsf")), "supports_slice", False)
    obj.got_keys.clear()
    with pytest.raises(NotImplementedError, match="slice"):
        ref.read_slice([(0, 2)])
    assert obj.data_gets() == []               # raised before any chunk get
