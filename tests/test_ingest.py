"""Streaming ingest: watermark commits, crash-consistent recovery, and the
fault-injection harness.

The crash tests drive :class:`~repro.data.ingest.IngestWriter` into a
deterministic failure at every seam of a flush (mid-seal, after upload /
before commit, lost commit ack, mid-commit-retry) via
:class:`~repro.lake.FaultInjectingObjectStore`, then assert the headline
correctness claim: the table is NEVER torn — a killed writer leaves only
invisible orphans that vacuum reclaims exactly, and a restarted writer
resumes from the committed row count without duplicating a row.
"""

import os
import threading

import numpy as np
import pytest

from repro.core.store import DeltaTensorStore
from repro.data.stream import StreamLoader
from repro.lake import (FaultInjectingObjectStore, FaultRule,
                        InjectedFault, InMemoryObjectStore, LatencyModel)

from ._hypothesis_compat import given, settings, st

WIDTH = 4


def rows_for(lo, hi, width=WIDTH, dtype=np.int32):
    """Distinct, self-describing sample rows: row i holds i*width..i*width+w."""
    return np.arange(lo * width, hi * width, dtype=dtype).reshape(-1, width)


def fresh(shards=1, **kw):
    obj = InMemoryObjectStore()
    return obj, DeltaTensorStore(obj, "ts", shards=shards, **kw)


def faulty_fresh(**kw):
    faulty = FaultInjectingObjectStore(InMemoryObjectStore())
    return faulty, DeltaTensorStore(faulty, "ts", **kw)


def part_keys(obj):
    """Every stored data-file key (any shard), by the part- naming scheme."""
    return {k for k in obj.list("")
            if k.rsplit("/", 1)[-1].startswith("part-")}


# -- watermark semantics ------------------------------------------------------


def test_row_watermark_commits_and_close_flushes_the_tail():
    obj, store = fresh()
    w = store.ingest("t", watermark_rows=4)
    versions = [w.append_rows(rows_for(i, i + 1)) for i in range(10)]
    # exactly two watermark commits (at rows 4 and 8), buffered tail of 2
    assert [v is not None for v in versions].count(True) == 2
    assert w.rows_pending == 2 and w.rows_committed == 8
    w.close()
    assert w.rows_committed == 10 and w.rows_pending == 0
    assert np.array_equal(store.get("t"), rows_for(0, 10))
    with pytest.raises(RuntimeError):
        w.append_rows(rows_for(0, 1))


def test_time_watermark_commits_via_poll():
    clock = [0.0]
    obj, store = fresh()
    w = store.ingest("t", watermark_rows=10_000, watermark_s=5.0,
                     clock=lambda: clock[0])
    w.append_rows(rows_for(0, 2))
    assert w.poll() is None and w.rows_committed == 0
    clock[0] = 6.0
    assert w.poll() is not None
    assert w.rows_committed == 2
    # appends also honor the expired time watermark
    w.append_rows(rows_for(2, 3))
    clock[0] = 20.0
    assert w.append_rows(rows_for(3, 4)) is not None
    assert np.array_equal(store.get("t"), rows_for(0, 4))
    w.close()


def test_append_validates_shape_and_dtype():
    obj, store = fresh()
    with store.ingest("t", watermark_rows=2) as w:
        w.append_rows(rows_for(0, 2))
        with pytest.raises(ValueError):
            w.append_rows(np.zeros((1, WIDTH + 1), np.int32))
        with pytest.raises(ValueError):
            w.append_rows(np.zeros((1, WIDTH), np.float64))
        with pytest.raises(ValueError):
            w.append_rows(np.int32(3))
        assert w.append_rows(np.zeros((0, WIDTH), np.int32)) is None


def test_ingest_grows_a_put_tensor_and_slices_cross_the_boundary():
    obj, store = fresh(shards=2)
    store.put(rows_for(0, 6), tensor_id="t", layout="ftsf")
    with store.ingest("t", watermark_rows=4) as w:
        assert w.row_count == 6
        w.append_rows(rows_for(6, 10))
    assert np.array_equal(store.get("t"), rows_for(0, 10))
    # a slice window spanning old and ingested files prunes + decodes right
    assert np.array_equal(store.get_slice("t", [(4, 9)]), rows_for(0, 10)[4:9])


def test_ingest_rejects_non_row_chunked_layouts():
    obj, store = fresh()
    store.put(np.arange(64.0).reshape(8, 8), tensor_id="c", layout="csf")
    with pytest.raises(ValueError):
        store.ingest("c")


def test_deduped_ingest_chunks_commit_as_physpath_references():
    obj, store = fresh()  # dedup on by default
    payload = rows_for(0, 8)
    with store.ingest("a", watermark_rows=8) as w:
        w.append_rows(payload)
    with store.ingest("b", watermark_rows=8) as w:
        w.append_rows(payload)
    entry = store.catalog().entry("b")
    assert entry.chunk_adds and all(a.get("physPath") for a in entry.chunk_adds)
    assert np.array_equal(store.get("b"), payload)
    # deleting the alias never strands the original's bytes
    store.delete("b")
    store.vacuum()
    assert np.array_equal(store.get("a"), payload)


def test_spill_to_index_stays_correct_past_the_threshold():
    obj, store = fresh(spill_threshold=8)
    with store.ingest("t", watermark_rows=2, target_file_bytes=32) as w:
        for i in range(0, 12, 2):
            w.append_rows(rows_for(i, i + 2))
    assert any("/_catalog/" in k for k in obj.list("")), \
        "ingest commits past the threshold must spill a catalog index"
    cold = DeltaTensorStore(obj, "ts", spill_threshold=8)
    assert np.array_equal(cold.get("t"), rows_for(0, 12))
    assert cold.catalog_stats["index_loads"] >= 1


# -- snapshot isolation / reader handoff --------------------------------------


def test_pinned_reader_is_isolated_and_reopen_picks_up_new_rows():
    obj, store = fresh(shards=2)
    store.put(rows_for(0, 8), tensor_id="t", layout="ftsf")
    loader = StreamLoader(store, "t", batch_size=4, epochs=1, seed=3)
    before = {b["step"]: b["data"].copy() for b in loader}
    assert len(before) == 2  # 2 batches of 4

    with store.ingest("t", watermark_rows=4) as w:
        w.append_rows(rows_for(8, 16))
    # the pinned loader replays byte-identically after the ingest commits
    loader.seek(0, 0)
    again = {b["step"]: b["data"] for b in loader}
    assert before.keys() == again.keys()
    for step, data in before.items():
        assert np.array_equal(data, again[step])
    assert loader.steps_per_epoch == 2

    reopened = loader.reopen()
    assert loader.closed and not reopened.closed
    assert reopened.steps_per_epoch == 4  # 16 rows now owned
    seen = np.sort(np.concatenate([b["samples"] for b in reopened]))
    assert np.array_equal(seen, np.arange(16))
    reopened.close()


# -- crash seams --------------------------------------------------------------


def crashed_flush(store, faulty, rule, *, n_rows=6, tid="t",
                  target_file_bytes=64):
    """Drive one writer into `rule` during its first flush; return the
    writer and the set of orphan keys the crash left behind."""
    before = part_keys(faulty)
    w = store.ingest(tid, watermark_rows=n_rows,
                     target_file_bytes=target_file_bytes)
    w.append_rows(rows_for(0, n_rows - 1))
    faulty.add_rule(rule)
    with pytest.raises(InjectedFault):
        w.append_rows(rows_for(n_rows - 1, n_rows))  # trips the watermark
    faulty.clear_rules()
    return w, part_keys(faulty) - before


@pytest.mark.parametrize("seam,rule", [
    # the writer dies after uploading data files, before the commit put
    ("before-commit", FaultRule(op="put", key="_delta_log", action="raise")),
    # the writer dies halfway through sealing (2nd data-file upload fails)
    ("mid-seal", FaultRule(op="put", key="part-", nth=2, action="raise")),
    # a data-file upload is torn: half the bytes land, then the writer dies
    ("torn-upload", FaultRule(op="put", key="part-", nth=2, action="partial")),
])
def test_crash_seams_never_tear_and_vacuum_reclaims_exactly(seam, rule):
    faulty, store = faulty_fresh()
    w, orphans = crashed_flush(store, faulty, rule)
    assert orphans, seam

    # 1) never torn: the table is fully readable and shows no partial flush
    assert store.list_tensors() == []
    assert store.tables[0].snapshot().files == {}

    # 2) vacuum reclaims exactly the crash's orphans (guard closed on exit)
    res = store.vacuum()
    assert set(res[0].deleted_paths) == \
        {k.split("/", 1)[1] for k in orphans}
    assert part_keys(faulty) == set()

    # 3) a restarted writer resumes from the committed row count (0 here):
    # the producer replays its uncommitted rows, nothing duplicates
    w2 = store.ingest("t", watermark_rows=4)
    assert w2.row_count == 0
    w2.append_rows(rows_for(w2.row_count, 6))
    w2.close()
    assert np.array_equal(store.get("t"), rows_for(0, 6))


def test_lost_commit_ack_is_detected_not_double_ingested():
    faulty, store = faulty_fresh()
    w = store.ingest("t", watermark_rows=3)
    w.append_rows(rows_for(0, 3))
    # the NEXT commit put lands but its acknowledgement is lost
    faulty.add_rule(FaultRule(op="put", key="_delta_log",
                              action="raise-after"))
    v = w.append_rows(rows_for(3, 6))
    assert v is not None  # flush recognized its own landed commit
    assert w.rows_committed == 6
    w.close()
    assert np.array_equal(store.get("t"), rows_for(0, 6))
    assert store.tables[0].version() == v


def test_crash_mid_commit_retry_after_conflict():
    faulty, store = faulty_fresh()
    w1 = store.ingest("t", watermark_rows=4)
    w2 = store.ingest("t", watermark_rows=4)
    w1.append_rows(rows_for(0, 4))          # lands rows 0..3
    before = part_keys(faulty)
    # w2 stages rows at base 0, conflicts with w1, and dies while
    # re-sealing at the rebased row count (its 2nd upload generation)
    faulty.add_rule(FaultRule(op="put", key="part-", nth=4, action="raise"))
    with pytest.raises(InjectedFault):
        w2.append_rows(rows_for(100, 104))
    faulty.clear_rules()
    assert w2.conflicts == 1 and w2.reencodes == 1

    # never torn: only w1's flush is visible
    assert np.array_equal(store.get("t"), rows_for(0, 4))
    # both abandoned upload generations are orphans; vacuum reclaims all
    orphans = part_keys(faulty) - before
    assert len(orphans) >= 3
    res = store.vacuum()
    assert set(res[0].deleted_paths) >= \
        {k.split("/", 1)[1] for k in orphans}
    assert np.array_equal(store.get("t"), rows_for(0, 4))

    # the restarted writer resumes after w1's committed rows
    w3 = store.ingest("t", watermark_rows=4)
    assert w3.row_count == 4
    w3.append_rows(rows_for(100, 104))
    w3.close()
    assert np.array_equal(store.get("t")[4:], rows_for(100, 104))


def test_conflict_on_unrelated_tensor_is_a_cheap_retry():
    obj, store = fresh()  # one shard: both tensors share the commit domain
    w_a = store.ingest("a", watermark_rows=4)
    w_b = store.ingest("b", watermark_rows=4)
    w_a.append_rows(rows_for(0, 4))   # moves the fence under w_b
    w_b.append_rows(rows_for(50, 54))
    assert w_b.conflicts == 1 and w_b.reencodes == 0, \
        "an unrelated commit must not force a re-upload"
    assert np.array_equal(store.get("a"), rows_for(0, 4))
    assert np.array_equal(store.get("b"), rows_for(50, 54))
    assert store.commit_stats["conflicts"] == store.commit_stats["retries"]
    w_a.close()
    w_b.close()


def test_two_writers_one_tensor_interleave_without_losing_rows():
    obj, store = fresh()
    w1 = store.ingest("t", watermark_rows=2)
    w2 = store.ingest("t", watermark_rows=2)
    for i in range(0, 8, 2):
        (w1 if i % 4 == 0 else w2).append_rows(rows_for(i, i + 2))
    w1.close()
    w2.close()
    got = store.get("t")
    assert got.shape == (8, WIDTH)
    # every appended row survives exactly once (order = commit order)
    assert sorted(map(tuple, got)) == sorted(map(tuple, rows_for(0, 8)))
    store.vacuum()
    assert store.get("t").shape == (8, WIDTH)


# -- property test: arbitrary interleavings -----------------------------------


def _run_ingest_interleaving(ops):
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "ts", shards=2)
    w = store.ingest("t", watermark_rows=4, target_file_bytes=128)
    appended = [0]
    pins = []  # (open TensorRef, frozen copy of what it read)
    try:
        for op, arg in ops:
            if op == "append":
                k = (arg % 3) + 1
                w.append_rows(rows_for(appended[0], appended[0] + k))
                appended[0] += k
            elif op == "flush":
                w.flush()
            elif op == "reader":
                if w.rows_committed:
                    ref = store.open("t")
                    pins.append((ref, ref.read().copy()))
            elif op == "vacuum":
                store.vacuum()
        w.close()

        # every pinned read is byte-identical after any later appends,
        # watermark commits, and vacuums
        for ref, frozen in pins:
            assert np.array_equal(ref.read(), frozen)
        # the final row set is exact: every appended row, exactly once,
        # in append order
        if appended[0]:
            assert np.array_equal(store.get("t"), rows_for(0, appended[0]))
        store.vacuum()
        if appended[0]:
            assert np.array_equal(store.get("t"), rows_for(0, appended[0]))
    finally:
        for ref, _ in pins:
            ref.close()


_OPS = st.lists(st.tuples(st.sampled_from(["append", "flush", "reader",
                                           "vacuum"]),
                          st.integers(min_value=0, max_value=7)),
                max_size=24)


@settings(max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "25")),
          deadline=None)
@given(ops=_OPS)
def test_ingest_interleavings_keep_pinned_reads_and_final_rows_exact(ops):
    _run_ingest_interleaving(ops)


@pytest.mark.parametrize("ops", [
    # commit, pin a reader, keep ingesting, vacuum under the pin
    [("append", 3), ("flush", 0), ("reader", 0), ("append", 2),
     ("flush", 0), ("vacuum", 0), ("append", 1), ("vacuum", 0)],
    # vacuum between every step, reader pinned mid-stream
    [("append", 0), ("vacuum", 0), ("flush", 0), ("vacuum", 0),
     ("reader", 0), ("append", 2), ("vacuum", 0), ("flush", 0),
     ("vacuum", 0)],
    # watermark-triggered commits only (no explicit flush), two pins
    [("append", 2), ("append", 2), ("reader", 0), ("append", 2),
     ("append", 2), ("reader", 0), ("vacuum", 0)],
])
def test_ingest_fixed_interleavings(ops):
    # deterministic fallback for environments without hypothesis
    _run_ingest_interleaving(ops)


# -- concurrency stress -------------------------------------------------------


def test_concurrent_ingest_readers_and_compact_stress():
    """4 threaded ingest writers + 2 StreamLoader readers + periodic
    compact/vacuum on a 4-shard store, run for 200 virtual-clock seconds:
    zero lost rows, zero reader errors, every commit conflict retried."""
    lm = LatencyModel(rtt_s=0.5, virtual_clock=True, parallelism=4,
                      occupancy_scale=0.001)
    obj = InMemoryObjectStore(latency=lm)
    store = DeltaTensorStore(obj, "ts", shards=4)
    tids = [f"w{i}" for i in range(4)]
    counts = {t: 0 for t in tids}

    def tag(i, t):
        # writer i's row t: a constant row (torn rows would show mixed
        # values), unique across writers
        return np.full((1, WIDTH), i * 1_000_000 + t, dtype=np.int64)

    # pre-phase: every tensor exists with enough rows for a batch
    for i, t in enumerate(tids):
        with store.ingest(t, watermark_rows=8) as w:
            for _ in range(8):
                w.append_rows(tag(i, counts[t]))
                counts[t] += 1

    stop = threading.Event()
    errors = []
    batches = [0]

    def writer(i):
        t = tids[i]
        try:
            w = store.ingest(t, watermark_rows=8)
            flushes = 0
            while lm.elapsed_s < 200.0 and flushes < 12:
                for _ in range(8):
                    w.append_rows(tag(i, counts[t]))
                    counts[t] += 1
                flushes += 1
            w.close()
        except Exception as e:  # pragma: no cover - the assertion payload
            errors.append(("writer", i, e))

    def reader(j):
        try:
            loader = StreamLoader(store, tids, batch_size=8, epochs=1,
                                  seed=j, clock=lambda: lm.elapsed_s)
            while not stop.is_set():
                for b in loader:
                    data = np.asarray(b["data"])
                    # rows are never torn: each sample row is constant
                    assert (data == data[:, :1]).all()
                    batches[0] += 1
                    if stop.is_set():
                        break
                loader = loader.reopen()
            loader.close()
        except Exception as e:  # pragma: no cover - the assertion payload
            errors.append(("reader", j, e))

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    threads += [threading.Thread(target=reader, args=(j,)) for j in range(2)]
    for th in threads:
        th.start()
    # maintenance loop on the main thread: compact + vacuum race the writers
    while any(th.is_alive() for th in threads[:4]):
        store.compact()
        store.vacuum()
        for th in threads[:4]:
            th.join(timeout=0.05)
    stop.set()
    for th in threads:
        th.join(timeout=60)
    assert not errors, errors
    assert batches[0] > 0

    # zero lost rows: every writer's appends are all present, exactly once
    for i, t in enumerate(tids):
        got = store.get(t)
        assert got.shape == (counts[t], WIDTH), t
        want = np.arange(counts[t], dtype=np.int64) + i * 1_000_000
        assert np.array_equal(np.sort(got[:, 0]), want), t
    # commit conflicts were all absorbed by retries, none escaped
    assert store.commit_stats["conflicts"] == store.commit_stats["retries"]
    store.vacuum()
    for t in tids:
        assert store.get(t).shape[0] == counts[t]
