"""Content-addressed chunk store: dedup index, refcounted GC, variants.

The contract under test: byte-identical chunks are stored ONCE (re-puts
commit references, not uploads), ``put_variant`` stores a fine-tune as
XOR deltas against its base's objects, and the vacuum liveness closure
counts every reference — logical path, dedup alias (``physPath``) and
delta base (``deltaBase``, cross-shard included) — so no interleaving of
put / put_variant / delete / compact / vacuum ever reclaims a chunk some
retained or leased snapshot still needs, nor leaks one nothing needs.
"""

import gc as _gc
import json

import numpy as np
import pytest

from repro.core import DeltaTensorStore, chunk_index_for
from repro.core.cas import chunk_index_key
from repro.lake import InMemoryObjectStore, LocalFSObjectStore, ReadExecutor
from repro.lake.table import physical_path

from ._hypothesis_compat import given, settings, st

RNG = np.random.default_rng(11)


def dense(shape=(8, 32, 32), seed=None):
    r = np.random.default_rng(seed) if seed is not None else RNG
    x = r.standard_normal(shape)
    return (np.round(x * 64) / 64).astype(np.float32)


def fresh(compression="zlib+shuffle", cache_bytes=1 << 20, **kw):
    obj = kw.pop("obj", None) or InMemoryObjectStore()
    io = ReadExecutor(max_workers=4, cache_bytes=cache_bytes)
    return obj, DeltaTensorStore(obj, "t", io=io, compression=compression,
                                 **kw)


def data_keys(obj, root="t"):
    return sorted(k for k in obj.list(f"{root}/")
                  if "_delta_log" not in k and "/_catalog/" not in k
                  and "/_cas/" not in k and "_store_manifest" not in k)


def live_closure(store):
    """Every object key some retained version still references."""
    live = set()
    for table in store.tables:
        latest = table.version()
        if latest < 0:
            continue
        for v in table.retained_versions(
                horizon=max(0, latest - (store.retention.keep_versions - 1))):
            snap = table.snapshot(version=v)
            for path, add in snap.files.items():
                live.add(f"{table.path}/{add.get('physPath') or path}")
                if add.get("deltaBase"):
                    live.add(add["deltaBase"])
    return live


# ---------------------------------------------------------------------------
# dedup on put: identical chunks upload once
# ---------------------------------------------------------------------------


def test_identical_put_stores_chunks_once():
    obj, store = fresh()
    x = dense()
    store.put(x, tensor_id="a", layout="ftsf")
    keys_before = data_keys(obj)
    store.put(x, tensor_id="b", layout="ftsf")
    keys_after = data_keys(obj)
    # only the header file is new: every chunk deduped into a reference
    new = set(keys_after) - set(keys_before)
    assert len(new) == 1, new
    assert np.array_equal(store.get("a"), x)
    assert np.array_equal(store.get("b"), x)
    dd = store.storage_stats()["dedup"]
    assert dd["deduped_refs"] >= 1 and dd["saved_bytes"] > 0
    assert store.storage_stats()["referenced_bytes"] > \
        store.storage_stats()["physical_bytes"]


def test_dedup_add_actions_alias_not_share_paths():
    obj, store = fresh()
    x = dense()
    store.put(x, tensor_id="a")
    store.put(x, tensor_id="b")
    cat = store.catalog()
    a_adds, b_adds = cat.entry("a").chunk_adds, cat.entry("b").chunk_adds
    # logical paths stay unique (the delta log is path-keyed)...
    assert {ad["path"] for ad in a_adds}.isdisjoint(
        {ad["path"] for ad in b_adds})
    # ...but the physical objects are shared via physPath
    assert {physical_path(ad) for ad in a_adds} == \
        {physical_path(ad) for ad in b_adds}
    assert all(ad.get("contentHash") for ad in b_adds)


def test_dedup_within_one_batch_and_off_switch():
    x = dense()
    _, store = fresh()
    with store.batch() as b:
        b.put(x, tensor_id="a")
        b.put(x, tensor_id="b")
    assert store.storage_stats()["dedup"]["deduped_refs"] >= 1

    _, plain = fresh(dedup=False)
    plain.put(x, tensor_id="a")
    plain.put(x, tensor_id="b")
    assert plain.storage_stats()["dedup"]["deduped_refs"] == 0


def test_self_identical_chunks_stay_distinct_keys():
    # a tensor whose chunks are all byte-identical: the intra-tensor
    # guard keeps one physical object per add (the read scheduler
    # counts distinct keys per tensor), so reads stay correct
    _, store = fresh()
    x = np.zeros((8, 32, 32), dtype=np.float32)
    store.put(x, tensor_id="z", chunk_dims=1)
    assert np.array_equal(store.get("z"), x)
    outs = store.catalog().read_many([("z", None)])
    assert np.array_equal(outs[0], x)


# ---------------------------------------------------------------------------
# put_variant: delta storage against a base
# ---------------------------------------------------------------------------


def test_put_variant_roundtrip_and_footprint():
    obj, store = fresh()
    base = dense((16, 64, 64), seed=1)
    store.put(base, tensor_id="m")
    base_phys = store.storage_stats()["physical_bytes"]

    var = base.copy()
    var[2:4] += 0.015625  # perturb ~12% of the values
    vid = store.put_variant(var, base_tid="m")
    assert vid.startswith("m~")
    assert np.array_equal(store.get(vid), var)
    assert np.array_equal(store.get("m"), base)

    st_ = store.storage_stats()
    assert st_["dedup"]["delta_files"] >= 1
    # identical chunks deduped + changed chunks delta-encoded: the
    # variant adds a small fraction of the base's physical footprint
    assert st_["physical_bytes"] < 1.6 * base_phys, \
        (st_["physical_bytes"], base_phys)

    # slices and merged plans read through the delta transparently
    assert np.array_equal(store.open(vid).read_slice([(2, 4), None, None]),
                          var[2:4])
    outs = store.catalog().read_many([(vid, None), ("m", None)])
    assert np.array_equal(outs[0], var) and np.array_equal(outs[1], base)
    assert store.io_stats()["deltas_reconstructed"] >= 1


def test_put_variant_explicit_id_and_duplicate_rejection():
    _, store = fresh()
    base = dense()
    store.put(base, tensor_id="m")
    vid = store.put_variant(base + 1, base_tid="m", tensor_id="m-ft")
    assert vid == "m-ft"
    with pytest.raises(ValueError):
        store.put_variant(base, base_tid="m", tensor_id="m-ft")
    vid2 = store.put_variant(base + 2, base_tid="m", tensor_id="m-ft",
                             overwrite=True)
    assert np.array_equal(store.get(vid2), base + 2)
    with pytest.raises(KeyError):
        store.put_variant(base, base_tid="nope")


def test_variant_identical_to_base_is_pure_references():
    obj, store = fresh()
    base = dense()
    store.put(base, tensor_id="m")
    before = data_keys(obj)
    vid = store.put_variant(base.copy(), base_tid="m")
    new = set(data_keys(obj)) - set(before)
    assert len(new) == 1, new  # header only: every chunk deduped
    assert np.array_equal(store.get(vid), base)


def test_variant_of_variant_anchors_on_nondelta_base():
    # delta chains stay single-hop: a variant's deltas may only target
    # objects that are not themselves delta-stored
    _, store = fresh()
    base = dense((16, 64, 64), seed=2)
    store.put(base, tensor_id="m")
    v1 = store.put_variant(base + 0.5, base_tid="m")
    v2 = store.put_variant(base + 1.0, base_tid=v1)
    assert np.array_equal(store.get(v2), base + 1.0)
    cat = store.catalog()
    nondelta_rels = {physical_path(a) for t in ("m", v1)
                     for a in cat.entry(t).chunk_adds
                     if not a.get("deltaBase")}
    v1_delta_rels = {physical_path(a) for a in cat.entry(v1).chunk_adds
                     if a.get("deltaBase")}
    for a in cat.entry(v2).chunk_adds:
        if not a.get("deltaBase"):
            continue
        rel = a["deltaBase"].rsplit("/", 1)[-1]
        assert rel not in v1_delta_rels, "delta anchored on another delta"
        assert rel in nondelta_rels


def test_variant_mismatched_shape_falls_back_to_plain_rows():
    _, store = fresh()
    base = dense((8, 32, 32))
    store.put(base, tensor_id="m")
    grown = np.concatenate([base, base[:2] + 1.0], axis=0)
    vid = store.put_variant(grown, base_tid="m")
    assert np.array_equal(store.get(vid), grown)


# ---------------------------------------------------------------------------
# refcount-aware vacuum
# ---------------------------------------------------------------------------


def test_vacuum_keeps_shared_chunks_until_last_reference_dies():
    obj, store = fresh(cache_bytes=0)
    x = dense()
    store.put(x, tensor_id="a")
    store.put(x, tensor_id="b")
    store.delete("a")
    store.vacuum()
    assert np.array_equal(store.get("b"), x)  # shared chunks survived
    store.delete("b")
    store.vacuum()
    assert data_keys(obj) == []               # last ref gone: all reclaimed


def test_vacuum_keeps_delta_base_alive_and_reclaims_variant_chunks():
    obj, store = fresh(cache_bytes=0)
    base = dense((16, 64, 64), seed=3)
    store.put(base, tensor_id="m")
    var = base.copy()
    var[0:3] += 0.25
    vid = store.put_variant(var, base_tid="m")
    store.delete("m")  # base tensor gone, but variant's deltas need it
    store.vacuum()
    assert np.array_equal(store.get(vid), var)
    store.delete(vid)
    store.vacuum()
    assert data_keys(obj) == []


def test_vacuum_reclaims_exactly_unshared_chunks():
    obj, store = fresh(cache_bytes=0)
    base = dense((16, 64, 64), seed=4)
    store.put(base, tensor_id="m")
    var = base.copy()
    var[0:2] += 0.125
    vid = store.put_variant(var, base_tid="m")
    with_variant = set(data_keys(obj))
    store.delete(vid)
    res = store.vacuum()
    deleted = {p for r in res for p in r.deleted_paths}
    survivors = set(data_keys(obj))
    # exactly the variant-only objects went; every base object remains
    assert survivors | {f"t/{p}" for p in deleted} >= with_variant
    assert np.array_equal(store.get("m"), base)
    closure = live_closure(store)
    assert {k for k in survivors} <= closure | set()


def test_leased_reads_stay_byte_identical_through_churn():
    _, store = fresh(cache_bytes=0)
    base = dense((16, 64, 64), seed=5)
    store.put(base, tensor_id="m")
    var = base.copy()
    var[1:3] -= 0.5
    vid = store.put_variant(var, base_tid="m")
    ref_b, ref_v = store.open("m"), store.open(vid)
    store.delete(vid)
    store.delete("m")
    store.compact()
    store.vacuum()
    assert np.array_equal(ref_v.read(), var)
    assert np.array_equal(ref_b.read(), base)
    ref_b.close(), ref_v.close()
    store.vacuum()


def test_chunk_index_drops_entries_for_vacuumed_objects():
    _, store = fresh(cache_bytes=0)
    x = dense()
    store.put(x, tensor_id="a")
    idx = store.tables[0].cas
    n = len(idx)
    assert n > 0
    store.delete("a")
    store.vacuum()
    assert len(idx) < n
    # a re-put after reclamation must re-upload, not reference a ghost
    store.put(x, tensor_id="a2")
    assert np.array_equal(store.get("a2"), x)


# ---------------------------------------------------------------------------
# compact / recompress preserve dedup
# ---------------------------------------------------------------------------


def test_compact_skips_shared_and_delta_files():
    _, store = fresh()
    x = dense()
    store.put(x, tensor_id="a")
    store.put(x, tensor_id="b")
    vid = store.put_variant(x + 1, base_tid="a")
    res = store.compact()
    assert all(r.files_compacted == 0 for r in res)
    assert sum(r.files_skipped_shared for r in res) >= 1
    for tid, want in (("a", x), ("b", x), (vid, x + 1)):
        assert np.array_equal(store.get(tid), want)


def test_compact_result_counts_physical_bytes_once():
    _, store = fresh()
    # two files in one partition -> a genuine merge, unshared
    store.tables[0].append({"v": np.arange(64)},
                           partition_values={"tensor": "r", "kind": "chunks",
                                             "layout": "ftsf"})
    store.tables[0].append({"v": np.arange(64) + 64},
                           partition_values={"tensor": "r", "kind": "chunks",
                                             "layout": "ftsf"})
    res = store.tables[0].compact()
    assert res.files_written == 1
    snap = store.tables[0].snapshot()
    merged_sizes = sum(int(a["size"]) for a in snap.add_actions()
                      if (a.get("partitionValues") or {}).get("tensor") == "r")
    assert res.bytes_rewritten == merged_sizes


def test_recompress_then_vacuum_keeps_delta_bases():
    _, store = fresh(cache_bytes=0)
    base = dense((16, 64, 64), seed=6)
    store.put(base, tensor_id="m")
    var = base.copy()
    var[4:6] *= 2
    vid = store.put_variant(var, base_tid="m")
    store.compact(recompress="zlib:9+shuffle")
    store.vacuum()
    assert np.array_equal(store.get(vid), var)
    assert np.array_equal(store.get("m"), base)


# ---------------------------------------------------------------------------
# collision paranoia: (hash, raw_size) keys + reuse verification
# ---------------------------------------------------------------------------


def test_hash_collision_with_different_size_never_aliases(monkeypatch):
    import repro.lake.table as table_mod
    monkeypatch.setattr(table_mod, "chunk_hash",
                        lambda data: "constant-digest")
    # cache-free: the block cache trusts recorded content hashes (sound
    # for a real 160-bit blake2b); under test is the INDEX refusing to
    # alias two entries whose raw sizes disagree
    _, store = fresh(cache_bytes=0)
    a = dense((4, 16, 16), seed=7)
    b = dense((8, 16, 16), seed=8)  # same fake hash, different raw size
    store.put(a, tensor_id="a")
    store.put(b, tensor_id="b")
    cat = store.catalog()
    assert {physical_path(ad) for ad in cat.entry("a").chunk_adds} \
        .isdisjoint({physical_path(ad) for ad in cat.entry("b").chunk_adds})
    assert np.array_equal(store.get("a"), a)
    assert np.array_equal(store.get("b"), b)


def test_reuse_verifies_object_exists_before_referencing():
    obj, store = fresh()
    x = dense()
    store.put(x, tensor_id="a")
    idx = store.tables[0].cas
    # simulate a stale index entry: delete the object behind its back
    victim = next(iter(idx._by_hash.values()))
    victim.verified = False
    obj.delete(f"t/{victim.path}")
    store.put(x, tensor_id="b")  # must re-upload, not alias the ghost
    assert np.array_equal(store.get("b"), x)
    assert idx.stats["verify_failures"] >= 1


# ---------------------------------------------------------------------------
# spilled index: reload, verification, backfill migration
# ---------------------------------------------------------------------------


def test_chunk_index_spills_and_reloads_across_processes(tmp_path):
    obj = LocalFSObjectStore(str(tmp_path))
    store = DeltaTensorStore(obj, "t", io=ReadExecutor(max_workers=2),
                             compression="zlib+shuffle")
    x = dense()
    store.put(x, tensor_id="a")
    key = store.tables[0].cas.spill(store.tables[0], force=True)
    assert key == chunk_index_key(store.tables[0].path)
    assert obj.exists(key)
    rec = json.loads(obj.get(key).decode("utf8"))
    assert rec["format"] == 1 and rec["chunks"]

    del store
    _gc.collect()  # drop the weakly-registered in-memory index

    store2 = DeltaTensorStore(LocalFSObjectStore(str(tmp_path)), "t",
                              io=ReadExecutor(max_workers=2),
                              compression="zlib+shuffle")
    idx2 = store2.tables[0].cas
    assert idx2 is not None and len(idx2) == 0  # lazy: loads on first use
    store2.put(x, tensor_id="b")
    assert idx2.stats["hits"] >= 1 and idx2.stats["verified"] >= 1
    assert store2.storage_stats()["dedup"]["deduped_refs"] >= 1
    assert np.array_equal(store2.get("b"), x)


def test_build_chunk_index_backfills_pre_cas_tables():
    obj, store = fresh(dedup=False)
    x = dense()
    store.put(x, tensor_id="a")
    assert store.tables[0].cas is None
    # migration: enable dedup, backfill from the latest snapshot
    store.dedup = True
    for t in store.tables:
        t.cas = chunk_index_for(t)
    counts = store.build_chunk_index()
    assert sum(counts) == len(store.catalog().entry("a").chunk_adds)
    store.put(x, tensor_id="b")
    assert store.storage_stats()["dedup"]["deduped_refs"] >= 1
    assert np.array_equal(store.get("b"), x)
    # idempotent: a second pass finds nothing new to add
    assert store.build_chunk_index() == [0]


def test_gc_cli_build_chunk_index(tmp_path, capsys):
    from repro.launch import gc as gc_cli
    obj = LocalFSObjectStore(str(tmp_path))
    store = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    store.put(dense(), tensor_id="a")
    del store
    _gc.collect()
    rc = gc_cli.main(["--dir", str(tmp_path), "--root", "tensors",
                      "--build-chunk-index"])
    assert rc == 0
    assert "chunk index covers" in capsys.readouterr().out
    assert LocalFSObjectStore(str(tmp_path)).exists(
        chunk_index_key("tensors"))


# ---------------------------------------------------------------------------
# sharded stores: cross-shard delta bases
# ---------------------------------------------------------------------------


def test_cross_shard_variant_survives_vacuum():
    obj, store = fresh(shards=4, cache_bytes=0)
    base = dense((16, 64, 64), seed=9)
    store.put(base, tensor_id="m")
    cat = store.catalog()
    vid = store.put_variant(base + 0.5, base_tid="m", tensor_id="m-variant-x")
    cat2 = store.catalog()
    assert cat2.entry(vid).shard != cat2.entry("m").shard
    assert any(a.get("deltaBase") for a in cat2.entry(vid).chunk_adds)
    store.vacuum()
    assert np.array_equal(store.get(vid), base + 0.5)
    store.delete("m")
    store.vacuum()  # base files must survive: the variant references them
    assert np.array_equal(store.get(vid), base + 0.5)
    store.delete(vid)
    store.vacuum()
    assert data_keys(obj) == []


# ---------------------------------------------------------------------------
# refcount invariants under arbitrary op interleavings (property test)
# ---------------------------------------------------------------------------

_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 3)),
        st.tuples(st.just("variant"), st.integers(0, 3)),
        st.tuples(st.just("delete"), st.integers(0, 20)),
        st.tuples(st.just("compact"), st.just(0)),
        st.tuples(st.just("vacuum"), st.just(0)),
    ),
    min_size=1, max_size=12)


def _run_interleaving(ops):
    obj, store = fresh(cache_bytes=0)
    model = {}  # tid -> expected array
    counter = [0]

    def tid_for(i):
        return f"t{i}"

    for op, arg in ops:
        if op == "put":
            x = dense((4, 16, 16), seed=arg)
            t = f"t{counter[0]}"
            counter[0] += 1
            store.put(x, tensor_id=t)
            model[t] = x
        elif op == "variant":
            if not model:
                continue
            base_tid = sorted(model)[arg % len(model)]
            x = model[base_tid] + (arg + 1) * 0.25
            t = f"t{counter[0]}"
            counter[0] += 1
            store.put_variant(x, base_tid=base_tid, tensor_id=t)
            model[t] = x
        elif op == "delete":
            if not model:
                continue
            t = sorted(model)[arg % len(model)]
            store.delete(t)
            del model[t]
        elif op == "compact":
            store.compact()
        elif op == "vacuum":
            store.vacuum()

    store.vacuum()
    # 1) nothing referenced was orphaned: every tensor reads back exactly
    for t, want in model.items():
        assert np.array_equal(store.get(t), want), t
    # 2) nothing unreferenced leaked: every surviving data object is in
    #    the retained-snapshot liveness closure
    closure = live_closure(store)
    leaked = set(data_keys(obj)) - closure
    assert not leaked, leaked


@settings(max_examples=25, deadline=None)
@given(ops=_OPS)
def test_refcount_invariants_hold_for_any_interleaving(ops):
    _run_interleaving(ops)


@pytest.mark.parametrize("ops", [
    # dedup pair, delete one, vacuum, delete the other, vacuum
    [("put", 0), ("put", 0), ("delete", 0), ("vacuum", 0),
     ("delete", 0), ("vacuum", 0)],
    # variant chain with base deleted under it, compact in the middle
    [("put", 1), ("variant", 0), ("variant", 1), ("delete", 0),
     ("compact", 0), ("vacuum", 0), ("delete", 0), ("vacuum", 0)],
    # churn: interleaved puts/variants/deletes with repeated maintenance
    [("put", 2), ("put", 3), ("variant", 1), ("vacuum", 0), ("delete", 1),
     ("variant", 0), ("compact", 0), ("vacuum", 0), ("delete", 2),
     ("vacuum", 0)],
])
def test_refcount_invariants_fixed_interleavings(ops):
    # deterministic fallback for environments without hypothesis: the
    # same invariant over handpicked adversarial sequences
    _run_interleaving(ops)
