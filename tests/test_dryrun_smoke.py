"""Dry-run smoke: one real lower+compile on a small host-device mesh.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(the main test process keeps its single real device, per the assignment).
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.launch import specs
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 4), ("data", "model"))
cell = specs.make_cell("whisper-tiny", "train_4k", mesh)
with mesh:
    jt = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings, donate_argnums=cell.donate)
    lowered = jt.lower(*cell.args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns one dict per device
        cost = cost[0] if cost else {}
    from repro.analysis import hlo_cost
    c = hlo_cost.analyze(compiled.as_text())
print(json.dumps({"flops": c.flops, "bytes": c.bytes,
                  "coll": c.total_coll_bytes,
                  "xla_flops": float(cost.get("flops", 0))}))
"""


@pytest.mark.slow
def test_dryrun_cell_compiles_on_small_mesh():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 1e9           # corrected flops counted
    assert rec["bytes"] > 1e8
    assert rec["flops"] > rec["xla_flops"]  # trip-count correction applied


def test_make_cell_specs_have_shardings():
    """Cheap structural check (no compile): specs build for every arch."""
    # uses the current (single-device) process only for tree structure
    import jax
    from repro.launch import specs
    from repro.models.config import list_archs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in list_archs():
        cell = specs.make_cell(arch, "train_4k", mesh)
        n_in = len(jax.tree.leaves(cell.in_shardings))
        assert n_in == len(jax.tree.leaves(cell.args)), arch
