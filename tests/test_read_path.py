"""Parallel read-path subsystem: executor, cache, hedging, makespan model."""

import time

import numpy as np
import pytest

from repro.core import DeltaTensorStore
from repro.lake import (DeltaLog, DeltaTable, InMemoryObjectStore, LatencyModel,
                        ReadExecutor)

from .test_encodings import sparse_tensor

LAYOUTS = ["ftsf", "coo", "csr", "csf", "bsgs"]


class CountingStore(InMemoryObjectStore):
    """Counts get/list calls; optionally stalls the first get of chosen keys."""

    def __init__(self, *args, stall_keys=(), stall_s=0.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.get_calls = 0
        self.got_keys = []
        self.list_calls = 0
        self.stall_keys = set(stall_keys)
        self.stall_s = stall_s

    def get(self, key):
        self.get_calls += 1
        self.got_keys.append(key)
        if key in self.stall_keys:
            self.stall_keys.discard(key)   # only the first attempt stalls
            time.sleep(self.stall_s)
        return super().get(key)

    def list(self, prefix=""):
        self.list_calls += 1
        return super().list(prefix)


# ---------------------------------------------------------------------------
# executor + cache
# ---------------------------------------------------------------------------

def test_cache_hit_miss_accounting():
    obj = CountingStore()
    io = ReadExecutor(max_workers=4, cache_bytes=1 << 20)
    obj.put("a", b"x" * 100)
    obj.put("b", b"y" * 100)

    assert io.fetch(obj, "a") == b"x" * 100
    assert (io.stats.cache_hits, io.stats.cache_misses) == (0, 1)
    assert io.fetch(obj, "a") == b"x" * 100          # warm
    assert (io.stats.cache_hits, io.stats.cache_misses) == (1, 1)
    assert obj.get_calls == 1                        # one real get only

    out = list(io.fetch_ordered(obj, ["a", "b", "a"]))
    assert out == [b"x" * 100, b"y" * 100, b"x" * 100]
    assert obj.get_calls == 2                        # only "b" was fetched


def test_cache_lru_eviction_bounded_by_bytes():
    from repro.lake.io import BlockCache
    c = BlockCache(capacity_bytes=250)
    c.put((0, "a"), b"x" * 100)
    c.put((0, "b"), b"y" * 100)
    c.get((0, "a"))                                  # refresh a
    c.put((0, "c"), b"z" * 100)                      # evicts b (LRU)
    assert c.get((0, "a")) is not None
    assert c.get((0, "b")) is None
    assert c.get((0, "c")) is not None
    assert c.nbytes <= 250
    c.put((0, "huge"), b"!" * 1000)                  # oversized: not admitted
    assert c.get((0, "huge")) is None


def test_cache_disabled_with_zero_capacity():
    obj = CountingStore()
    obj.put("a", b"data")
    io = ReadExecutor(cache_bytes=0)
    io.fetch(obj, "a")
    io.fetch(obj, "a")
    assert obj.get_calls == 2


def test_fetch_ordered_preserves_order_under_concurrency():
    obj = CountingStore()
    keys = [f"k{i:03d}" for i in range(50)]
    for i, k in enumerate(keys):
        obj.put(k, f"payload-{i}".encode())
    io = ReadExecutor(max_workers=8, cache_bytes=0)
    out = list(io.fetch_ordered(obj, keys))
    assert out == [f"payload-{i}".encode() for i in range(50)]


# ---------------------------------------------------------------------------
# parallel == serial, bit-for-bit, all codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
def test_parallel_get_slice_matches_serial_all_codecs(layout):
    x = sparse_tensor((24, 6, 5), density=0.15, seed=11)
    obj = InMemoryObjectStore()
    serial = DeltaTensorStore(obj, "s", io=ReadExecutor(max_workers=1, cache_bytes=0))
    tid = serial.put(x, layout=layout, target_file_bytes=2 << 10)
    parallel = DeltaTensorStore(obj, "s", io=ReadExecutor(max_workers=8, cache_bytes=0))

    np.testing.assert_array_equal(serial.get(tid), parallel.get(tid))
    for spec in ([(3, 9)], [(0, 24), (2, 5)], [(20, 24)]):
        a = serial.get_slice(tid, spec)
        b = parallel.get_slice(tid, spec)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_warm_cache_repeat_get_zero_object_store_requests():
    lm = LatencyModel()
    obj = InMemoryObjectStore(latency=lm)
    store = DeltaTensorStore(obj, "t", io=ReadExecutor(max_workers=4))
    x = np.arange(4 * 64, dtype=np.float32).reshape(4, 64)
    tid = store.put(x, layout="ftsf", target_file_bytes=1 << 10)
    v = store.version()

    np.testing.assert_array_equal(store.get(tid, version=v), x)  # cold: fills cache
    lm.reset()
    # version-pinned repeat read: snapshot cache + block cache -> fully local
    np.testing.assert_array_equal(store.get(tid, version=v), x)
    assert lm.requests == 0                            # zero gets/lists/heads
    assert lm.elapsed_s == 0.0

    # unpinned repeat read pays only freshness probes (HEADs), no data bytes
    lm.reset()
    np.testing.assert_array_equal(store.get(tid), x)
    assert lm.bytes_moved == 0
    assert 1 <= lm.requests <= 2


# ---------------------------------------------------------------------------
# hedging under an injected straggler
# ---------------------------------------------------------------------------

def test_hedged_fetch_beats_injected_straggler():
    obj = CountingStore(stall_keys={"slow"}, stall_s=1.5)
    obj.put("slow", b"payload")
    io = ReadExecutor(max_workers=4, cache_bytes=0, hedge_after_s=0.1)
    t0 = time.perf_counter()
    assert io.fetch(obj, "slow") == b"payload"
    assert time.perf_counter() - t0 < 1.2              # duplicate won
    assert io.stats.hedges_launched >= 1
    assert io.stats.hedges_won >= 1
    assert obj.get_calls >= 2


def test_scan_hedges_straggling_chunk_file():
    obj = CountingStore(stall_s=1.5)
    io = ReadExecutor(max_workers=4, cache_bytes=0, hedge_after_s=0.15)
    t = DeltaTable.create(obj, "tbl", io=io)
    for i in range(4):
        t.append({"v": np.full(8, i)})
    # make one data file a straggler on its first fetch
    victim = f"tbl/{t.files()[1]['path']}"
    obj.stall_keys = {victim}
    t0 = time.perf_counter()
    out = t.read_all()
    assert time.perf_counter() - t0 < 1.2
    assert sorted(set(out["v"])) == [0, 1, 2, 3]
    assert io.stats.hedges_launched >= 1


# ---------------------------------------------------------------------------
# LatencyModel makespan accounting
# ---------------------------------------------------------------------------

def test_latency_model_serial_unchanged():
    lm = LatencyModel(rtt_s=0.01, bandwidth_bps=1e9)
    for _ in range(8):
        lm.charge(0)
    assert lm.elapsed_s == pytest.approx(0.08)
    assert lm.serial_s == pytest.approx(0.08)


def _charge_concurrently(lm, n, nbytes):
    """Issue n charges from n distinct threads (rendezvous before charging),
    modeling genuinely concurrent requests."""
    import threading
    barrier = threading.Barrier(n)

    def one():
        barrier.wait()
        lm.charge(nbytes)

    ths = [threading.Thread(target=one) for _ in range(n)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()


def test_latency_model_makespan_width_gt_1():
    lm = LatencyModel(rtt_s=0.01, bandwidth_bps=1e9, parallelism=4)
    _charge_concurrently(lm, 8, 0)         # pure-RTT requests, 8 threads
    # 8 concurrent requests over 4 channels -> 2 rounds of RTT
    assert lm.elapsed_s == pytest.approx(0.02)
    assert lm.serial_s == pytest.approx(0.08)
    assert lm.requests == 8


def test_latency_model_same_thread_requests_stay_serial():
    # causality: one thread cannot overlap its own sequential requests,
    # whatever the configured channel width
    lm = LatencyModel(rtt_s=0.01, bandwidth_bps=1e9, parallelism=8)
    for _ in range(8):
        lm.charge(0)
    assert lm.elapsed_s == pytest.approx(0.08)


def test_latency_model_bandwidth_clamps_makespan():
    # RTTs parallelize; payload bytes still share the one 1 Gbps link
    lm = LatencyModel(rtt_s=0.0, bandwidth_bps=1e9, parallelism=8)
    _charge_concurrently(lm, 8, 125_000_000)   # 1 Gb each
    assert lm.elapsed_s == pytest.approx(8.0, rel=1e-6)   # link-bound
    lm2 = LatencyModel(rtt_s=0.010, bandwidth_bps=1e9, parallelism=8)
    _charge_concurrently(lm2, 8, 1_250)        # 10 us transfer, RTT-bound
    assert lm2.elapsed_s == pytest.approx(0.01001, rel=1e-3)


def test_latency_model_reset_clears_channels():
    lm = LatencyModel(parallelism=4)
    lm.charge(100)
    lm.reset()
    assert lm.elapsed_s == 0.0 and lm.serial_s == 0.0 and lm.requests == 0
    lm.charge(0)
    assert lm.elapsed_s == pytest.approx(lm.rtt_s)


# ---------------------------------------------------------------------------
# plan/fetch split
# ---------------------------------------------------------------------------

def test_plan_scan_prunes_without_fetching_data():
    obj = CountingStore()
    t = DeltaTable.create(obj, "tbl", io=ReadExecutor(cache_bytes=0))
    for lo in range(0, 40, 10):
        t.append({"chunk_index": np.arange(lo, lo + 10)})
    obj.got_keys.clear()
    plan = t.plan_scan(filters={"chunk_index": (12, 13)})
    assert len(plan) == 1                                # pruned to one file
    assert plan[0]["partitionValues"] == {}
    # planning reads log metadata only, never a data file
    assert all("_delta_log" in k for k in obj.got_keys)
    paths = {a["path"] for a in plan}
    batches = list(t.scan(filters={"chunk_index": (12, 13)}))
    assert len(batches) == 1
    np.testing.assert_array_equal(batches[0]["chunk_index"], [12, 13])
    assert paths <= {a["path"] for a in t.files()}


# ---------------------------------------------------------------------------
# compact keeps partitionValues (regression)
# ---------------------------------------------------------------------------

def test_compact_preserves_partition_values():
    obj = InMemoryObjectStore()
    t = DeltaTable.create(obj, "tbl", io=ReadExecutor(cache_bytes=0))
    for i in range(3):
        t.append({"v": np.full(4, i)}, partition_values={"tensor": "a"})
    for i in range(3):
        t.append({"v": np.full(4, 10 + i)}, partition_values={"tensor": "b"})
    t.compact()
    files = t.files()
    assert len(files) == 2                 # one merged file per partition
    pvs = sorted(f["partitionValues"]["tensor"] for f in files)
    assert pvs == ["a", "b"]
    # partition pruning still works after OPTIMIZE
    out = t.read_all(partition_filters={"tensor": "a"})
    assert sorted(set(out["v"])) == [0, 1, 2]
    out_b = t.read_all(partition_filters={"tensor": "b"})
    assert sorted(set(out_b["v"])) == [10, 11, 12]


def test_compact_after_tensor_put_keeps_store_readable():
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "t", io=ReadExecutor(cache_bytes=0))
    x = sparse_tensor((16, 8), density=0.3, seed=3)
    tid = store.put(x, layout="coo", target_file_bytes=1 << 10)
    store.table.compact()
    np.testing.assert_array_equal(store.get(tid), x)
    np.testing.assert_array_equal(store.get_slice(tid, [(4, 9)]), x[4:9])


# ---------------------------------------------------------------------------
# latest_version: checkpoint floor + probe forward, not full lists
# ---------------------------------------------------------------------------

def test_latest_version_stops_listing_on_hot_path():
    obj = CountingStore()
    log = DeltaLog(obj, "tbl")
    log.commit([{"metaData": {}}])
    lists_after_first = obj.list_calls
    for i in range(12):                    # crosses a checkpoint boundary
        log.commit([{"add": {"path": f"f{i}", "size": 1, "stats": {}}}])
    assert log.latest_version() == 12
    # the cold start listed at most once; hot commits only probe forward
    assert obj.list_calls == lists_after_first


def test_latest_version_fresh_client_uses_checkpoint_floor():
    obj = CountingStore()
    log = DeltaLog(obj, "tbl")
    for i in range(15):
        log.commit([{"add": {"path": f"f{i}", "size": 1, "stats": {}}}])
    fresh = DeltaLog(obj, "tbl")
    obj.list_calls = 0
    assert fresh.latest_version() == 14
    assert obj.list_calls == 0             # floor came from _last_checkpoint
    # and new external commits are still observed via probing
    log.commit([{"add": {"path": "f15", "size": 1, "stats": {}}}])
    assert fresh.latest_version() == 15


def test_bench_read_path_meets_acceptance():
    """bench_read_path: >=2x modeled speedup at width 8, cached repeat = 0 req."""
    import re
    import sys, os
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks import bench_read_path

    lines = bench_read_path.run(widths=(1, 8))
    joined = "\n".join(lines)
    m = re.search(r"read_path_speedup_w8,0\.0,get=([\d.]+)x slice=([\d.]+)x", joined)
    assert m, joined
    assert float(m.group(1)) >= 2.0
    assert float(m.group(2)) >= 2.0
    m = re.search(r"read_path_get_cached,[\d.]+,requests=(\d+)", joined)
    assert m and int(m.group(1)) == 0


def test_latest_version_empty_table():
    obj = CountingStore()
    log = DeltaLog(obj, "tbl")
    assert log.latest_version() == -1
    log.commit([{"metaData": {}}])
    assert log.latest_version() == 0
