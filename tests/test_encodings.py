"""Roundtrip + slice correctness for all five paper codecs."""

import numpy as np
import pytest
from ._hypothesis_compat import given, settings, st  # skips property tests if hypothesis is missing

from repro.core import SparseCOO, choose_layout, density, get_codec
from repro.core.encodings.base import normalize_slices

LAYOUTS = ["ftsf", "coo", "csr", "csc", "csf", "bsgs"]
RNG = np.random.default_rng(42)


def sparse_tensor(shape, density=0.05, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = np.zeros(shape, dtype=dtype)
    n = max(1, int(np.prod(shape) * density))
    flat = rng.choice(int(np.prod(shape)), size=n, replace=False)
    x.reshape(-1)[flat] = rng.standard_normal(n).astype(dtype) + 1.0
    return x


def groups_as_dicts(groups):
    return [g.columns for g in groups]


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("shape", [(7,), (5, 8), (4, 5, 6), (3, 4, 5, 2)])
def test_roundtrip_dense_input(layout, shape):
    x = sparse_tensor(shape, density=0.2, seed=hash(shape) % 2**31)
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(x))
    np.testing.assert_array_equal(codec.decode(groups), x)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_roundtrip_empty_tensor(layout):
    x = np.zeros((4, 5, 6), dtype=np.float64)
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(x))
    np.testing.assert_array_equal(codec.decode(groups), x)


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_roundtrip_dtypes(layout, dtype):
    x = sparse_tensor((6, 7, 8), density=0.1, dtype=dtype, seed=3)
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(x))
    out = codec.decode(groups)
    assert out.dtype == x.dtype
    np.testing.assert_array_equal(out, x)


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("sl", [
    [(0, 2)],
    [(1, 3)],
    [(2, 3), (0, 4)],
    [(0, 4), (1, 2), (3, 5)],
])
def test_decode_slice(layout, sl):
    shape = (4, 5, 6, 3)
    x = sparse_tensor(shape, density=0.15, seed=11)
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(x))
    spec = normalize_slices(shape, sl)
    expected = x[tuple(slice(lo, hi) for lo, hi in spec)]
    np.testing.assert_array_equal(codec.decode_slice(groups, spec), expected)


@pytest.mark.parametrize("layout", ["coo", "csr", "csf", "bsgs"])
def test_coo_input_path(layout):
    # sparse tensors arrive as COO (the paper's Uber dataset case)
    shape = (10, 6, 7)
    x = sparse_tensor(shape, density=0.03, seed=5)
    t = SparseCOO.from_dense(x)
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(t))
    np.testing.assert_array_equal(codec.decode(groups), x)
    back = codec.decode_coo(groups)
    np.testing.assert_array_equal(back.to_dense(), x)


def test_csf_duplicate_coordinates_are_summed():
    idx = np.array([[0, 1], [0, 1], [2, 3]])
    vals = np.array([1.0, 2.0, 5.0], dtype=np.float32)
    t = SparseCOO(idx, vals, (4, 4))
    codec = get_codec("csf")
    out = codec.decode(groups_as_dicts(codec.encode(t)))
    assert out[0, 1] == 3.0 and out[2, 3] == 5.0


def test_bsgs_block_shape_padding_and_custom_blocks():
    x = sparse_tensor((5, 7), density=0.3, seed=9)  # not divisible by block
    codec = get_codec("bsgs")
    groups = groups_as_dicts(codec.encode(x, block_shape=(2, 3)))
    np.testing.assert_array_equal(codec.decode(groups), x)
    # paper-style short block shape: (1x2) on a 3-d tensor pads leading dims
    y = sparse_tensor((3, 4, 2), density=0.4, seed=10)
    groups = groups_as_dicts(codec.encode(y, block_shape=(1, 2)))
    np.testing.assert_array_equal(codec.decode(groups), y)


def test_ftsf_chunk_dims_variants():
    x = RNG.standard_normal((4, 3, 8, 8)).astype(np.float32)
    codec = get_codec("ftsf")
    for cd in (0, 1, 2, 3, 4):
        groups = groups_as_dicts(codec.encode(x, chunk_dims=cd))
        np.testing.assert_array_equal(codec.decode(groups), x)


def test_csr_split_variants():
    x = sparse_tensor((4, 5, 6), density=0.1, seed=13)
    codec = get_codec("csr")
    for split in (1, 2):
        groups = groups_as_dicts(codec.encode(x, split=split))
        np.testing.assert_array_equal(codec.decode(groups), x)


def test_sparsity_policy():
    dense = np.ones((10, 10))
    sparse = np.zeros((10, 10))
    sparse[0, 0] = 1
    assert density(dense) == 1.0
    assert choose_layout(dense) == "ftsf"
    assert choose_layout(sparse) == "bsgs"
    assert choose_layout(sparse, prefer="csf") == "csf"


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------

@st.composite
def coo_tensors(draw):
    ndim = draw(st.integers(1, 4))
    shape = tuple(draw(st.integers(1, 6)) for _ in range(ndim))
    total = int(np.prod(shape))
    nnz = draw(st.integers(0, min(total, 20)))
    flat = draw(st.lists(st.integers(0, total - 1), min_size=nnz, max_size=nnz,
                         unique=True))
    vals = draw(st.lists(st.floats(-100, 100, allow_nan=False, width=32).filter(lambda v: v != 0.0),
                         min_size=nnz, max_size=nnz))
    idx = np.stack(np.unravel_index(np.asarray(flat, dtype=np.int64), shape), axis=1) \
        if nnz else np.zeros((0, ndim), np.int64)
    return SparseCOO(idx, np.asarray(vals, dtype=np.float32), shape)


@settings(max_examples=60, deadline=None)
@given(t=coo_tensors(), layout=st.sampled_from(LAYOUTS))
def test_property_roundtrip(t, layout):
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(t))
    np.testing.assert_array_equal(codec.decode(groups), t.to_dense())


@settings(max_examples=40, deadline=None)
@given(t=coo_tensors(), layout=st.sampled_from(LAYOUTS), data=st.data())
def test_property_slice_equals_numpy(t, layout, data):
    codec = get_codec(layout)
    groups = groups_as_dicts(codec.encode(t))
    spec = tuple(
        (lambda lo, hi: (lo, hi))(lo, data.draw(st.integers(lo + 1, s), label=f"hi{d}"))
        for d, s in enumerate(t.shape)
        for lo in [data.draw(st.integers(0, s - 1), label=f"lo{d}")]
    )
    dense = t.to_dense()
    expected = dense[tuple(slice(lo, hi) for lo, hi in spec)]
    np.testing.assert_array_equal(codec.decode_slice(groups, spec), expected)
