"""Pallas kernels (interpret mode) vs pure-jnp oracles, swept over shapes/dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest
from ._hypothesis_compat import given, settings, st  # skips property tests if hypothesis is missing

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)

SHAPES_BLOCKS = [
    ((16, 128), (8, 128)),
    ((32, 256), (8, 128)),
    ((24, 384), (8, 128)),
    ((64, 128), (16, 64)),
    ((9, 130), (4, 64)),     # ragged: wrapper pads
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def _mk(shape, dtype, seed=0):
    x = np.random.default_rng(seed).standard_normal(shape)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray((x * 10).astype(np.int32), dtype=dtype)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize("shape,bs", SHAPES_BLOCKS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_block_gather_matches_ref(shape, bs, dtype):
    x = _mk(shape, dtype, seed=1)
    gh = -(-shape[0] // bs[0])
    gw = -(-shape[1] // bs[1])
    n_blocks = gh * gw
    k = min(n_blocks, 5)
    ids = jnp.asarray(RNG.choice(n_blocks + 1, size=k, replace=False), jnp.int32)
    got = ops.block_gather(x, ids, bs, use_pallas=True)
    want = ops.block_gather(x, ids, bs, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), rtol=0, atol=0)


@pytest.mark.parametrize("shape,bs", SHAPES_BLOCKS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_scatter_matches_ref(shape, bs, dtype):
    base = _mk(shape, dtype, seed=2)
    gh = -(-shape[0] // bs[0])
    gw = -(-shape[1] // bs[1])
    n_blocks = gh * gw
    k = min(n_blocks, 4)
    ids = jnp.asarray(RNG.choice(n_blocks + 1, size=k, replace=False), jnp.int32)
    blocks = _mk((k,) + bs, dtype, seed=3)
    got = ops.block_scatter(base, ids, blocks, use_pallas=True)
    want = ops.block_scatter(base, ids, blocks, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), rtol=0, atol=0)


@pytest.mark.parametrize("g,b", [(8, 128), (16, 64), (3, 256), (40, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_norms_matches_ref(g, b, dtype):
    bv = _mk((g, b), dtype, seed=4)
    got = ops.block_norms(bv, use_pallas=True)
    want = ref.block_norms(bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("size,k", [(512, 17), (1024, 100), (640, 1), (130, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_coo_scatter_matches_ref(size, k, dtype):
    idx = jnp.asarray(RNG.choice(size, size=k, replace=False), jnp.int32)
    vals = _mk((k,), dtype, seed=5)
    got = ops.coo_scatter(idx, vals, size, use_pallas=True)
    want = ref.coo_scatter(idx, vals, size)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_coo_scatter_padding_indices_drop():
    idx = jnp.asarray([5, 700, 1000], jnp.int32)  # 700/1000 out of range
    vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    out = ops.coo_scatter(idx, vals, 512, use_pallas=True)
    assert float(out[5]) == 1.0
    assert float(jnp.sum(out)) == 1.0


def test_block_topk_matches_ref():
    x = _mk((32, 256), jnp.float32, seed=6)
    ids_p, blk_p = ops.block_topk(x, (8, 128), k=3, use_pallas=True)
    ids_r, blk_r = ref.block_topk(x, (8, 128), k=3)
    np.testing.assert_array_equal(np.sort(np.asarray(ids_p)), np.sort(np.asarray(ids_r)))
    np.testing.assert_allclose(np.asarray(blk_p)[np.argsort(np.asarray(ids_p))],
                               np.asarray(blk_r)[np.argsort(np.asarray(ids_r))])


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_property_gather_scatter_inverse(data):
    """scatter(zeros, ids, gather(x, ids)) keeps exactly the chosen tiles."""
    gh = data.draw(st.integers(1, 4))
    gw = data.draw(st.integers(1, 3))
    bs = (8, 128)
    shape = (gh * bs[0], gw * bs[1])
    x = _mk(shape, jnp.float32, seed=data.draw(st.integers(0, 99)))
    n_blocks = gh * gw
    k = data.draw(st.integers(1, n_blocks))
    ids = jnp.asarray(np.random.default_rng(k).choice(n_blocks, size=k, replace=False),
                      jnp.int32)
    tiles = ops.block_gather(x, ids, bs, use_pallas=True)
    back = ops.block_scatter(jnp.zeros_like(x), ids, tiles, use_pallas=True)
    mask = np.zeros(shape, bool)
    for i in np.asarray(ids):
        r, c = divmod(int(i), gw)
        mask[r * bs[0]:(r + 1) * bs[0], c * bs[1]:(c + 1) * bs[1]] = True
    np.testing.assert_array_equal(np.asarray(back)[mask], np.asarray(x)[mask])
    assert (np.asarray(back)[~mask] == 0).all()
