"""Chunk-blob compression subsystem: codecs, shuffle, and every layer above.

Covers the acceptance surface of the compression tentpole:

* codec x layout round-trips (dense/FTSF, COO, CSR, slice reads);
* byte-identical reads of pre-compression tables (frame passthrough);
* recompress-via-compact under a live lease (migration safety);
* shuffle∘unshuffle identity for all fixed-width dtypes (property test);
* decoded block cache, add-action metadata, storage_stats accounting,
  store-manifest defaults, and the gc CLI ``--recompress`` path.
"""

import json

import numpy as np
import pytest

from repro.core import DeltaTensorStore, SparseCOO
from repro.lake import (InMemoryObjectStore, LatencyModel, LocalFSObjectStore,
                        ReadExecutor, available_codecs, decode_frame,
                        encode_frame, frame_info, parse_compression,
                        register_compressor)
from repro.lake.compression import (CompressionSpec, UnknownCodecError,
                                    byte_shuffle, byte_unshuffle)
from repro.launch import gc as gc_cli

# an identity codec that never shrinks anything: the deterministic way to
# exercise the incompressible-fallback path through the full store stack
register_compressor("identity-test", lambda b: b, lambda b: b)

from ._hypothesis_compat import given, settings, st

RNG = np.random.default_rng(7)

# every codec this process can actually run (zstd/lz4 join when importable;
# the identity test codec is excluded — it exists to force the fallback)
CODECS = [c for c in available_codecs() if c != "identity-test"]
SPECS = [c for c in CODECS if c != "none"] + \
        [f"{c}+shuffle" for c in CODECS if c != "none"]


def compressible(shape, dtype=np.float32):
    """Low-mantissa-entropy floats: the workload compression should win on."""
    x = RNG.standard_normal(shape)
    return (np.round(x * 64) / 64).astype(dtype)


def fresh(compression=None, **kw):
    io = ReadExecutor(max_workers=4)
    store = DeltaTensorStore(InMemoryObjectStore(), "tensors", io=io,
                             compression=compression, **kw)
    return store


# ---------------------------------------------------------------------------
# spec parsing / registry
# ---------------------------------------------------------------------------


def test_parse_compression_specs():
    assert parse_compression(None) is None
    s = parse_compression("zlib+shuffle")
    assert s == CompressionSpec("zlib", True) and s.id == "zlib+shuffle"
    assert parse_compression("ZLIB").id == "zlib"
    assert not parse_compression("none").active
    assert parse_compression(CompressionSpec("lzma", False)).id == "lzma"
    with pytest.raises(UnknownCodecError):
        parse_compression("snappy")
    with pytest.raises(ValueError):
        parse_compression("zlib+zlib+shuffle")
    with pytest.raises(ValueError):
        parse_compression(42)
    with pytest.raises(ValueError):
        # shuffle-without-codec would disable legacy block compression
        # while compressing nothing: a silent space regression
        parse_compression("none+shuffle")


def test_available_codecs_stdlib_floor():
    assert {"none", "zlib", "lzma"} <= set(CODECS)


# ---------------------------------------------------------------------------
# frame format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", SPECS)
def test_frame_roundtrip(spec):
    raw = compressible(4096).tobytes()
    frame, codec_id = encode_frame(raw, parse_compression(spec), itemsize=4)
    assert decode_frame(frame) == raw
    info = frame_info(frame)
    assert info["raw_size"] == len(raw)
    assert codec_id == spec  # compressible payload: no fallback


def test_frame_passthrough_unframed():
    for blob in (b"", b"PQL1junk", b'{"json": true}', bytes(100)):
        assert decode_frame(blob) == blob
        assert frame_info(blob) is None


def test_frame_incompressible_falls_back_to_raw_unframed():
    raw = RNG.integers(0, 256, 1 << 16, dtype=np.uint8).tobytes()
    stored, codec_id = encode_frame(raw, parse_compression("zlib+shuffle"),
                                    itemsize=4)
    assert codec_id == "none"
    assert stored == raw  # zero overhead: raw bytes, no frame
    assert decode_frame(stored) == raw


def test_fallback_put_records_request_only():
    """A put whose frame cannot pay for itself stores raw unframed files
    with ratio exactly 1.0 and only a codecRequested marker (which keeps
    recompress idempotent). The registered identity codec triggers this
    path deterministically: it never shrinks anything."""
    store = fresh(compression="identity-test")
    x = RNG.integers(0, 256, (8, 64, 64), dtype=np.uint8)
    store.put(x, layout="ftsf", tensor_id="t")
    adds = store.catalog().entry("t").chunk_adds
    assert all("codec" not in a and "rawSize" not in a for a in adds)
    assert all(a.get("codecRequested") == "identity-test" for a in adds)
    st = store.storage_stats()
    assert st["by_codec"]["none"]["ratio"] == 1.0  # exact, never < 1
    assert np.array_equal(store.get("t"), x)
    # idempotent: nothing to rewrite under the same requested codec
    assert not store.compact(recompress="identity-test")[0]


def test_storage_never_inflates_past_raw():
    """The fallback guarantee at the store level: whatever the data,
    stored physical bytes never exceed logical bytes per file."""
    store = fresh(compression="zlib+shuffle")
    store.put(RNG.integers(0, 256, (8, 64, 64), dtype=np.uint8),
              layout="ftsf", tensor_id="noise")
    store.put(compressible((8, 64, 64)), layout="ftsf", tensor_id="smooth")
    for tid in ("noise", "smooth"):
        for add in store.catalog().entry(tid).chunk_adds:
            assert int(add.get("rawSize", add["size"])) >= int(add["size"])
    assert store.storage_stats()["ratio"] >= 1.0


# ---------------------------------------------------------------------------
# byte shuffle
# ---------------------------------------------------------------------------

FIXED_WIDTH_DTYPES = ["int8", "uint8", "int16", "uint16", "int32", "uint32",
                      "int64", "uint64", "float16", "float32", "float64",
                      "complex64", "complex128", "bool"]


@pytest.mark.parametrize("dtype", FIXED_WIDTH_DTYPES)
def test_shuffle_identity_every_fixed_width_dtype(dtype):
    it = np.dtype(dtype).itemsize
    for n in (0, 1, it - 1, it, 7 * it + 3, 4096):
        raw = RNG.integers(0, 256, max(n, 0), dtype=np.uint8).tobytes()
        assert byte_unshuffle(byte_shuffle(raw, it), it) == raw


@settings(max_examples=200, deadline=None)
@given(st.binary(min_size=0, max_size=4096),
       st.integers(min_value=1, max_value=16))
def test_shuffle_unshuffle_identity_property(raw, itemsize):
    """shuffle∘unshuffle is the identity for any buffer and item width."""
    assert byte_unshuffle(byte_shuffle(raw, itemsize), itemsize) == raw


def test_shuffle_groups_bytes():
    # [0,1,2,3]*k shuffled at itemsize 4 puts all the 0s first: runs a
    # byte codec can crush — the reason the filter exists
    raw = bytes(range(4)) * 64
    shuf = byte_shuffle(raw, 4)
    assert shuf[:64] == bytes(64 * [0])
    assert shuf[64:128] == bytes(64 * [1])


# ---------------------------------------------------------------------------
# codec x layout round trips through the store
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", SPECS)
def test_dense_ftsf_roundtrip(spec):
    store = fresh(compression=spec)
    x = compressible((16, 32, 32))
    store.put(x, layout="ftsf", tensor_id="t")
    assert np.array_equal(store.get("t"), x)
    # slice read through codec pushdown on compressed chunk files
    assert np.array_equal(store.get_slice("t", [(3, 9)]), x[3:9])
    with store.open("t") as ref:
        assert np.array_equal(ref[2:5, 1:7], x[2:5, 1:7])


@pytest.mark.parametrize("spec", ["zlib+shuffle", "lzma"])
def test_sparse_layouts_roundtrip(spec):
    store = fresh(compression=spec)
    dense = np.zeros((64, 64), dtype=np.float32)
    dense[RNG.integers(0, 64, 200), RNG.integers(0, 64, 200)] = \
        RNG.standard_normal(200).astype(np.float32)
    store.put(dense, layout="coo", tensor_id="c")
    store.put(dense, layout="csr", tensor_id="r")
    assert np.array_equal(store.get("c"), dense)
    assert np.array_equal(store.get("r"), dense)
    coo = store.get_coo("c")
    assert isinstance(coo, SparseCOO)
    assert np.array_equal(coo.to_dense(), dense)


def test_int_dtype_roundtrip():
    store = fresh(compression="zlib+shuffle")
    x = RNG.integers(-1000, 1000, (32, 128), dtype=np.int64)
    store.put(x, layout="ftsf", tensor_id="i")
    got = store.get("i")
    assert got.dtype == np.int64 and np.array_equal(got, x)


def test_per_put_override_beats_store_default():
    store = fresh(compression="zlib+shuffle")
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="default")
    store.put(x, layout="ftsf", tensor_id="raw", compression="none")
    by = store.storage_stats()["by_codec"]
    # headers are always raw; the override kept "raw"'s chunks raw too
    raw_chunk = [a for a in store.catalog().entry("raw").chunk_adds]
    assert all("codec" not in a for a in raw_chunk)
    assert by["zlib+shuffle"]["files"] >= 1


# ---------------------------------------------------------------------------
# back-compat: pre-compression tables
# ---------------------------------------------------------------------------


def test_uncompressed_layout_byte_identical():
    """A store without compression writes the exact legacy byte layout."""
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    x = compressible((8, 32))
    store.put(x, layout="ftsf", tensor_id="t")
    for key in obj.list("tensors/"):
        if key.endswith(".pql"):
            assert obj.get(key)[:4] == b"PQL1"  # no frame, plain parq-lite
    adds = store.catalog().entry("t").header_adds + \
        store.catalog().entry("t").chunk_adds
    assert all("codec" not in a and "rawSize" not in a for a in adds)
    # no manifest either: byte-compatible with pre-sharding tables
    assert not obj.exists("tensors/_store_manifest.json")


def test_precompression_table_reads_back_identically():
    """Tables written by a codec-less client read fine from any client."""
    obj = InMemoryObjectStore()
    old = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    x = compressible((8, 32))
    old.put(x, layout="ftsf", tensor_id="t")
    # a new client configured with a default codec changes nothing about
    # how existing files read back (codec "none" implied per file)
    new = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                           compression="zlib+shuffle")
    assert np.array_equal(new.get("t"), x)
    stats = new.storage_stats()
    assert set(stats["by_codec"]) == {"none"}
    assert stats["ratio"] == 1.0


def test_mixed_codec_store_reads_all():
    store = fresh(compression=None)
    xs = {}
    for i, spec in enumerate([None, "zlib", "zlib+shuffle", "lzma"]):
        x = compressible((4, 32, 32))
        xs[f"t{i}"] = x
        store.put(x, layout="ftsf", tensor_id=f"t{i}", compression=spec)
    for tid, x in xs.items():
        assert np.array_equal(store.get(tid), x)


# ---------------------------------------------------------------------------
# add-action metadata + storage_stats
# ---------------------------------------------------------------------------


def test_add_action_records_codec_and_sizes():
    store = fresh(compression="zlib+shuffle")
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    entry = store.catalog().entry("t")
    assert entry.header_adds and "codec" not in entry.header_adds[0]
    for add in entry.chunk_adds:
        assert add["codec"] == "zlib+shuffle"
        assert add["itemsize"] == 4
        assert add["rawSize"] > add["size"]  # it actually compressed
    # physical tensor bytes (what refs report) shrink accordingly
    with store.open("t") as ref:
        assert ref.nbytes < x.nbytes


def test_storage_stats_accounting():
    store = fresh(compression="zlib+shuffle")
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    st = store.storage_stats()
    assert st["tensors"] == 1
    assert st["physical_bytes"] < st["logical_bytes"]
    assert st["ratio"] > 1.5
    assert st["compression"] == "zlib+shuffle"
    total = sum(r["physical_bytes"] for r in st["by_codec"].values())
    assert total == st["physical_bytes"]
    # physical matches what the object store actually holds for data files
    empty = fresh().storage_stats()
    assert empty["ratio"] == 1.0 and empty["files"] == 0


# ---------------------------------------------------------------------------
# read path: the cache stores decoded blocks, the wire moves compressed
# ---------------------------------------------------------------------------


def test_block_cache_stores_decoded_blocks():
    lm = LatencyModel(virtual_clock=True)
    obj = InMemoryObjectStore(latency=lm)
    io = ReadExecutor(max_workers=2)
    store = DeltaTensorStore(obj, "tensors", io=io,
                             compression="zlib+shuffle")
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    io.stats.reset()
    assert np.array_equal(store.get("t"), x)
    first = io.stats.frames_decoded
    assert first >= 1
    assert io.stats.frame_bytes_wire < io.stats.frame_bytes_decoded
    assert np.array_equal(store.get("t"), x)  # warm: cache hit, no decode
    assert io.stats.frames_decoded == first
    assert io.stats.cache_hits >= 1


def test_wire_charges_compressed_bytes():
    def read_bytes(compression):
        lm = LatencyModel(virtual_clock=True)
        obj = InMemoryObjectStore(latency=lm)
        io = ReadExecutor(max_workers=2, cache_bytes=0)
        store = DeltaTensorStore(obj, "tensors", io=io,
                                 compression=compression)
        x = compressible((16, 64, 64))
        store.put(x, layout="ftsf", tensor_id="t")
        lm.reset()
        assert np.array_equal(store.get("t"), x)
        return x.nbytes, lm.bytes_moved

    logical, wire = read_bytes("zlib+shuffle")
    # the full read moved less than half the tensor's raw bytes over the
    # modeled wire, and strictly less than the legacy layout moves (which
    # already block-zlibs opportunistically — shuffle beats it further)
    assert wire < logical / 2
    assert wire < read_bytes(None)[1]


# ---------------------------------------------------------------------------
# maintenance: compact preserves codecs, recompress migrates, leases hold
# ---------------------------------------------------------------------------


def test_compact_preserves_codec():
    store = fresh(compression="zlib+shuffle")
    x = compressible((8, 64, 64))
    # two files per partition group: put in halves via overwrite-free tids
    store.put(x, layout="ftsf", tensor_id="t", target_file_bytes=x.nbytes // 3)
    before = store.storage_stats()
    res = store.compact()
    assert res[0].files_compacted > 0
    after = store.storage_stats()
    assert np.array_equal(store.get("t"), x)
    for add in store.catalog().entry("t").chunk_adds:
        assert add["codec"] == "zlib+shuffle"
    # compacting must not inflate the store back toward raw bytes
    assert after["physical_bytes"] <= before["physical_bytes"] * 1.1


def test_recompress_under_live_lease():
    """The migration path: recompress while a pinned ref reads old bytes."""
    store = fresh(compression=None)
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    raw_bytes = store.storage_stats()["physical_bytes"]

    ref = store.open("t")  # leases the raw generation
    res = store.compact(recompress="zlib+shuffle")
    assert res[0] and res[0].files_recompressed > 0
    # pinned ref still reads its snapshot byte-identically
    assert np.array_equal(ref.read(), x)
    # new snapshot is compressed: smaller than the legacy layout (which
    # already block-zlibs what it can) and >=2x under the logical bytes
    migrated = store.storage_stats()
    assert "zlib+shuffle" in migrated["by_codec"]
    assert migrated["physical_bytes"] < raw_bytes
    assert migrated["ratio"] > 2.0
    assert np.array_equal(store.get("t"), x)

    # vacuum spares the leased raw generation, then reclaims it on release
    spared = store.vacuum(keep_versions=1)
    assert np.array_equal(ref.read(), x)
    ref.close()
    freed = store.vacuum(keep_versions=1)
    assert sum(r.bytes_reclaimed for r in freed) > 0
    assert np.array_equal(store.get("t"), x)
    assert sum(r.bytes_reclaimed for r in spared + freed) > 0


def test_recompress_is_idempotent():
    store = fresh(compression=None)
    store.put(compressible((8, 64, 64)), layout="ftsf", tensor_id="t")
    assert store.compact(recompress="zlib+shuffle")[0]
    v = store.version()
    # second pass: every file already carries the target codec -> no-op,
    # commit-free (maintenance crons must not grow the log doing nothing)
    assert not store.compact(recompress="zlib+shuffle")[0]
    assert store.version() == v


def test_recompress_idempotent_for_one_byte_dtypes():
    """itemsize-1 tensors skip shuffle, so the actual codec id drops the
    '+shuffle' suffix — codecRequested must still match the target or a
    recompress cron would rewrite (and grow the log) forever."""
    store = fresh(compression=None)
    x = np.tile(np.arange(64, dtype=np.uint8), (8, 64, 1))  # compressible
    store.put(x, layout="ftsf", tensor_id="mask")
    assert store.compact(recompress="zlib+shuffle")[0]
    v = store.version()
    for add in store.catalog().entry("mask").chunk_adds:
        assert add["codec"] == "zlib"  # shuffle skipped: itemsize 1
        assert add["codecRequested"] == "zlib+shuffle"
    for _ in range(3):  # repeated cron runs: commit-free no-ops
        assert not store.compact(recompress="zlib+shuffle")[0]
    assert store.version() == v
    assert np.array_equal(store.get("mask"), x)


def test_recompress_sharded_store():
    io = ReadExecutor(max_workers=4)
    store = DeltaTensorStore(InMemoryObjectStore(), "tensors", io=io,
                             shards=3)
    xs = {f"t{i}": compressible((4, 32, 32)) for i in range(6)}
    with store.batch() as b:
        for tid, x in xs.items():
            b.put(x, layout="ftsf", tensor_id=tid)
    results = store.compact(recompress="zlib+shuffle")
    assert sum(r.files_recompressed for r in results) >= 6
    for tid, x in xs.items():
        assert np.array_equal(store.get(tid), x)
    assert store.storage_stats()["ratio"] > 1.5


# ---------------------------------------------------------------------------
# manifest default + unknown-codec failure mode
# ---------------------------------------------------------------------------


def test_manifest_records_default_and_later_clients_inherit():
    obj = InMemoryObjectStore()
    DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                     compression="zlib+shuffle")
    manifest = json.loads(obj.get("tensors/_store_manifest.json"))
    assert manifest["compression"] == "zlib+shuffle"
    # a later client with no explicit arg inherits the recorded default
    client = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    assert client.compression is not None
    assert client.compression.id == "zlib+shuffle"
    x = compressible((4, 32, 32))
    client.put(x, layout="ftsf", tensor_id="t")
    assert client.storage_stats()["by_codec"]["zlib+shuffle"]["files"] >= 1


def test_sharded_manifest_records_compression():
    obj = InMemoryObjectStore()
    DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                     shards=2, compression="lzma")
    manifest = json.loads(obj.get("tensors/_store_manifest.json"))
    assert manifest["shards"] == 2 and manifest["compression"] == "lzma"
    client = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    assert client.compression.id == "lzma"


def test_opening_existing_table_does_not_write_manifest():
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    store.put(compressible((4, 16)), layout="ftsf", tensor_id="t")
    # opening with a codec default must not mutate a pre-existing store
    DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                     compression="zlib+shuffle")
    assert not obj.exists("tensors/_store_manifest.json")


def test_manifest_with_unavailable_codec_still_opens_for_reads():
    """A manifest naming a codec this process lacks (e.g. zstd on a
    stdlib-only client) must not block opening: reads work on whatever
    frames ARE decodable; this client just degrades to raw writes."""
    obj = InMemoryObjectStore()
    store = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                             compression="zlib+shuffle")
    x = compressible((4, 32, 32))
    store.put(x, layout="ftsf", tensor_id="t")
    manifest = json.loads(obj.get("tensors/_store_manifest.json"))
    manifest["compression"] = "imaginary-codec+shuffle"
    obj.delete("tensors/_store_manifest.json")
    obj.put("tensors/_store_manifest.json",
            json.dumps(manifest).encode("utf-8"))
    client = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    assert client.compression is None  # degraded, not dead
    assert np.array_equal(client.get("t"), x)  # zlib frames still decode
    client.put(x, layout="ftsf", tensor_id="u")  # writes land raw
    assert all("codec" not in a
               for a in client.catalog().entry("u").chunk_adds)
    # an EXPLICIT unknown codec still fails fast
    with pytest.raises(UnknownCodecError):
        DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2),
                         compression="imaginary-codec")


def test_unknown_codec_fails_fast():
    with pytest.raises(UnknownCodecError):
        fresh(compression="snappy+shuffle")
    store = fresh()
    with pytest.raises(UnknownCodecError):
        store.put(np.ones(4), layout="ftsf", tensor_id="t",
                  compression="brotli")
    assert "t" not in store.catalog()  # nothing staged, nothing leaked


# ---------------------------------------------------------------------------
# gc CLI migration path
# ---------------------------------------------------------------------------


def test_gc_cli_recompress_roundtrip(tmp_path, capsys):
    obj = LocalFSObjectStore(str(tmp_path))
    store = DeltaTensorStore(obj, "tensors", io=ReadExecutor(max_workers=2))
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    raw_bytes = store.storage_stats()["physical_bytes"]

    rc = gc_cli.main(["--dir", str(tmp_path), "--root", "tensors",
                      "--recompress", "zlib+shuffle", "--vacuum",
                      "--keep-versions", "1"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "recompressed" in out and "storage after recompress" in out

    reopened = DeltaTensorStore(obj, "tensors",
                                io=ReadExecutor(max_workers=2))
    assert np.array_equal(reopened.get("t"), x)
    stats = reopened.storage_stats()
    assert stats["physical_bytes"] < raw_bytes
    assert stats["ratio"] > 2.0


# ---------------------------------------------------------------------------
# per-codec compression levels ("<codec>:<level>[+shuffle]")
# ---------------------------------------------------------------------------


def test_parse_compression_levels():
    s = parse_compression("zlib:9+shuffle")
    assert (s.codec, s.level, s.shuffle) == ("zlib", 9, True)
    assert s.id == "zlib:9+shuffle"
    assert parse_compression(s.id) == s          # id round-trips
    assert parse_compression("zlib").level is None
    for bad in ("zlib:", ":9", "zlib:x", "zlib:99", "none:3"):
        with pytest.raises(ValueError):
            parse_compression(bad)


def test_frame_header_records_level():
    raw = compressible((64, 64)).tobytes()
    frame, codec_id = encode_frame(raw, parse_compression("zlib:9"))
    assert codec_id == "zlib:9"
    info = frame_info(frame)
    assert info["codec"] == "zlib" and info["level"] == 9
    assert decode_frame(frame) == raw
    # level-less frames keep the old header shape (no "level" key)
    frame0, _ = encode_frame(raw, parse_compression("zlib"))
    assert "level" not in frame_info(frame0)
    assert decode_frame(frame0) == raw


def test_level_tradeoff_decodes_identically():
    raw = compressible((128, 256)).tobytes()
    lo, _ = encode_frame(raw, parse_compression("zlib:1"))
    hi, _ = encode_frame(raw, parse_compression("zlib:9"))
    assert decode_frame(lo) == raw == decode_frame(hi)
    assert len(hi) <= len(lo)  # more effort never stores more (zlib)


def test_recompress_across_levels_is_idempotent():
    store = fresh(compression="zlib:1+shuffle")
    x = compressible((8, 64, 64))
    store.put(x, layout="ftsf", tensor_id="t")
    res = store.compact(recompress="zlib:9+shuffle")
    assert sum(r.files_recompressed for r in res) > 0
    assert np.array_equal(store.get("t"), x)
    # the add-actions now record the levelled codec id: a second pass
    # under the same spec must be a commit-free no-op
    again = store.compact(recompress="zlib:9+shuffle")
    assert sum(r.files_recompressed for r in again) == 0
    assert all(r.version is None for r in again)
    assert np.array_equal(store.get("t"), x)


def test_level_store_default_roundtrip():
    store = fresh(compression="zlib:9+shuffle")
    x = compressible((4, 32, 32))
    store.put(x, layout="ftsf", tensor_id="t")
    codecs = store.storage_stats()["by_codec"]
    assert any(c.startswith("zlib:9") for c in codecs), codecs
    assert np.array_equal(store.get("t"), x)
