"""Loop-aware HLO cost model: trip-count correction vs known ground truth."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.dot(c, wi), None
        return jax.lax.scan(body, x, w)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((16, 32), jnp.float32),
                    jax.ShapeDtypeStruct((10, 32, 32), jnp.float32))
    c = hlo_cost.analyze(comp.as_text())
    expected = 10 * 2 * 16 * 32 * 32
    assert abs(c.flops - expected) / expected < 0.01
    # XLA's own analysis undercounts by the trip count
    xla_cost = comp.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # older jax: one dict per device
        xla_cost = xla_cost[0] if xla_cost else {}
    xla = xla_cost.get("flops", 0)
    assert xla < expected / 5


def test_plain_dot_matches_xla():
    def f(a, b):
        return a @ b

    comp = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                    jax.ShapeDtypeStruct((128, 256), jnp.float32))
    c = hlo_cost.analyze(comp.as_text())
    expected = 2 * 64 * 128 * 256
    assert abs(c.flops - expected) / expected < 0.01
    assert c.bytes >= (64 * 128 + 128 * 256 + 64 * 256) * 4


def test_nested_scan():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        return jax.lax.scan(outer, x, w)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((8, 16), jnp.float32),
                    jax.ShapeDtypeStruct((5, 16, 16), jnp.float32))
    c = hlo_cost.analyze(comp.as_text())
    expected = 5 * 3 * 2 * 8 * 16 * 16
    assert abs(c.flops - expected) / expected < 0.05
