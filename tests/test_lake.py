
import numpy as np
import pytest
from ._hypothesis_compat import given, settings, st  # skips property tests if hypothesis is missing

from repro.lake import (CommitConflict, DeltaLog, DeltaTable, InMemoryObjectStore,
                        LatencyModel, LocalFSObjectStore, ObjectNotFoundError,
                        PutIfAbsentError, columnar)


# ---------------------------------------------------------------------------
# columnar (parq-lite)
# ---------------------------------------------------------------------------

def test_columnar_roundtrip_mixed():
    cols = {
        "id": ["t1"] * 3 + ["t2"] * 2,
        "chunk_index": np.arange(5, dtype=np.int64),
        "payload": [b"a" * 10, b"", b"xyz", b"\x00\x01", b"q"],
        "dims": [np.array([4, 3]), np.array([4, 3]), np.array([4, 3]),
                 np.array([7]), np.array([7])],
        "score": np.linspace(0, 1, 5).astype(np.float32),
    }
    data, stats = columnar.write_table(cols)
    out = columnar.read_table(data)
    assert list(out["id"]) == cols["id"]
    np.testing.assert_array_equal(out["chunk_index"], cols["chunk_index"])
    assert out["payload"] == cols["payload"]
    for a, b in zip(out["dims"], cols["dims"]):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(out["score"], cols["score"], rtol=0)
    assert stats["column_stats"]["chunk_index"] == {"min": 0, "max": 4}
    assert columnar.num_rows(data) == 5


def test_columnar_projection():
    cols = {"a": np.arange(10), "b": np.arange(10.0)}
    data, _ = columnar.write_table(cols)
    out = columnar.read_table(data, columns=["a"])
    assert set(out) == {"a"}
    with pytest.raises(KeyError):
        columnar.read_table(data, columns=["missing"])


def test_columnar_dictionary_compresses_repeats():
    # the paper's point: repeated metadata columns compress to ~nothing
    rep = {"meta": np.full(100_000, 7, dtype=np.int64)}
    uniq = {"meta": np.arange(100_000, dtype=np.int64)}
    rep_data, _ = columnar.write_table(rep)
    uniq_data, _ = columnar.write_table(uniq)
    assert len(rep_data) < len(uniq_data) / 100


@settings(max_examples=50, deadline=None)
@given(
    vals=st.lists(st.integers(-2**31, 2**31 - 1), min_size=0, max_size=200),
    dtype=st.sampled_from(["int32", "int64", "float32", "float64"]),
)
def test_columnar_array_roundtrip_property(vals, dtype):
    arr = np.asarray(vals, dtype=dtype)
    data, _ = columnar.write_table({"v": arr, "pad": np.zeros(len(arr))}) if len(arr) else (None, None)
    if data is None:
        return
    out = columnar.read_table(data)["v"]
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


@settings(max_examples=30, deadline=None)
@given(blobs=st.lists(st.binary(max_size=64), min_size=1, max_size=40))
def test_columnar_bytes_roundtrip_property(blobs):
    data, _ = columnar.write_table({"b": blobs})
    assert columnar.read_table(data)["b"] == blobs


# ---------------------------------------------------------------------------
# object stores
# ---------------------------------------------------------------------------

@pytest.fixture(params=["mem", "fs"])
def store(request, tmp_path):
    if request.param == "mem":
        return InMemoryObjectStore()
    return LocalFSObjectStore(str(tmp_path / "store"))


def test_object_store_basics(store):
    store.put("a/b/c", b"hello")
    assert store.get("a/b/c") == b"hello"
    assert store.head("a/b/c") == 5
    assert list(store.list("a/")) == ["a/b/c"]
    store.put("a/b/c", b"hi2")  # overwrite allowed without if_absent
    assert store.get("a/b/c") == b"hi2"
    with pytest.raises(PutIfAbsentError):
        store.put("a/b/c", b"x", if_absent=True)
    store.delete("a/b/c")
    with pytest.raises(ObjectNotFoundError):
        store.get("a/b/c")


def test_latency_model_accounting():
    lm = LatencyModel(rtt_s=0.01, bandwidth_bps=1e9, virtual_clock=True)
    s = InMemoryObjectStore(latency=lm)
    s.put("k", b"x" * 125_000_000)  # 1 Gb -> 1 s at 1 Gbps
    assert lm.elapsed_s == pytest.approx(1.01, rel=1e-6)
    lm.reset()
    s.get("k")
    assert lm.requests == 1 and lm.bytes_moved == 125_000_000


# ---------------------------------------------------------------------------
# delta log
# ---------------------------------------------------------------------------

def test_log_commit_snapshot_time_travel(store):
    log = DeltaLog(store, "tbl")
    v0 = log.commit([{"metaData": {"name": "t"}}])
    v1 = log.commit([{"add": {"path": "f1", "size": 10, "stats": {}}}])
    v2 = log.commit([{"add": {"path": "f2", "size": 20, "stats": {}}}])
    v3 = log.commit([{"remove": {"path": "f1"}}])
    assert (v0, v1, v2, v3) == (0, 1, 2, 3)
    snap = log.snapshot()
    assert set(snap.files) == {"f2"}
    # time travel
    assert set(log.snapshot(2).files) == {"f1", "f2"}
    assert set(log.snapshot(1).files) == {"f1"}
    assert log.snapshot().metadata == {"name": "t"}


def test_log_checkpoint_replay(store):
    log = DeltaLog(store, "tbl")
    for i in range(25):
        log.commit([{"add": {"path": f"f{i}", "size": i, "stats": {}}}])
    snap = log.snapshot()
    assert len(snap.files) == 25
    # a checkpoint file must exist (interval 10)
    assert any(k.endswith(".checkpoint.json") for k in store.list("tbl/_delta_log/"))
    # time travel before the checkpoint still works
    assert len(log.snapshot(4).files) == 5


def test_log_expected_version_fencing(store):
    log = DeltaLog(store, "tbl")
    log.commit([{"metaData": {}}])
    with pytest.raises(CommitConflict):
        log.commit([{"add": {"path": "x", "size": 1, "stats": {}}}], expected_version=5)


def test_log_crash_before_commit_invisible():
    store = InMemoryObjectStore()
    t = DeltaTable.create(store, "tbl")
    t.append({"a": np.arange(3)})
    # simulate a writer that uploads a data file but dies before commit
    add = t.append({"a": np.arange(7)}, commit=False)
    assert add["path"]  # the orphan exists in the store...
    batches = list(t.scan())
    assert len(batches) == 1 and len(batches[0]["a"]) == 3  # ...but is invisible
    # vacuum removes the orphan
    res = t.vacuum()
    assert res.files_deleted == 1 and res.bytes_reclaimed > 0


def test_log_latest_version_cache_refreshes_on_miss():
    # regression: a DeltaLog whose probe-forward latest cache went stale
    # under an EXTERNAL writer must refresh on a version miss instead of
    # raising ValueError for a commit that exists
    from repro.lake import FaultInjectingObjectStore, FaultRule

    inner = InMemoryObjectStore()
    faulty = FaultInjectingObjectStore(inner)
    writer = DeltaLog(inner, "tbl")
    writer.commit([{"metaData": {}}])
    reader = DeltaLog(faulty, "tbl")
    assert reader.latest_version() == 0
    v1 = writer.commit([{"add": {"path": "f", "size": 1, "stats": {}}}])
    # eventual consistency: the reader's forward head probes 404, so its
    # cached latest stays stale at 0...
    faulty.add_rule(FaultRule(op="head", key="_delta_log",
                              action="notfound", count=2))
    assert reader.latest_version() == 0
    # ...but an explicit request for the missed version invalidates the
    # cache and replays the commit
    snap = reader.snapshot(v1)
    assert snap.version == v1 and set(snap.files) == {"f"}
    assert reader.latest_version() >= v1


# ---------------------------------------------------------------------------
# delta table
# ---------------------------------------------------------------------------

def test_table_append_scan_skipping():
    store = InMemoryObjectStore(latency=LatencyModel())
    t = DeltaTable.create(store, "tensors/t1")
    rng = np.random.default_rng(0)
    for lo in range(0, 100, 10):
        t.append({"chunk_index": np.arange(lo, lo + 10),
                  "val": np.full(10, lo),
                  "payload": [rng.bytes(4096) for _ in range(10)]})
    assert t.version() == 10  # create + 10 appends

    store.latency.reset()
    full = t.read_all()
    full_bytes = store.latency.bytes_moved
    assert len(full["chunk_index"]) == 100

    store.latency.reset()
    sl = t.read_all(filters={"chunk_index": (42, 44)})
    slice_bytes = store.latency.bytes_moved
    np.testing.assert_array_equal(sl["chunk_index"], [42, 43, 44])
    # data skipping: the slice read touched ~1 file out of 10
    assert slice_bytes < full_bytes / 5


def test_table_time_travel_and_compact():
    store = InMemoryObjectStore()
    t = DeltaTable.create(store, "tbl")
    t.append({"x": np.arange(4)})
    v_before = t.version()
    t.append({"x": np.arange(4, 8)})
    assert len(t.read_all()["x"]) == 8
    assert len(t.read_all(version=v_before)["x"]) == 4
    t.compact()
    assert len(t.files()) == 1
    np.testing.assert_array_equal(np.sort(t.read_all()["x"]), np.arange(8))


def test_two_phase_commit_atomicity():
    store = InMemoryObjectStore()
    t = DeltaTable.create(store, "tbl")
    adds = [t.append({"x": np.arange(i, i + 2)}, commit=False) for i in range(0, 6, 2)]
    assert t.read_all() == {}  # nothing visible yet
    t.commit_adds(adds, op="CHECKPOINT")
    assert len(t.read_all()["x"]) == 6  # all-or-nothing
