"""Serving engine: continuous batching, slot reuse, ragged lengths."""

import jax
import numpy as np

from repro.core import DeltaTensorStore
from repro.lake import InMemoryObjectStore, ReadExecutor
from repro.models import get_arch, transformer
from repro.serve import Request, ServeEngine, load_weights, save_weights

CFG = get_arch("granite-3-8b").reduced()


def test_weight_save_load_roundtrip_parallel():
    """Weights persist as delta tensors; load fans out on the shared executor."""
    params = transformer.init_params(CFG, jax.random.key(2))
    store = DeltaTensorStore(InMemoryObjectStore(), "weights",
                             io=ReadExecutor(max_workers=8))
    tids = save_weights(store, params, prefix="w")
    assert len(tids) == len(jax.tree.leaves(params))

    template = jax.eval_shape(lambda: transformer.init_params(CFG, jax.random.key(0)))
    loaded = load_weights(store, template, prefix="w")
    flat_a = jax.tree_util.tree_leaves_with_path(params)
    flat_b = jax.tree_util.tree_leaves_with_path(loaded)
    assert [p for p, _ in flat_a] == [p for p, _ in flat_b]
    for (_, a), (_, b) in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # loaded weights actually serve
    eng = ServeEngine(loaded, CFG, n_slots=1, max_len=32)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32) % CFG.vocab_size,
                  max_new_tokens=3)
    eng.submit(req)
    eng.run_until_drained(max_iters=50)
    assert req.done and len(req.out_tokens) == 3


def test_weight_resave_replaces_previous_generation():
    """Re-saving under the same prefix must not leave stale chunk files live."""
    params = transformer.init_params(CFG, jax.random.key(3))
    store = DeltaTensorStore(InMemoryObjectStore(), "weights")
    save_weights(store, params, prefix="w")
    bumped = jax.tree.map(lambda x: x + 1, params)
    save_weights(store, bumped, prefix="w")

    loaded = load_weights(store, params, prefix="w")
    for a, b in zip(jax.tree.leaves(bumped), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_continuous_batching():
    params = transformer.init_params(CFG, jax.random.key(0))
    eng = ServeEngine(params, CFG, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, (4 + 3 * i,)).astype(np.int32),
                    max_new_tokens=5 + i)
            for i in range(5)]  # 5 requests through 2 slots, ragged lengths
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_iters=200)
    for r in reqs:
        assert r.done
        assert len(r.out_tokens) == r.max_new_tokens
        assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)


def test_engine_matches_offline_decode():
    """Engine output == plain prefill+decode for a single request."""
    params = transformer.init_params(CFG, jax.random.key(1))
    prompt = np.arange(6, dtype=np.int32) % CFG.vocab_size

    # offline reference
    import jax.numpy as jnp
    caches = transformer.init_caches(CFG, 1, 32)
    logits, caches, _ = transformer.prefill(params, CFG,
                                            jnp.asarray(prompt[None]), caches)
    ref = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        lg, caches, _ = transformer.decode_step(
            params, CFG, jnp.asarray([[ref[-1]]], jnp.int32), caches)
        ref.append(int(jnp.argmax(lg[0, 0])))

    eng = ServeEngine(params, CFG, n_slots=1, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_drained(max_iters=50)
    assert req.out_tokens == ref
