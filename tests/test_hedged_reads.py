"""Straggler mitigation: hedged object-store reads via the shared executor."""

import time

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.pipeline import FTSFLoader, write_token_dataset
from repro.lake import InMemoryObjectStore, ReadExecutor


def test_hedged_duplicate_beats_straggler():
    io = ReadExecutor(max_workers=2)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(1.5)   # first attempt stalls
        return calls["n"]

    t0 = time.perf_counter()
    result = io.hedged(flaky, hedge_after_s=0.1)
    dt = time.perf_counter() - t0
    assert result in (1, 2)
    assert calls["n"] >= 2        # a duplicate was raced
    assert dt < 1.4               # and it won
    assert io.stats.hedges_launched >= 1


def test_hedged_disabled_runs_inline():
    io = ReadExecutor()
    assert io.hedged(lambda: 42) == 42          # no hedge_after_s configured
    assert io.stats.hedges_launched == 0


def test_hedged_propagates_error_when_all_attempts_fail():
    io = ReadExecutor()

    def boom():
        raise RuntimeError("nope")

    import pytest
    with pytest.raises(RuntimeError, match="nope"):
        io.hedged(boom, hedge_after_s=0.05, attempts=2)


def test_loader_with_hedging_yields_correct_batches():
    store = DeltaTensorStore(InMemoryObjectStore(), "data")
    tokens = np.arange(32 * 8, dtype=np.int32).reshape(32, 8)
    write_token_dataset(store, tokens, tensor_id="ds")
    loader = FTSFLoader(store, "ds", batch_size=4, seed=3, hedge_after_s=0.25)
    b = next(iter(loader))
    assert b["tokens"].shape == (4, 8)
    # rows are genuine dataset rows
    for row in b["tokens"]:
        assert row[0] % 8 == 0 and (row == np.arange(row[0], row[0] + 8)).all()
    loader.close()


def test_pipeline_module_defines_no_threading_primitives():
    """The ad-hoc hedged()/thread machinery moved to repro.lake.io."""
    import repro.data.pipeline as pipeline
    assert not hasattr(pipeline, "hedged")
    assert not hasattr(pipeline, "threading")
    assert not hasattr(pipeline, "queue")
