"""Straggler mitigation: hedged object-store reads."""

import time

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.pipeline import FTSFLoader, hedged, write_token_dataset
from repro.lake import InMemoryObjectStore


def test_hedged_duplicate_beats_straggler():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(1.5)   # first attempt stalls
        return calls["n"]

    t0 = time.perf_counter()
    result = hedged(flaky, hedge_after_s=0.1)()
    dt = time.perf_counter() - t0
    assert result in (1, 2)
    assert calls["n"] >= 2        # a duplicate was raced
    assert dt < 1.4               # and it won


def test_loader_with_hedging_yields_correct_batches():
    store = DeltaTensorStore(InMemoryObjectStore(), "data")
    tokens = np.arange(32 * 8, dtype=np.int32).reshape(32, 8)
    write_token_dataset(store, tokens, tensor_id="ds")
    loader = FTSFLoader(store, "ds", batch_size=4, seed=3, hedge_after_s=0.25)
    b = next(iter(loader))
    assert b["tokens"].shape == (4, 8)
    # rows are genuine dataset rows
    for row in b["tokens"]:
        assert row[0] % 8 == 0 and (row == np.arange(row[0], row[0] + 8)).all()
    loader.close()
