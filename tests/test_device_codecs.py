"""Device (jit) codecs: fixed-capacity COO/BSGS vs numpy ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from ._hypothesis_compat import given, settings, st  # skips property tests if hypothesis is missing

from repro.core import device as dev

from .test_encodings import sparse_tensor


@pytest.mark.parametrize("shape", [(16,), (8, 12), (4, 6, 10)])
def test_coo_roundtrip(shape):
    x = sparse_tensor(shape, density=0.2, seed=1)
    coo = dev.coo_encode(jnp.asarray(x), capacity=int(np.prod(shape)))
    out = dev.coo_decode(coo, shape)
    np.testing.assert_array_equal(np.asarray(out), x)
    assert int(coo.nnz) == np.count_nonzero(x)


def test_coo_capacity_truncates_gracefully():
    x = np.ones((8, 8), dtype=np.float32)
    coo = dev.coo_encode(jnp.asarray(x), capacity=10)
    assert int(coo.nnz) == 10
    out = np.asarray(dev.coo_decode(coo, (8, 8)))
    assert np.count_nonzero(out) == 10  # first 10 nnz kept, rest dropped


@pytest.mark.parametrize("shape,bs", [((16, 16), (4, 4)), ((6, 9), (2, 3)),
                                      ((5, 7), (2, 2)), ((4, 4, 8), (2, 2, 4))])
def test_blockify_roundtrip(shape, bs):
    x = np.random.default_rng(0).standard_normal(shape).astype(np.float32)
    bv = dev.blockify(jnp.asarray(x), bs)
    back = dev.unblockify(bv, shape, bs)
    np.testing.assert_array_equal(np.asarray(back), x)


@pytest.mark.parametrize("shape,bs", [((16, 16), (4, 4)), ((10, 9), (3, 3))])
def test_bsgs_roundtrip(shape, bs):
    x = sparse_tensor(shape, density=0.1, seed=2)
    grid = tuple(-(-s // b) for s, b in zip(shape, bs))
    db = dev.bsgs_encode(jnp.asarray(x), bs, capacity=int(np.prod(grid)))
    out = dev.bsgs_decode(db, shape, bs)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_bsgs_topk_keeps_highest_energy():
    x = np.zeros((8, 8), dtype=np.float32)
    x[0:2, 0:2] = 10.0   # block (0,0) strongest
    x[4:6, 4:6] = 5.0    # block (2,2)
    x[6:8, 0:2] = 0.1    # weak block
    db = dev.bsgs_topk(jnp.asarray(x), (2, 2), k=2)
    out = np.asarray(dev.bsgs_decode(db, (8, 8), (2, 2)))
    assert out[0, 0] == 10.0 and out[4, 4] == 5.0
    assert out[6, 0] == 0.0  # weak block dropped
    # error = x - decoded is exactly the dropped blocks (error feedback uses this)
    err = x - out
    assert np.abs(err).max() == pytest.approx(0.1)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_bsgs_device_matches_host(data):
    h = data.draw(st.integers(2, 12))
    w = data.draw(st.integers(2, 12))
    bh = data.draw(st.integers(1, 4))
    bw = data.draw(st.integers(1, 4))
    x = sparse_tensor((h, w), density=0.3, seed=data.draw(st.integers(0, 99)))
    grid = (-(-h // bh)) * (-(-w // bw))
    db = dev.bsgs_encode(jnp.asarray(x), (bh, bw), capacity=grid)
    out = np.asarray(dev.bsgs_decode(db, (h, w), (bh, bw)))
    np.testing.assert_array_equal(out, x)


def test_device_codecs_jit_under_vmap():
    # gradient compression runs per-leaf under jit; ensure nothing breaks
    xs = jnp.asarray(np.random.default_rng(3).standard_normal((4, 8, 8)).astype(np.float32))
    f = jax.vmap(lambda x: dev.bsgs_topk(x, (2, 2), k=3).blocks)
    out = f(xs)
    assert out.shape == (4, 3, 4)
