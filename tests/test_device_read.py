"""Device read path: read_device byte-identity, staged decode, pipeline stats.

Covers the PR's acceptance surface on CPU (jax device = host):

* ``read_device`` full/slice/COO results are byte-identical to the host
  ``read``/``read_slice``/``read_coo`` decode for every device-exact dtype;
* non-canonical dtypes (f64/i64 without x64) fall back to numpy, still exact;
* the staged decode pool produces the same bytes as inline decode and fills
  the new ``ReadStats`` counters (``decode_s``, ``decodes_offloaded``);
* ``LatencyModel.charge_compute`` keeps ``elapsed_s`` = pipelined makespan
  while ``io_elapsed_s`` stays pure wire time;
* ``read_many(device=True)`` and ``StreamLoader(device=True)`` land batches
  on device and bump ``bytes_to_device``.
"""

import numpy as np
import pytest

from repro.core import DeltaTensorStore
from repro.data.stream import StreamLoader
from repro.lake import (ChunkAssembler, InMemoryObjectStore, LatencyModel,
                        ReadExecutor, device)

from .test_encodings import sparse_tensor

RNG = np.random.default_rng(23)

# dtypes jax canonicalizes losslessly on CPU without x64
EXACT_DTYPES = ["float32", "float16", "int32", "int16", "uint8", "complex64",
                "bool"]


def make_store(io=None, compression=None):
    obj = InMemoryObjectStore()
    return DeltaTensorStore(obj, "tensors", io=io or ReadExecutor(max_workers=4),
                            compression=compression)


def dense(shape, dtype, seed=0):
    x = np.random.default_rng(seed).standard_normal(shape)
    if np.dtype(dtype).kind in "iub":
        return (x * 10).astype(dtype)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# byte identity: read_device vs host decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", EXACT_DTYPES)
def test_read_device_full_byte_identical(dtype):
    store = make_store()
    x = dense((6, 4, 8), dtype, seed=1)
    store.put(x, tensor_id="x", layout="ftsf", chunk_dims=2)
    with store.open("x") as ref:
        out, info = ref.read_device(with_info=True)
        want = ref.read()
    assert info.path == "block_gather" and info.on_device
    got = np.asarray(out)
    assert got.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, x)


def test_read_device_slice_byte_identical():
    store = make_store()
    x = dense((16, 3, 8, 8), "float32", seed=2)
    store.put(x, tensor_id="x", layout="ftsf", chunk_dims=3)
    spec = [(4, 11), None, None, None]
    with store.open("x") as ref:
        out, info = ref.read_device(spec, with_info=True)
        want = ref.read_slice(spec)
    assert info.path == "block_gather" and info.on_device
    np.testing.assert_array_equal(np.asarray(out), want)
    # only the 7 wanted chunks were staged on the host, not the full tensor
    assert info.host_staged_bytes == 7 * 3 * 8 * 8 * 4
    assert info.host_staged_bytes < x.nbytes


def test_read_device_subchunk_slice_crops_on_device():
    store = make_store()
    x = dense((8, 6, 10), "float32", seed=3)
    store.put(x, tensor_id="x", layout="ftsf", chunk_dims=2)
    spec = [(2, 5), (1, 4), (0, 7)]   # trailing dims narrow inside the chunk
    with store.open("x") as ref:
        out, info = ref.read_device(spec, with_info=True)
        want = ref.read_slice(spec)
    assert info.on_device
    np.testing.assert_array_equal(np.asarray(out), want)


def test_read_device_coo_scatter_byte_identical():
    store = make_store()
    x = sparse_tensor((64, 64), density=0.012, seed=4).astype(np.float32)
    store.put(x, tensor_id="s", layout="coo")
    with store.open("s") as ref:
        out, info = ref.read_device(with_info=True)
        want = ref.read()
    assert info.path == "coo_scatter" and info.on_device
    np.testing.assert_array_equal(np.asarray(out), want)
    # sparse staging beats densify-then-transfer on the host
    assert info.host_staged_bytes < x.nbytes
    assert info.device_bytes == x.nbytes


def test_read_device_coo_complex_values():
    # complex can't go through Pallas; the jnp reference scatter keeps it
    # on-device and exact
    store = make_store()
    x = np.zeros((16, 16), dtype=np.complex64)
    x[3, 4] = 1 + 2j
    x[9, 1] = -0.5j
    store.put(x, tensor_id="c", layout="coo")
    with store.open("c") as ref:
        out, info = ref.read_device(with_info=True)
    assert info.path == "coo_scatter" and info.on_device
    np.testing.assert_array_equal(np.asarray(out), x)


def test_read_device_coo_slice():
    store = make_store()
    x = sparse_tensor((32, 48), density=0.05, seed=5).astype(np.float32)
    store.put(x, tensor_id="s", layout="coo")
    spec = [(8, 24), (0, 48)]
    with store.open("s") as ref:
        out, info = ref.read_device(spec, with_info=True)
        want = ref.read_slice(spec)
    assert info.path == "coo_scatter"
    np.testing.assert_array_equal(np.asarray(out), want)


@pytest.mark.parametrize("dtype", ["float64", "int64"])
def test_read_device_noncanonical_dtype_falls_back_exact(dtype):
    # without jax x64 these would silently downcast; the path must stay numpy
    if device.device_dtype_exact(dtype):
        pytest.skip("x64 enabled: dtype is device-exact here")
    store = make_store()
    x = dense((4, 4, 6), dtype, seed=6)
    store.put(x, tensor_id="x", layout="ftsf", chunk_dims=2)
    with store.open("x") as ref:
        out, info = ref.read_device(with_info=True)
    assert info.path == "host_fallback" and not info.on_device
    assert isinstance(out, np.ndarray) and out.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(out, x)


def test_read_device_unsliceable_codec_raises(monkeypatch):
    from repro.core.encodings.ftsf import FTSFCodec
    store = make_store()
    store.put(dense((4, 8), "float32"), tensor_id="x", layout="ftsf")
    monkeypatch.setattr(FTSFCodec, "supports_slice", False)
    with store.open("x") as ref:
        with pytest.raises(NotImplementedError):
            ref.read_device([(0, 2), None])


def test_get_device_wrapper_and_bytes_to_device():
    store = make_store()
    x = dense((8, 16), "float32", seed=7)
    store.put(x, tensor_id="x", layout="ftsf")
    store.io.stats.reset()
    out = store.get_device("x")
    np.testing.assert_array_equal(np.asarray(out), x)
    assert store.io_stats()["bytes_to_device"] == x.nbytes


# ---------------------------------------------------------------------------
# ChunkAssembler
# ---------------------------------------------------------------------------

def test_chunk_assembler_gathers_arrival_order():
    asm = ChunkAssembler(3, 4, np.float32)
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    # arrive out of order: slot 2 first
    for pos in (2, 0, 1):
        asm.add(pos, rows[pos].tobytes())
    assert asm.staged_bytes == rows.nbytes
    out = np.asarray(asm.gather())
    np.testing.assert_array_equal(out, rows)


def test_chunk_assembler_incomplete_raises():
    asm = ChunkAssembler(2, 4, np.float32)
    asm.add(0, np.zeros(4, np.float32).tobytes())
    with pytest.raises(ValueError):
        asm.gather()


def test_scatter_coo_empty_and_dense():
    out = device.scatter_coo(np.empty(0, np.int64),
                             np.empty(0, np.float32), 8)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(8, np.float32))
    out = device.scatter_coo(np.array([1, 5]),
                             np.array([2.0, 3.0], np.float32), 6)
    want = np.zeros(6, np.float32)
    want[[1, 5]] = [2.0, 3.0]
    np.testing.assert_array_equal(np.asarray(out), want)


# ---------------------------------------------------------------------------
# staged decode pool
# ---------------------------------------------------------------------------

def test_staged_decode_matches_inline_decode():
    x = dense((32, 4, 16), "float32", seed=8)
    outs = {}
    for workers in (0, 2):
        io = ReadExecutor(max_workers=4, decode_workers=workers)
        store = make_store(io=io, compression="zlib+shuffle")
        store.put(x, tensor_id="x", layout="ftsf", chunk_dims=2,
                  target_file_bytes=2048)
        outs[workers] = store.get("x")
        if workers:
            assert io.stats.decodes_offloaded > 0
        else:
            assert io.stats.decodes_offloaded == 0
        assert io.stats.decode_s > 0.0
        io.shutdown()
    np.testing.assert_array_equal(outs[0], outs[2])
    np.testing.assert_array_equal(outs[2], x)


def test_decode_stats_surface_in_io_stats():
    io = ReadExecutor(max_workers=4)
    store = make_store(io=io, compression="zlib+shuffle")
    store.put(dense((16, 8), "float32", seed=9), tensor_id="x", layout="ftsf",
              target_file_bytes=1024)
    store.get("x")
    s = store.io_stats()
    for key in ("decode_s", "decode_overlap_frac", "decodes_offloaded",
                "bytes_to_device"):
        assert key in s
    assert s["decode_s"] > 0.0
    assert 0.0 <= s["decode_overlap_frac"] <= 1.0


def test_unframed_bytes_skip_decode_stage():
    io = ReadExecutor(max_workers=2)
    obj = InMemoryObjectStore()
    obj.put("k", b"plain bytes")
    assert io.fetch(obj, "k") == b"plain bytes"
    assert io.stats.decodes_offloaded == 0
    assert io.stats.decode_s == 0.0


# ---------------------------------------------------------------------------
# virtual-clock compute charging
# ---------------------------------------------------------------------------

def test_charge_compute_overlaps_under_parallel_clock():
    lm = LatencyModel(rtt_s=0.0, bandwidth_bps=1e9, parallelism=4,
                      virtual_clock=True)
    lm.charge(1_000_000)               # 8 ms wire on one channel
    io_done = lm.io_elapsed_s
    lm.charge_compute(0.3, not_before=lm.thread_done_s())
    # decode rode the same thread after its fetch: makespan extends,
    # wire time does not
    assert lm.io_elapsed_s == pytest.approx(io_done)
    assert lm.elapsed_s == pytest.approx(io_done + 0.3)
    assert lm.compute_s == pytest.approx(0.3)


def test_charge_compute_serial_clock_adds_up():
    lm = LatencyModel(rtt_s=0.01, bandwidth_bps=1e9, parallelism=1,
                      virtual_clock=True)
    lm.charge(1000)
    wire = lm.elapsed_s
    lm.charge_compute(0.05)
    assert lm.elapsed_s == pytest.approx(wire + 0.05)
    assert lm.io_elapsed_s == pytest.approx(wire)


def test_charge_compute_reset():
    lm = LatencyModel(rtt_s=0.0, bandwidth_bps=1e9, parallelism=2,
                      virtual_clock=True)
    lm.charge(1000)
    lm.charge_compute(0.1)
    lm.reset()
    assert lm.compute_s == 0.0 and lm.io_elapsed_s == 0.0


# ---------------------------------------------------------------------------
# batched + streaming device reads
# ---------------------------------------------------------------------------

def test_read_many_device_matches_host():
    store = make_store()
    a = dense((8, 4, 4), "float32", seed=10)
    b = dense((6, 4, 4), "float32", seed=11)
    store.put(a, tensor_id="a", layout="ftsf", chunk_dims=2)
    store.put(b, tensor_id="b", layout="ftsf", chunk_dims=2)
    reqs = [("a", None), ("b", [(1, 5), None, None]), ("a", [(0, 3), None, None])]
    host = store.read_many(reqs)
    store.io.stats.reset()
    dev = store.read_many(reqs, device=True)
    for h, d in zip(host, dev):
        assert device.is_device_array(d)
        np.testing.assert_array_equal(np.asarray(d), h)
    assert store.io_stats()["bytes_to_device"] == sum(h.nbytes for h in host)


def test_stream_loader_device_batches():
    store = make_store()
    x = dense((12, 3, 4), "float32", seed=12)
    store.put(x, tensor_id="x", layout="ftsf", chunk_dims=2)
    loader = StreamLoader(store, "x", batch_size=4, epochs=1, seed=0,
                          device=True)
    seen = 0
    for b in loader:
        assert device.is_device_array(b["data"])
        assert np.asarray(b["data"]).shape == (4, 3, 4)
        seen += 1
    assert seen == 3
    assert store.io.stats.bytes_to_device >= x.nbytes
