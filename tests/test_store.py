"""DeltaTensorStore integration: put/get/slice/time-travel + skipping."""

import numpy as np
import pytest

from repro.core import DeltaTensorStore, SparseCOO
from repro.lake import InMemoryObjectStore, LatencyModel

from .test_encodings import sparse_tensor


@pytest.fixture
def store():
    return DeltaTensorStore(InMemoryObjectStore(), "tensors")


def test_put_get_all_layouts(store):
    x = sparse_tensor((6, 5, 4), density=0.08, seed=1)
    for layout in ("ftsf", "coo", "csr", "csc", "csf", "bsgs"):
        tid = store.put(x, layout=layout)
        assert tid.startswith(layout)
        np.testing.assert_array_equal(store.get(tid), x)
        assert store.shape_of(tid) == (6, 5, 4)


def test_auto_layout(store):
    dense = np.ones((8, 8), dtype=np.float32)
    sp = np.zeros((8, 8), dtype=np.float32)
    sp[1, 2] = 3.0
    t_dense = store.put(dense)
    t_sp = store.put(sp)
    assert t_dense.startswith("ftsf") and t_sp.startswith("bsgs")
    assert dict(store.list_tensors())[t_dense] == "ftsf"


def test_get_slice(store):
    x = sparse_tensor((10, 4, 6), density=0.1, seed=2)
    for layout in ("ftsf", "coo", "csr", "csf", "bsgs"):
        tid = store.put(x, layout=layout)
        np.testing.assert_array_equal(store.get_slice(tid, [(2, 5)]), x[2:5])
        np.testing.assert_array_equal(store.get_slice(tid, [(0, 10), (1, 3)]),
                                      x[:, 1:3])


def test_slice_read_skips_bytes():
    lm = LatencyModel()
    obj = InMemoryObjectStore(latency=lm)
    store = DeltaTensorStore(obj, "tensors")
    x = np.random.default_rng(0).standard_normal((64, 32, 32)).astype(np.float32)
    tid = store.put(x, layout="ftsf", chunk_dims=2, target_file_bytes=64 << 10)
    store._headers_by_path.clear()      # make the full get pay the header fetch

    lm.reset()
    np.testing.assert_array_equal(store.get(tid), x)
    full_bytes = lm.bytes_moved

    lm.reset()
    np.testing.assert_array_equal(store.get_slice(tid, [(3, 7)]), x[3:7])
    slice_bytes = lm.bytes_moved
    # paper Fig.12: slice reads touch only covering chunks (−90% there)
    assert slice_bytes < full_bytes / 4


def test_overwrite_and_time_travel(store):
    x1 = np.arange(24, dtype=np.float32).reshape(4, 6)
    x2 = x1 * 10
    tid = store.put(x1, layout="ftsf", tensor_id="t")
    v1 = store.version()
    with pytest.raises(ValueError):
        store.put(x2, layout="ftsf", tensor_id="t")
    store.put(x2, layout="ftsf", tensor_id="t", overwrite=True)
    np.testing.assert_array_equal(store.get("t"), x2)
    np.testing.assert_array_equal(store.get("t", version=v1), x1)  # time travel


def test_coo_input_and_get_coo(store):
    x = sparse_tensor((12, 5, 5), density=0.02, seed=7)
    t = SparseCOO.from_dense(x)
    tid = store.put(t, layout="csf")
    back = store.get_coo(tid)
    np.testing.assert_array_equal(back.to_dense(), x)


def test_delete(store):
    tid = store.put(np.ones((3, 3)), layout="ftsf")
    store.delete(tid)
    with pytest.raises(KeyError):
        store.get(tid)


def test_multi_file_split(store):
    # force several files per tensor; chunk pruning must still reassemble
    x = sparse_tensor((40, 8, 8), density=0.3, seed=8)
    tid = store.put(x, layout="coo", target_file_bytes=2 << 10)
    files = [a for a in store.table.files()
             if a["partitionValues"].get("tensor") == tid
             and a["partitionValues"]["kind"] == "chunk"]
    assert len(files) > 3
    np.testing.assert_array_equal(store.get(tid), x)
    np.testing.assert_array_equal(store.get_slice(tid, [(10, 12)]), x[10:12])
