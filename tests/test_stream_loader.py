"""StreamLoader: deterministic shuffled streaming over leased snapshots,
windowed prefetch memory bounds, shard-interleave fairness, and the
``read_many`` cross-tensor fetch scheduler it rides on."""

import gc

import numpy as np
import pytest

from repro.core import DeltaTensorStore
from repro.data.stream import StreamLoader
from repro.data.synthetic import token_stream
from repro.lake import InMemoryObjectStore, ReadExecutor
from repro.lake.io import LatencyHistogram


def _store(shards=1, io=None, n_tensors=1, samples=64, seq=16):
    store = DeltaTensorStore(InMemoryObjectStore(), "tensors",
                             io=io, shards=shards)
    tids = []
    for i in range(n_tensors):
        tid = f"ds{i}"
        tokens = token_stream(samples, seq, 1000, seed=i)
        store.put(tokens.astype(np.int32), layout="ftsf", tensor_id=tid,
                  chunk_dims=1, target_file_bytes=4 << 10)
        tids.append(tid)
    return store, tids


def _collect(loader):
    return [(b["epoch"], b["step"], b["samples"].copy(), b["data"].copy())
            for b in loader]


# ---------------------------------------------------------------------------
# determinism + resumability
# ---------------------------------------------------------------------------


def test_epoch_plan_deterministic_and_distinct_across_epochs():
    store, tids = _store(n_tensors=2)
    with StreamLoader(store, tids, batch_size=8, seed=3, epochs=2) as a:
        run_a = _collect(a)
    with StreamLoader(store, tids, batch_size=8, seed=3, epochs=2) as b:
        run_b = _collect(b)
    assert len(run_a) == len(run_b) == 2 * a.steps_per_epoch
    for (ea, sa, ra, da), (eb, sb, rb, db) in zip(run_a, run_b):
        assert (ea, sa) == (eb, sb)
        np.testing.assert_array_equal(ra, rb)
        np.testing.assert_array_equal(da, db)
    # epoch 1 reshuffles (covers the same sample set in a different order)
    e0 = np.concatenate([r for e, _, r, _ in run_a if e == 0])
    e1 = np.concatenate([r for e, _, r, _ in run_a if e == 1])
    np.testing.assert_array_equal(np.sort(e0), np.sort(e1))
    assert not np.array_equal(e0, e1)


def test_resume_from_cursor_replays_exact_tail():
    store, tids = _store(n_tensors=2)
    with StreamLoader(store, tids, batch_size=8, seed=9, epochs=2) as full:
        whole = _collect(full)
    with StreamLoader(store, tids, batch_size=8, seed=9, epochs=2,
                      start_cursor=(0, 3)) as resumed:
        tail = _collect(resumed)
    assert len(tail) == len(whole) - 3
    for (ea, sa, ra, da), (eb, sb, rb, db) in zip(whole[3:], tail):
        assert (ea, sa) == (eb, sb)
        np.testing.assert_array_equal(ra, rb)
        np.testing.assert_array_equal(da, db)


def test_cursor_property_and_seek():
    store, tids = _store()
    loader = StreamLoader(store, tids, batch_size=8, seed=0, epochs=4)
    it = iter(loader)
    next(it); next(it)
    assert loader.cursor == (0, 2)
    loader.seek(2, 1)
    b = next(iter(loader))
    assert (b["epoch"], b["step"]) == (2, 2 * loader.steps_per_epoch + 1)
    np.testing.assert_array_equal(b["samples"], loader._rows_for(2, 1))
    loader.close()


# ---------------------------------------------------------------------------
# snapshot isolation + lifecycle
# ---------------------------------------------------------------------------


def test_snapshot_isolation_under_concurrent_writer():
    store, tids = _store(samples=32, seq=8)
    original = store.get(tids[0]).copy()
    loader = StreamLoader(store, tids, batch_size=8, seed=1, epochs=1)
    # a writer overwrites the dataset and vacuum runs mid-stream; the
    # loader's leased snapshot keeps every batch reading the original
    store.put(original + 1, layout="ftsf", tensor_id=tids[0],
              chunk_dims=1, target_file_bytes=4 << 10, overwrite=True)
    store.vacuum(keep_versions=1, ttl_s=0.0)
    seen = np.empty_like(original)
    for b in loader:
        seen[b["samples"]] = b["data"]
    np.testing.assert_array_equal(seen, original)
    loader.close()
    # after release the new generation is what a fresh reader sees
    np.testing.assert_array_equal(store.get(tids[0]), original + 1)


def test_dropped_loader_releases_lease_via_finalizer():
    store, tids = _store(samples=16, seq=8)
    loader = StreamLoader(store, tids, batch_size=4)
    vec = loader.catalog.version_vector
    assert store.leases.leased_versions(0)
    del loader
    gc.collect()
    assert not store.leases.leased_versions(0), vec


def test_context_manager_closes():
    store, tids = _store(samples=16, seq=8)
    with StreamLoader(store, tids, batch_size=4) as loader:
        assert not loader.closed
    assert loader.closed
    loader.close()  # idempotent


def test_incompatible_row_shapes_rejected():
    store = DeltaTensorStore(InMemoryObjectStore(), "tensors")
    store.put(token_stream(8, 16, 100).astype(np.int32), layout="ftsf",
              tensor_id="a", chunk_dims=1)
    store.put(token_stream(8, 32, 100).astype(np.int32), layout="ftsf",
              tensor_id="b", chunk_dims=1)
    with pytest.raises(ValueError, match="incompatible"):
        StreamLoader(store, ["a", "b"], batch_size=4)


# ---------------------------------------------------------------------------
# prefetch memory bound
# ---------------------------------------------------------------------------


def test_prefetch_memory_bounded_by_window():
    store, tids = _store(n_tensors=2, samples=64)
    with StreamLoader(store, tids, batch_size=8, window=3, epochs=2) as loader:
        for _ in loader:
            assert loader.inflight_bytes <= 3 * loader.batch_bytes
        stats = loader.stats()
    assert stats["peak_inflight_bytes"] <= stats["memory_bound_bytes"]
    assert stats["memory_bound_bytes"] == 3 * loader.batch_bytes
    assert stats["inflight_bytes"] == 0
    assert stats["batch_latency"]["count"] == stats["batches_yielded"]


# ---------------------------------------------------------------------------
# shard-aware interleave
# ---------------------------------------------------------------------------


def test_shard_interleave_fairness():
    # four equal tensors, one per shard table of a 4-shard store: every
    # batch must spread its rows across all shards, not drain one table's
    # files at a time. Placement is hash-routed, so pick ids per shard.
    store = DeltaTensorStore(InMemoryObjectStore(), "tensors", shards=4)
    by_shard = {}
    i = 0
    while len(by_shard) < 4:
        by_shard.setdefault(store.router.shard_of(f"ds{i}"), f"ds{i}")
        i += 1
    tids = [by_shard[s] for s in sorted(by_shard)]
    for j, tid in enumerate(tids):
        store.put(token_stream(32, 8, 1000, seed=j).astype(np.int32),
                  layout="ftsf", tensor_id=tid, chunk_dims=1,
                  target_file_bytes=4 << 10)
    catalog = store.catalog()
    shard_by_tensor = np.asarray([catalog.entry(t).shard for t in tids])
    assert len(set(shard_by_tensor.tolist())) == 4
    with StreamLoader(store, tids, batch_size=16, seed=4, epochs=1) as loader:
        for b in loader:
            t_idx = np.searchsorted(loader._offsets, b["samples"],
                                    side="right") - 1
            counts = np.bincount(shard_by_tensor[t_idx], minlength=4)
            # proportional interleave: 16 rows over 4 equal shards ~> 4 each
            assert counts.min() >= 2 and counts.max() <= 6, counts


# ---------------------------------------------------------------------------
# read_many fetch scheduler
# ---------------------------------------------------------------------------


def test_read_many_matches_serial_reads_and_dedups_chunks():
    io = ReadExecutor(max_workers=4, cache_bytes=0)  # no cache: count real gets
    store, tids = _store(io=io, n_tensors=2, samples=32, seq=8)
    tid = tids[0]
    expect_a = store.get_slice(tid, [(0, 4)])
    expect_b = store.get_slice(tid, [(2, 8)])
    expect_c = store.get(tids[1])
    io.stats.reset()
    got = store.read_many([(tid, [(0, 4)]), (tid, [(2, 8)]), (tids[1], None)])
    np.testing.assert_array_equal(got[0], expect_a)
    np.testing.assert_array_equal(got[1], expect_b)
    np.testing.assert_array_equal(got[2], expect_c)
    s = store.io_stats()
    assert s["plans"] == 1 and s["plan_requests"] == 3
    # overlapping slices share chunk files: the plan fetched each once
    assert s["plan_keys_deduped"] >= 1
    assert s["gets"] == s["plan_keys_fetched"]


def test_loader_records_dedup_through_read_many():
    io = ReadExecutor(max_workers=4, cache_bytes=0)
    store, tids = _store(io=io, n_tensors=2, samples=64)
    with StreamLoader(store, tids, batch_size=8, seed=2, epochs=1) as loader:
        io.stats.reset()
        n = sum(1 for _ in loader)
    s = store.io_stats()
    assert n == loader.steps_per_epoch
    assert s["plans"] == n                      # one merged plan per batch
    assert s["latency"]["count"] == s["gets"]   # every get observed
    assert s["latency"]["p99_s"] is not None


# ---------------------------------------------------------------------------
# latency histogram
# ---------------------------------------------------------------------------


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    for ms in range(1, 101):
        h.observe(ms / 1000.0)
    assert h.count == 100
    assert h.p50() == pytest.approx(0.050, rel=0.10)
    assert h.p99() == pytest.approx(0.100, rel=0.10)
    assert h.max == pytest.approx(0.100, rel=1e-6)
    assert 0.040 < h.mean < 0.060
    s = h.summary()
    assert s["count"] == 100 and s["p95_s"] == pytest.approx(0.095, rel=0.10)
    h.reset()
    assert h.count == 0 and h.p50() is None
