"""End-to-end training substrate tests: trainer + FTSF pipeline +
delta checkpointing (incremental, async, crash recovery, elastic restore)
+ gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeltaTensorStore
from repro.data.pipeline import FTSFLoader, write_token_dataset
from repro.data.synthetic import token_stream
from repro.lake import InMemoryObjectStore
from repro.models import get_arch
from repro.train import checkpoint as ckpt_mod
from repro.train import grad_compress, optimizer as opt, trainer

CFG = get_arch("granite-3-8b").reduced()
OCFG = opt.OptConfig(lr=1e-2, warmup_steps=2, total_steps=50, grad_clip=1.0)


def _batch(rng, b=2, t=16):
    tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (b, t)), jnp.int32)
    labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)], 1)
    return {"tokens": tokens, "labels": labels}


def test_train_loss_decreases():
    rng = np.random.default_rng(0)
    state = trainer.init_state(CFG, jax.random.key(0))
    step = jax.jit(trainer.make_train_step(CFG, OCFG))
    batch = _batch(rng)  # overfit one batch
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
    assert int(state.step) == 12


def test_ftsf_pipeline_feeds_trainer():
    store = DeltaTensorStore(InMemoryObjectStore(), "data")
    tokens = token_stream(64, 16, CFG.vocab_size)
    write_token_dataset(store, tokens, tensor_id="ds")
    loader = FTSFLoader(store, "ds", batch_size=4, seed=0)
    state = trainer.init_state(CFG, jax.random.key(1))
    step = jax.jit(trainer.make_train_step(CFG, OCFG))
    it = iter(loader)
    for _ in range(3):
        b = next(it)
        state, metrics = step(state, {"tokens": jnp.asarray(b["tokens"]),
                                      "labels": jnp.asarray(b["labels"])})
        assert np.isfinite(float(metrics["loss"]))
    loader.close()


def test_pipeline_determinism_and_host_sharding():
    store = DeltaTensorStore(InMemoryObjectStore(), "data")
    tokens = token_stream(64, 8, 100, seed=5)
    write_token_dataset(store, tokens, tensor_id="ds")
    l0 = FTSFLoader(store, "ds", batch_size=4, seed=7)
    l1 = FTSFLoader(store, "ds", batch_size=4, seed=7)
    b0 = next(iter(l0)); b1 = next(iter(l1))
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])  # deterministic
    l0.close(); l1.close()
    # host sharding partitions the sample space
    h0 = FTSFLoader(store, "ds", batch_size=4, n_hosts=2, host_index=0, seed=1)
    h1 = FTSFLoader(store, "ds", batch_size=4, n_hosts=2, host_index=1, seed=1)
    assert set(h0.owned).isdisjoint(set(h1.owned))
    assert len(h0.owned) + len(h1.owned) == 64
    h0.close(); h1.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_save_restore_roundtrip():
    state = trainer.init_state(CFG, jax.random.key(2))
    ck = ckpt_mod.DeltaCheckpointer(InMemoryObjectStore())
    ck.save(0, state)
    step_found, restored = ck.restore(state)
    assert step_found == 0
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_incremental_skips_unchanged():
    state = trainer.init_state(CFG, jax.random.key(3))
    store = InMemoryObjectStore()
    ck = ckpt_mod.DeltaCheckpointer(store)
    ck.save(0, state)
    n_files_0 = len(list(store.list("checkpoints/")))
    ck.save(1, state)  # nothing changed -> only a manifest row
    n_files_1 = len(list(store.list("checkpoints/")))
    assert n_files_1 - n_files_0 <= 4  # manifest + log + checkpoint artifacts
    _, restored = ck.restore(state, step=1)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state)[0]),
        np.asarray(jax.tree.leaves(restored)[0]))


def test_checkpoint_async_and_crash_recovery():
    state = trainer.init_state(CFG, jax.random.key(4))
    store = InMemoryObjectStore()
    ck = ckpt_mod.DeltaCheckpointer(store)
    ck.save_async(0, state)
    ck.wait()
    assert ck.steps() == [0]

    # crash mid-upload of the next checkpoint: inject failure
    store.fail_after_puts = store._puts + 2
    state2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, state)
    with pytest.raises(IOError):
        ck.save(1, state2)
    store.fail_after_puts = None
    # the failed checkpoint is invisible; restore returns step 0 intact
    ck2 = ckpt_mod.DeltaCheckpointer(store)
    step_found, restored = ck2.restore(state)
    assert step_found == 0
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state)[0]),
        np.asarray(jax.tree.leaves(restored)[0]))


def test_checkpoint_elastic_shard_restore():
    """Restore only one host's shard via slice reads (resharded restart)."""
    state = trainer.init_state(CFG, jax.random.key(5))
    ck = ckpt_mod.DeltaCheckpointer(InMemoryObjectStore())
    ck.save(0, state)
    emb = np.asarray(state.params["embed"])
    half = emb.shape[0] // 2
    _, restored = ck.restore(
        {"params": {"embed": jax.ShapeDtypeStruct((half, emb.shape[1]),
                                                  emb.dtype)}},
        shard_slices={"params/embed": [(0, half)]})
    np.testing.assert_array_equal(np.asarray(restored["params"]["embed"]),
                                  emb[:half])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compressed_training_converges():
    state = trainer.init_compressed_state(CFG, jax.random.key(6), n_pods=2)
    step = jax.jit(trainer.make_compressed_train_step(CFG, OCFG, ratio=0.25))
    rng = np.random.default_rng(1)
    b = _batch(rng, b=4, t=16)
    pod_batch = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in b.items()}
    losses = []
    for _ in range(10):
        state, m = step(state, pod_batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert float(m["wire_ratio"]) < 0.5  # compressed payload on the wire
    # pod replicas stay in lockstep (identical updates)
    p0 = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(np.asarray(p0[0], np.float32),
                               np.asarray(p0[1], np.float32), atol=1e-6)


def test_error_feedback_accumulates_dropped_blocks():
    g = jnp.asarray(np.random.default_rng(2).standard_normal((1, 32, 256)),
                    jnp.float32)
    r = jnp.zeros_like(g)
    mean, new_r, stats = grad_compress.compressed_grad_mean(
        {"w": g}, {"w": r}, ratio=0.1)
    # decoded + residual == original (lossless decomposition)
    np.testing.assert_allclose(np.asarray(mean["w"] + new_r["w"][0]),
                               np.asarray(g[0]), atol=1e-5)
    assert grad_compress.compression_ratio_bytes(stats) < 0.2
