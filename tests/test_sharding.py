"""Sharded logical store: router, manifest, version vectors, commit-retry."""

import threading

import numpy as np
import pytest

from repro.core import DeltaTensorStore, ShardRouter, load_manifest
from repro.core.sharding import resolve_version_vector, shard_table_path
from repro.lake import CommitConflict, InMemoryObjectStore

N_SHARDS = 4


@pytest.fixture
def obj():
    return InMemoryObjectStore()


@pytest.fixture
def store(obj):
    return DeltaTensorStore(obj, "tensors", shards=N_SHARDS)


def tids_on_shard(router: ShardRouter, shard: int, n: int, prefix="t"):
    """First n tensor ids the router hashes onto ``shard``."""
    out, i = [], 0
    while len(out) < n:
        tid = f"{prefix}{i}"
        if router.shard_of(tid) == shard:
            out.append(tid)
        i += 1
    return out


# ---------------------------------------------------------------------------
# router + manifest
# ---------------------------------------------------------------------------

def test_router_stable_and_covering():
    r = ShardRouter(8)
    # deterministic across instances (and, via blake2b, across processes)
    assert all(r.shard_of(f"t{i}") == ShardRouter(8).shard_of(f"t{i}")
               for i in range(100))
    hit = {r.shard_of(f"t{i}") for i in range(200)}
    assert hit == set(range(8))                  # every shard gets traffic
    assert ShardRouter(1).shard_of("anything") == 0
    with pytest.raises(ValueError):
        ShardRouter(0)
    with pytest.raises(ValueError):
        ShardRouter(4, algo="md5")               # unknown router algo


def test_version_vector_resolution():
    assert resolve_version_vector(1, None) == (None,)
    assert resolve_version_vector(1, 7) == (7,)
    assert resolve_version_vector(3, None) == (None, None, None)
    assert resolve_version_vector(3, (1, 2, 3)) == (1, 2, 3)
    with pytest.raises(TypeError):
        resolve_version_vector(3, 7)             # bare int is ambiguous
    with pytest.raises(ValueError):
        resolve_version_vector(3, (1, 2))        # wrong arity


def test_manifest_created_and_reopened(obj, store):
    m = load_manifest(obj, "tensors")
    assert m["shards"] == N_SHARDS
    reopened = DeltaTensorStore(obj, "tensors")  # no shards arg: inferred
    assert reopened.shards == N_SHARDS
    assert DeltaTensorStore(obj, "tensors", shards=N_SHARDS).shards == N_SHARDS
    with pytest.raises(ValueError, match="fixed at create time"):
        DeltaTensorStore(obj, "tensors", shards=2)


def test_cannot_shard_over_existing_unsharded_table(obj):
    """Regression: creating shards=N over a populated unsharded table would
    silently shadow every existing tensor behind empty shard tables."""
    s = DeltaTensorStore(obj, "legacy")
    s.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id="x")
    with pytest.raises(ValueError, match="already exists"):
        DeltaTensorStore(obj, "legacy", shards=4)
    np.testing.assert_array_equal(                # data still reachable
        DeltaTensorStore(obj, "legacy").get("x"), np.ones((2, 2), np.float32))


def test_mixed_none_version_vector_pins_latest_per_shard(store):
    tid = tids_on_shard(store.router, 0, 1)[0]
    store.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=tid)
    vv = store.version()
    store.put(np.full((2, 2), 5.0, np.float32), layout="ftsf",
              tensor_id=tid, overwrite=True)
    # pin shard 0 at the old version, let the rest resolve to latest
    cat = store.catalog(version=(vv[0], None, None, None))
    assert cat.version_vector[0] == vv[0]
    np.testing.assert_array_equal(cat.open(tid).read(),
                                  np.ones((2, 2), np.float32))


def test_one_shard_store_is_byte_compatible(obj):
    """shards=1 must produce the exact pre-sharding object layout."""
    DeltaTensorStore(obj, "plain")               # the old default
    keys_plain = set(obj.list("plain/"))
    obj2 = InMemoryObjectStore()
    DeltaTensorStore(obj2, "plain", shards=1)    # explicit 1-shard
    assert set(obj2.list("plain/")) == keys_plain
    assert load_manifest(obj2, "plain") is None  # no manifest written
    # and an "old" table opens unchanged through the sharding-aware client
    s = DeltaTensorStore(obj, "plain")
    s.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id="x")
    assert s.shards == 1 and isinstance(s.version(), int)
    np.testing.assert_array_equal(
        DeltaTensorStore(obj, "plain").get("x"), np.ones((2, 2), np.float32))


# ---------------------------------------------------------------------------
# sharded reads/writes through the handle API
# ---------------------------------------------------------------------------

def test_sharded_roundtrip_merged_namespace(obj, store):
    arrays = {f"t{i}": np.full((3, 5), i, np.float32) for i in range(12)}
    with store.batch() as b:
        for tid, x in arrays.items():
            b.put(x, layout="ftsf", tensor_id=tid)
    # tensors really spread across shard tables (files under shard dirs)
    used = {store.shard_of(tid) for tid in arrays}
    assert len(used) > 1
    for shard in used:
        assert any(obj.list(f"{shard_table_path('tensors', shard)}/"))
    # one flat namespace on read
    assert [t for t, _ in store.list_tensors()] == sorted(arrays)
    for tid, x in arrays.items():
        ref = store.open(tid)
        assert ref.shard == store.shard_of(tid)
        np.testing.assert_array_equal(ref.read(), x)
        np.testing.assert_array_equal(ref[1:3], x[1:3])


def test_version_vector_pinning_and_time_travel(store):
    x1 = np.ones((4, 4), np.float32)
    with store.batch() as b:
        for tid in tids_on_shard(store.router, 0, 2) + \
                   tids_on_shard(store.router, 1, 2):
            b.put(x1, layout="ftsf", tensor_id=tid)
    vv = store.version()
    assert isinstance(vv, tuple) and len(vv) == N_SHARDS
    tid0 = tids_on_shard(store.router, 0, 1)[0]
    store.put(x1 * 9, layout="ftsf", tensor_id=tid0, overwrite=True)
    assert store.version()[0] == vv[0] + 1       # only shard 0 advanced
    np.testing.assert_array_equal(store.open(tid0).read(), x1 * 9)
    np.testing.assert_array_equal(                # time travel by vector
        store.open(tid0, version=vv).read(), x1)
    with pytest.raises(TypeError):
        store.open(tid0, version=3)              # bare int on sharded store


def test_cross_shard_pinned_catalog_under_concurrent_writer(obj, store):
    """A pinned version vector is one consistent logical snapshot even
    while a second client overwrites tensors on several shards."""
    writer = DeltaTensorStore(obj, "tensors")
    tids = [tids_on_shard(store.router, s, 1, prefix=f"s{s}-")[0]
            for s in range(N_SHARDS)]
    with store.batch() as b:
        for i, tid in enumerate(tids):
            b.put(np.full((2, 2), i, np.float32), layout="ftsf",
                  tensor_id=tid)
    cat = store.catalog()                        # pin the vector
    refs = [cat.open(t) for t in tids]
    for tid in tids:                             # concurrent overwrites
        writer.put(np.full((2, 2), -1.0, np.float32), layout="ftsf",
                   tensor_id=tid, overwrite=True)
    assert all(r.version == cat.version_vector for r in refs)
    for i, (tid, ref) in enumerate(zip(tids, refs)):
        np.testing.assert_array_equal(           # pinned: pre-overwrite
            ref.read(), np.full((2, 2), i, np.float32))
        np.testing.assert_array_equal(           # unpinned: sees the writer
            store.open(tid).read(), np.full((2, 2), -1.0, np.float32))


def test_delete_and_add_rows_on_sharded_store(obj, store):
    tid = tids_on_shard(store.router, 2, 1)[0]
    store.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=tid)
    store.delete(tid)
    with pytest.raises(KeyError):
        store.open(tid)
    with store.batch() as b:                     # raw rows -> meta shard 0
        b.add_rows({"step": np.asarray([1], np.int64)},
                   partition_values={"kind": "meta"})
    assert any(store.table.scan(partition_filters={"kind": "meta"}))


def test_writers_on_different_shards_never_conflict(obj, store):
    """The scale-out claim in miniature: commits in disjoint shard domains
    need no retries at all."""
    a = DeltaTensorStore(obj, "tensors")
    b = DeltaTensorStore(obj, "tensors")
    tid_a = tids_on_shard(store.router, 0, 1, prefix="a")[0]
    tid_b = tids_on_shard(store.router, 1, 1, prefix="b")[0]
    ba = a.batch()
    ba.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=tid_a)
    bb = b.batch()                               # interleaved batches
    bb.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=tid_b)
    ba.commit()
    bb.commit()
    assert ba.conflicts == 0 and bb.conflicts == 0
    assert a.commit_stats["conflicts"] == 0
    assert b.commit_stats["conflicts"] == 0


# ---------------------------------------------------------------------------
# commit-retry / rebase
# ---------------------------------------------------------------------------

def test_rebase_resolves_disjoint_same_shard_conflict(obj, store):
    """Deterministic interleaving: a racer lands in the same shard between
    this batch's base pin and its commit; the rebase loop resolves it."""
    racer = DeltaTensorStore(obj, "tensors")
    t1, t2 = tids_on_shard(store.router, 3, 2)
    b = store.batch()
    b.put(np.full((2, 2), 1.0, np.float32), layout="ftsf", tensor_id=t1)
    racer.put(np.full((2, 2), 2.0, np.float32), layout="ftsf", tensor_id=t2)
    b.commit()                                   # conflicts, rebases, lands
    assert b.conflicts == 1
    assert store.commit_stats["retries"] == 1
    np.testing.assert_array_equal(store.open(t1).read(),
                                  np.full((2, 2), 1.0, np.float32))
    np.testing.assert_array_equal(store.open(t2).read(),
                                  np.full((2, 2), 2.0, np.float32))


def test_two_threads_disjoint_tensors_same_shard_both_succeed(obj, store):
    """The satellite's concurrency requirement, with real threads."""
    shard = 1
    per_writer = 3
    errors = []
    start = threading.Barrier(2)

    def writer(wid: int):
        client = DeltaTensorStore(obj, "tensors")
        tids = tids_on_shard(client.router, shard, per_writer,
                             prefix=f"w{wid}-")
        try:
            start.wait(timeout=30)
            for tid in tids:
                with client.batch(commit_retries=32) as b:
                    b.put(np.full((2, 2), float(wid), np.float32),
                          layout="ftsf", tensor_id=tid)
        except BaseException as e:  # surfaced below
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(w,)) for w in (1, 2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors
    reader = DeltaTensorStore(obj, "tensors")
    for wid in (1, 2):                           # zero lost writes
        for tid in tids_on_shard(reader.router, shard, per_writer,
                                 prefix=f"w{wid}-"):
            np.testing.assert_array_equal(
                reader.open(tid).read(),
                np.full((2, 2), float(wid), np.float32))


def test_same_tensor_overlap_raises_commit_conflict(obj, store):
    """Rebase cannot make two overwrites of one tensor commute."""
    racer = DeltaTensorStore(obj, "tensors")
    tid = tids_on_shard(store.router, 2, 1)[0]
    store.put(np.zeros((2, 2), np.float32), layout="ftsf", tensor_id=tid)
    b = store.batch(commit_retries=8)
    b.put(np.full((2, 2), 1.0, np.float32), layout="ftsf", tensor_id=tid,
          overwrite=True)                        # base pinned here
    racer.put(np.full((2, 2), 2.0, np.float32), layout="ftsf",
              tensor_id=tid, overwrite=True)     # overlaps concurrently
    with pytest.raises(CommitConflict, match="concurrently modified"):
        b.commit()
    # the racer's write is intact — the failed batch changed nothing
    np.testing.assert_array_equal(store.open(tid).read(),
                                  np.full((2, 2), 2.0, np.float32))


def test_retries_exhausted_raises(obj, store):
    racer = DeltaTensorStore(obj, "tensors")
    t1, t2 = tids_on_shard(store.router, 0, 2)
    b = store.batch(commit_retries=0)            # no rebase budget at all
    b.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=t1)
    racer.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=t2)
    with pytest.raises(CommitConflict):
        b.commit()


def test_checkpoint_roundtrip_on_sharded_store(obj):
    """Consumer integration: leaves hash across shards, manifest rows stay
    on the meta shard, restore reads one consistent vector."""
    from repro.train.checkpoint import DeltaCheckpointer

    state = {f"layer{i}": np.full((4, 3), float(i), np.float32)
             for i in range(8)}
    ckpt = DeltaCheckpointer(obj, "ckpts", shards=N_SHARDS)
    assert ckpt.store.shards == N_SHARDS
    ckpt.save(3, state)
    shards_used = {ckpt.store.shard_of(f"layer{i}@3") for i in range(8)}
    assert len(shards_used) > 1                  # leaves really spread
    restored_step, restored = DeltaCheckpointer(obj, "ckpts").restore(state)
    assert restored_step == 3
    for k, v in state.items():
        np.testing.assert_array_equal(restored[k], v)


def test_batch_spanning_shards_reports_vector(store):
    t0 = tids_on_shard(store.router, 0, 1)[0]
    t1 = tids_on_shard(store.router, 1, 1)[0]
    base = store.version()
    with store.batch() as b:
        b.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=t0)
        b.put(np.ones((2, 2), np.float32), layout="ftsf", tensor_id=t1)
    assert sorted(b.shard_versions) == [0, 1]    # one commit per shard
    assert b.version == (base[0] + 1, base[1] + 1, base[2], base[3])
    assert store.version() == b.version
