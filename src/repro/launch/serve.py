"""Serving launcher: continuous-batching engine over a checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --requests 8 --slots 4 [--ckpt-dir /tmp/repro_ckpts]

Loads params from the latest delta-lake checkpoint when one exists
(elastic: any mesh/host count can restore), else serves fresh-initialized
weights (layout/perf testing). With ``--weights-dir`` the params come
from a serve-weights store instead, through the snapshot-pinned
``store.models(prefix)`` handle (one merged cold-start fetch plan); the
engine owns that handle and releases its lease on close.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..lake import LocalFSObjectStore
from ..models import transformer
from ..models.config import get_arch
from ..serve import Request, ServeEngine
from ..train import checkpoint as ckpt_mod, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-shards", type=int, default=None,
                    help="shard count for the checkpoint store (fixed at "
                         "store-create time; omit to use what exists)")
    ap.add_argument("--ckpt-gc-keep", type=int, default=None,
                    help="after the restore completes, prune checkpoints "
                         "beyond the newest N and vacuum the reclaimed "
                         "bytes")
    ap.add_argument("--weights-dir", default=None,
                    help="serve-weights store directory; loads params via "
                         "store.models(--weights-prefix) instead of a "
                         "checkpoint")
    ap.add_argument("--weights-prefix", default="serve_weights",
                    help="model prefix inside --weights-dir")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name}: no decode step")

    params = transformer.init_params(cfg, jax.random.key(args.seed))
    repo = None
    if args.weights_dir:
        from ..core import DeltaTensorStore
        wstore = DeltaTensorStore(LocalFSObjectStore(args.weights_dir),
                                  "weights")
        repo = wstore.models(args.weights_prefix)
        if repo.exists():
            params = repo.load(params)
            print(f"[serve] loaded {repo.stats()['leaves']} param leaves "
                  f"from {args.weights_dir!r} prefix "
                  f"{args.weights_prefix!r} @ v{repo.version}")
        else:
            repo.save(params)
            print(f"[serve] seeded fresh weights into {args.weights_dir!r} "
                  f"prefix {args.weights_prefix!r}")
    elif args.ckpt_dir:
        ckpt = ckpt_mod.DeltaCheckpointer(LocalFSObjectStore(args.ckpt_dir),
                                          shards=args.ckpt_shards)
        if ckpt.restore_available():
            step, state = ckpt.restore(
                trainer.init_state(cfg, jax.random.key(args.seed)))
            params = state.params
            print(f"[serve] restored params from checkpoint step {step}")
            if args.ckpt_gc_keep is not None:
                gc = ckpt.gc(keep=args.ckpt_gc_keep)
                print(f"[serve] checkpoint gc: pruned steps "
                      f"{gc['pruned_steps']}, reclaimed "
                      f"{gc['bytes_reclaimed']} bytes "
                      f"({gc['files_deleted']} files)")

    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.numpy.zeros(
            (args.slots, cfg.n_image_tokens, cfg.d_model), jax.numpy.float32)
    with ServeEngine(params, cfg, n_slots=args.slots, max_len=args.max_len,
                     extra_inputs=extra, repo=repo) as eng:
        rng = np.random.default_rng(args.seed)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (int(rng.integers(4, 24)),)).astype(np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            eng.submit(r)
        t0 = time.time()
        eng.run_until_drained()
        dt = time.time() - t0
        tok = sum(len(r.out_tokens) for r in reqs)
        print(f"[serve] {len(reqs)} requests, {tok} tokens, {dt:.2f}s "
              f"({tok/dt:.1f} tok/s) on {args.slots} slots")


if __name__ == "__main__":
    main()
