import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). For every applicable cell this driver:

  1. builds the production mesh ((16,16) or (2,16,16));
  2. assembles abstract inputs + shardings from launch.specs;
  3. ``jax.jit(fn, in_shardings=..., ...).lower(...).compile()``;
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the optimized HLO into a JSON artifact under
     ``experiments/dryrun/`` (consumed by benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\]\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-operand bytes of every collective in optimized HLO."""
    per_kind: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        nbytes = elems * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, profile: str = None,
             tag: str = "", remat: str = None) -> Dict[str, Any]:
    import jax
    from repro.launch import specs
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import get_arch

    mesh_name = "multi" if multi_pod else "single"
    name = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    record: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                              "tag": tag, "status": "running"}
    cfg = get_arch(arch)
    ok, why = specs.cell_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        _write(path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        if profile or remat:
            import dataclasses
            from repro.models import config as config_mod
            kw = {}
            if profile:
                kw["sharding_profile"] = profile
            if remat:
                kw["remat_policy"] = remat
            cfg = dataclasses.replace(cfg, **kw)
            config_mod._REGISTRY[arch] = cfg
        cell = specs.make_cell(arch, shape, mesh)
        with mesh:
            jitted = jax.jit(cell.fn,
                             in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            mem_rec = {}
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                mem_rec[attr] = getattr(mem, attr, None)
            cost = compiled.cost_analysis() or {}
            cost_rec = {k: float(v) for k, v in cost.items()
                        if isinstance(v, (int, float)) and
                        k in ("flops", "bytes accessed", "transcendentals",
                              "utilization operand 0 {}", "optimal_seconds")}
            # keep all numeric entries that look global
            for k, v in cost.items():
                if isinstance(v, (int, float)) and k.startswith("bytes accessed"):
                    cost_rec[k] = float(v)

            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            # loop-aware corrected costs (scan bodies × trip count)
            from repro.analysis import accounting, hlo_cost
            corrected = hlo_cost.analyze(hlo)
            info = specs.SHAPES[shape]
            analytic = accounting.model_flops(
                cfg, info["kind"], info["global_batch"],
                1 if info["kind"] == "decode" else info["seq_len"],
                cache_len=info["seq_len"])
            print(f"[{name}] memory_analysis: "
                  f"args={mem_rec.get('argument_size_in_bytes')} "
                  f"temp={mem_rec.get('temp_size_in_bytes')} "
                  f"out={mem_rec.get('output_size_in_bytes')}")
            print(f"[{name}] cost_analysis: flops={cost_rec.get('flops')} "
                  f"bytes={cost_rec.get('bytes accessed')}")
            print(f"[{name}] collectives: {coll['count_by_kind']} "
                  f"total={coll['total_bytes']/1e9:.3f} GB")
            print(f"[{name}] corrected: flops={corrected.flops:.3e} "
                  f"bytes={corrected.bytes:.3e} "
                  f"coll={corrected.total_coll_bytes:.3e}")
        record.update(
            status="ok", note=cell.note,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=mem_rec, cost=cost_rec, collectives=coll,
            corrected={"flops": corrected.flops, "bytes": corrected.bytes,
                       "coll_bytes": corrected.coll_bytes,
                       "coll_count": corrected.coll_count},
            analytic=analytic,
            n_devices=int(np_prod(mesh.devices.shape)),
            mesh_shape=list(mesh.devices.shape),
            profile=profile or cfg.sharding_profile)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[{name}] FAILED: {e}")
    _write(path, record)
    return record


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _write(path: str, record: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[None, "train_4k",
                    "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--profile", default=None, help="override sharding profile")
    ap.add_argument("--remat", default=None, help="override remat policy")
    ap.add_argument("--tag", default="", help="artifact suffix for perf iters")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    from repro.models.config import list_archs
    archs = [args.arch] if args.arch else list(list_archs())
    shapes = [args.shape] if args.shape else list(
        __import__("repro.launch.specs", fromlist=["SHAPES"]).SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                r = run_cell(arch, shape, multi, args.out, force=args.force,
                             profile=args.profile, tag=args.tag,
                             remat=args.remat)
                results.append(r)
                print(f"== {arch} × {shape} × "
                      f"{'multi' if multi else 'single'}: {r['status']} "
                      f"({r.get('compile_s', '-')}s)")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} failed "
          f"of {len(results)}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
