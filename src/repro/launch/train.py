"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --reduced --steps 100 --ckpt-dir /tmp/ckpts

On a real TPU fleet this same entry point runs under `jax.distributed`
(one process per host): the mesh comes from `launch.mesh`, the data
pipeline shards by host, checkpoints commit atomically through the delta
log, and a restart resumes from the last committed step. On this CPU box
use ``--reduced`` (the smoke-twin config) — full configs are exercised via
``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.store import DeltaTensorStore
from ..data.pipeline import FTSFLoader, write_token_dataset
from ..data.synthetic import token_stream
from ..lake import LocalFSObjectStore
from ..models.config import get_arch
from ..train import checkpoint as ckpt_mod, optimizer as opt, trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU smoke-twin config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-dir", default="/tmp/repro_data")
    ap.add_argument("--host-index", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.name} layers={cfg.n_layers} "
          f"d_model={cfg.d_model} devices={jax.device_count()}")

    # --- data: FTSF rows in a delta table on local disk --------------------
    data_store = DeltaTensorStore(LocalFSObjectStore(args.data_dir), "datasets")
    try:
        data_store.shape_of("corpus")
    except KeyError:
        tokens = token_stream(max(1024, 8 * args.batch), args.seq,
                              cfg.vocab_size, seed=args.seed)
        write_token_dataset(data_store, tokens, tensor_id="corpus")
    loader = FTSFLoader(data_store, "corpus", batch_size=args.batch,
                        host_index=args.host_index, n_hosts=args.n_hosts,
                        seed=args.seed)

    # --- state: fresh or restored from the last committed checkpoint -------
    ocfg = opt.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                         total_steps=args.steps)
    ckpt = ckpt_mod.DeltaCheckpointer(LocalFSObjectStore(args.ckpt_dir))
    state = trainer.init_state(cfg, jax.random.key(args.seed))
    start = 0
    if ckpt.restore_available():
        start, state = ckpt.restore(state)
        print(f"[train] resumed from committed step {start}")
    step_fn = jax.jit(trainer.make_train_step(cfg, ocfg))

    it = iter(loader)
    t0 = time.time()
    for i in range(start, args.steps):
        b = next(it)
        state, m = step_fn(state, {"tokens": jnp.asarray(b["tokens"]),
                                   "labels": jnp.asarray(b["labels"])})
        if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
            ckpt.save_async(i + 1, state)
        if (i + 1) % 10 == 0:
            print(f"[train] step {i+1:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(i+1-start)/(time.time()-t0):.2f} steps/s)")
    ckpt.wait()
    loader.close()
    print(f"[train] done; checkpoints at steps {ckpt.steps()}")


if __name__ == "__main__":
    main()
