"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Defines the 4 assigned shape cells and, per (arch × cell), the function to
lower (train_step / prefill / decode), its abstract inputs (weak-type-
correct, shardable, no device allocation — built with jax.eval_shape), and
NamedShardings for every input. Skip rules (documented in DESIGN.md §5):

* long_500k only for sub-quadratic archs (SSM/hybrid/SWA);
* SWA archs serve long_500k with a ring-buffer KV cache of window size
  (a full 500k replicated cache would not fit HBM — the ring buffer IS
  the windowed-attention serving design);
* glm4-style tiny-kv caches shard their sequence dim over `model` when
  heads don't divide (sequence-parallel KV).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as shd
from ..models import transformer
from ..models.config import ArchConfig, get_arch
from ..train import optimizer as opt, trainer

SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}

OCFG = opt.OptConfig()


class Cell(NamedTuple):
    arch: str
    shape: str
    fn: Callable                       # function to jit/lower
    args: Tuple[Any, ...]              # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...]
    note: str = ""


def cell_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 500k decode needs sub-quadratic "
                       "attention (skip per assignment)")
    if shape.startswith(("decode", "long")) and not cfg.supports_decode:
        return False, "no decode step for this arch"
    return True, ""


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, b: int, t: int) -> Dict[str, Any]:
    out = {"tokens": _sds((b, t), jnp.int32), "labels": _sds((b, t), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.family == "audio":
        out["encoder_frames"] = _sds((b, t // cfg.encoder_seq_divisor,
                                      cfg.d_model), jnp.bfloat16)
    return out


def _extra_inputs(batch: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in batch.items() if k not in ("tokens", "labels")}


def _batch_shardings(batch: Dict[str, Any], mesh: Mesh):
    axes = shd.batch_axes(mesh)

    def spec(v):
        b = v.shape[0]
        ax = axes if b % int(np.prod([mesh.shape[a] for a in axes])) == 0 else ()
        return NamedSharding(mesh, P(ax if ax else None,
                                     *([None] * (len(v.shape) - 1))))
    return {k: spec(v) for k, v in batch.items()}


def _cache_shardings(caches: Any, cfg: ArchConfig, mesh: Mesh, batch: int):
    """Name-aware serve-state partitioner.

    Batch dim: the unique dim equal to the serve batch (sharded over the
    data axes when divisible). Model axis preference per leaf kind:
    KV caches try heads → head_dim → seq (seq-parallel KV is the fallback
    for tiny-kv archs like glm4); SSM matrix states try ssm-heads → P → N;
    conv/slstm states shard channels.
    """
    axes = shd.batch_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in axes]))
    msize = mesh.shape[shd.MODEL]

    def leaf_spec(path, leaf):
        name = shd._path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec: list = [None] * nd
        # batch dim = first dim whose extent equals the serve batch
        bdim = next((d for d in range(nd) if leaf.shape[d] == batch), None)
        if bdim is not None and batch % dsize == 0 and "index" not in name:
            spec[bdim] = axes
        leaf_name = name.rsplit("/", 1)[-1]
        if leaf_name in ("k", "v") and nd >= 4:
            # heads first; then SEQUENCE (flash-decode style partial
            # softmax: reductions over the sharded seq dim are cheap under
            # GSPMD) — head_dim last (contraction sharding forced big
            # score all-reduces in the baseline)
            prefs = [nd - 2, nd - 3, nd - 1]
        elif leaf_name in ("ssd", "s") and nd >= 4:
            prefs = [nd - 3, nd - 1, nd - 2]      # ssm heads, P, N
        elif leaf_name in ("conv", "c", "n", "h", "enc_out"):
            prefs = [nd - 1]
        else:
            prefs = sorted(range(nd), key=lambda d: -leaf.shape[d])
        for d in prefs:
            if 0 <= d < nd and spec[d] is None and leaf.shape[d] % msize == 0 \
                    and leaf.shape[d] >= msize:
                spec[d] = shd.MODEL
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------


def make_cell(arch: str, shape: str, mesh: Mesh) -> Cell:
    cfg = get_arch(arch)
    info = SHAPES[shape]
    t, b = info["seq_len"], info["global_batch"]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch}×{shape} skipped: {why}")

    if info["kind"] == "train":
        batch = batch_specs(cfg, b, t)
        state = jax.eval_shape(lambda: trainer.init_state(cfg, jax.random.key(0)))
        st_sh = trainer.state_shardings(state, cfg, mesh)
        b_sh = _batch_shardings(batch, mesh)
        fn = trainer.make_train_step(cfg, OCFG, mesh)
        return Cell(arch, shape, fn, (state, batch), (st_sh, b_sh),
                    (st_sh, None), donate=(0,))

    if info["kind"] == "prefill":
        batch = batch_specs(cfg, b, t)
        extra = _extra_inputs(batch)
        enc_len = t // cfg.encoder_seq_divisor if cfg.family == "audio" else 1
        caches = jax.eval_shape(
            lambda: transformer.init_caches(cfg, b, t, enc_len=enc_len))
        params = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.key(0)))
        p_sh = shd.params_shardings(params, cfg, mesh)
        c_sh = _cache_shardings(caches, cfg, mesh, b)
        tok_sh = _batch_shardings({"tokens": batch["tokens"]}, mesh)["tokens"]
        e_sh = _batch_shardings(extra, mesh)

        def fn(params, tokens, caches, extra):
            return transformer.prefill(params, cfg, tokens, caches,
                                       last_logits_only=True, **extra)

        return Cell(arch, shape, fn,
                    (params, batch["tokens"], caches, extra),
                    (p_sh, tok_sh, c_sh, e_sh),
                    (None, c_sh, None), donate=(2,))

    # decode
    ring = (cfg.window is not None and shape == "long_500k")
    cache_len = cfg.window if ring else t
    enc_len = t // cfg.encoder_seq_divisor if cfg.family == "audio" else 1
    # cap whisper decode cache at its design length? keep assigned t.
    caches = jax.eval_shape(
        lambda: transformer.init_caches(cfg, b, cache_len, enc_len=enc_len))
    # decode from a (traced) fully-occupied cache
    params = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.key(0)))
    batch = batch_specs(cfg, b, 1)
    extra = _extra_inputs(batch)
    # enc-dec decode reads encoder states from caches["enc_out"], not inputs
    extra.pop("encoder_frames", None)
    p_sh = shd.params_shardings(params, cfg, mesh)
    c_sh = _cache_shardings(caches, cfg, mesh, b)
    tok_sh = _batch_shardings({"tokens": batch["tokens"]}, mesh)["tokens"]
    e_sh = _batch_shardings(extra, mesh)

    def fn(params, token, caches, extra):
        return transformer.decode_step(params, cfg, token, caches, **extra)

    note = f"ring-buffer KV (window={cfg.window})" if ring else ""
    return Cell(arch, shape, fn,
                (params, batch["tokens"], caches, extra),
                (p_sh, tok_sh, c_sh, e_sh),
                (None, c_sh, None), donate=(2,), note=note)
