"""Maintenance CLI: compact / vacuum a tensor store without writing Python.

    PYTHONPATH=src python -m repro.launch.gc --dir /data/lake --root tensors \
        --compact --vacuum --keep-versions 3 [--ttl 86400] [--dry-run]

Opens the store at ``<dir>/<root>`` (sharded or not — the store manifest
decides), optionally OPTIMIZEs every shard, then vacuums with the retention
horizon ``keep-versions``/``ttl`` computed per shard. Prints per-shard files
and bytes reclaimed. ``--dry-run`` reports without deleting. ``--spill-index``
backfills the spilled catalog index at the latest version (useful on tables
that grew large before spilling existed). ``--recompress zlib+shuffle``
rewrites every data file under that chunk-blob codec during compact — the
migration path for tables written before compression existed (run
``--vacuum`` afterwards, or in the same invocation, to reclaim the old
raw generation once retention allows). ``--build-chunk-index`` backfills
the content-addressed chunk index (``_cas/chunks.index.json``) from the
latest snapshot — the migration path for tables written before dedup
existed: afterwards, re-uploads of identical chunks (and ``put_variant``
deltas) resolve against the pre-existing objects.

Vacuum is **reference-counted**: a physical object is deleted only when
no retained or leased snapshot references it — directly, through a
deduplicated add-action (``physPath``), or as the base of a delta-stored
file (``deltaBase``, including cross-shard references). Deleting one of
several tensors sharing chunks therefore reclaims only the unshared ones.

Leases protect only readers in *this* process; the horizon policy is what
protects readers elsewhere — pick ``--keep-versions`` accordingly.
"""

from __future__ import annotations

import argparse
from typing import List, Optional

from ..core import DeltaTensorStore
from ..lake import LocalFSObjectStore


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="compact/vacuum a Delta tensor store")
    ap.add_argument("--dir", required=True,
                    help="object-store root directory (LocalFSObjectStore)")
    ap.add_argument("--root", default="tensor_store",
                    help="store root key prefix inside --dir")
    ap.add_argument("--compact", action="store_true",
                    help="OPTIMIZE every shard before vacuuming")
    ap.add_argument("--recompress", metavar="CODEC", default=None,
                    help="rewrite data files under this chunk-blob codec "
                         "spec during compact (e.g. zlib+shuffle; implies "
                         "--compact)")
    ap.add_argument("--vacuum", action="store_true",
                    help="delete files outside the retention horizon")
    ap.add_argument("--keep-versions", type=int, default=None,
                    help="retain the newest N versions per shard "
                         "(default: the store's recorded/default policy)")
    ap.add_argument("--ttl", type=float, default=None,
                    help="also retain versions younger than TTL seconds")
    ap.add_argument("--spill-index", action="store_true",
                    help="write the spilled catalog index at latest version")
    ap.add_argument("--build-chunk-index", action="store_true",
                    help="backfill the content-addressed chunk index from "
                         "the latest snapshot (enables dedup on tables "
                         "written before it existed)")
    ap.add_argument("--dry-run", action="store_true",
                    help="report what vacuum would delete; change nothing")
    args = ap.parse_args(argv)

    if args.recompress:
        args.compact = True
    if not (args.compact or args.vacuum or args.spill_index
            or args.build_chunk_index):
        ap.error("nothing to do: pass --compact (or --recompress), "
                 "--vacuum, --spill-index and/or --build-chunk-index")
    if args.dry_run and args.compact:
        print("[gc] --dry-run: skipping compact (it would commit)")
    if args.dry_run and args.spill_index:
        print("[gc] --dry-run: skipping --spill-index (it would write "
              "index files)")
    if args.dry_run and args.build_chunk_index:
        print("[gc] --dry-run: skipping --build-chunk-index (it would "
              "write index files)")

    store = DeltaTensorStore(LocalFSObjectStore(args.dir), args.root)
    print(f"[gc] store {args.root!r}: {store.shards} shard(s), "
          f"version {store.version()}")

    if args.build_chunk_index and not args.dry_run:
        for shard, n in enumerate(store.build_chunk_index()):
            print(f"[gc] shard {shard}: chunk index covers {n} objects")

    if args.compact and not args.dry_run:
        for shard, res in enumerate(store.compact(recompress=args.recompress)):
            if res:
                extra = (f", {res.files_recompressed} recompressed"
                         if res.files_recompressed else "")
                if res.files_skipped_shared:
                    extra += (f", {res.files_skipped_shared} shared/delta "
                              f"files left in place")
                # bytes_rewritten counts physical output bytes once, not
                # once per referencing add-action — the honest I/O bill
                print(f"[gc] shard {shard}: compacted {res.files_compacted} "
                      f"files -> {res.files_written}{extra}, "
                      f"{_fmt_bytes(res.bytes_rewritten)} rewritten "
                      f"(v{res.version})")
            else:
                print(f"[gc] shard {shard}: compact no-op (commit-free)")
        if args.recompress:
            stats = store.storage_stats()
            dd = stats["dedup"]
            print(f"[gc] storage after recompress: "
                  f"{_fmt_bytes(stats['physical_bytes'])} physical / "
                  f"{_fmt_bytes(stats['logical_bytes'])} logical "
                  f"({stats['ratio']:.2f}x); dedup saved "
                  f"{_fmt_bytes(dd['saved_bytes'])} across "
                  f"{dd['deduped_refs']} refs")

    if args.spill_index and not args.dry_run:
        for key in store.spill_catalog():
            print(f"[gc] spilled catalog index: {key}")

    if args.vacuum:
        results = store.vacuum(keep_versions=args.keep_versions,
                               ttl_s=args.ttl, dry_run=args.dry_run)
        verb = "would delete" if args.dry_run else "deleted"
        total_files = total_bytes = 0
        for shard, res in enumerate(results):
            total_files += res.files_deleted
            total_bytes += res.bytes_reclaimed
            print(f"[gc] shard {shard}: {verb} {res.files_deleted} files "
                  f"(+{res.index_files_deleted} indexes), "
                  f"{_fmt_bytes(res.bytes_reclaimed)}; retained versions "
                  f"{res.retained_versions[0]}..{res.retained_versions[-1]}"
                  if res.retained_versions else
                  f"[gc] shard {shard}: empty table")
        print(f"[gc] total: {verb} {total_files} files, "
              f"{_fmt_bytes(total_bytes)} reclaimed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
