"""Streaming-ingest CLI: append rows to a tensor with watermark commits.

    PYTHONPATH=src python -m repro.launch.ingest --dir /data/lake \
        --root tensors --tensor events --rows 4096 --row-shape 64,8 \
        --watermark-rows 256 [--watermark-s 5] [--batch-rows 32]

Opens (or creates) the store at ``<dir>/<root>`` and drives an
:class:`~repro.data.ingest.IngestWriter` with synthetic rows: the producer
appends ``--batch-rows`` rows at a time and the writer commits a new table
version whenever the row or time watermark is crossed. Readers are never
blocked — each commit is an ordinary fenced Delta version, so a
``StreamLoader`` (or a second ``ingest`` process) pointed at the same
tensor keeps working off its pinned snapshot and picks up the new rows on
``reopen()``.

The writer is crash-consistent: killing this process at any point leaves
either fully committed rows or invisible uploads that
``repro.launch.gc --vacuum`` reclaims. Re-running with the same arguments
resumes from the committed row count (the banner prints it), so a producer
that replays its stream from that offset never duplicates a row.
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np

from ..core import DeltaTensorStore
from ..lake import LocalFSObjectStore


def _parse_shape(text: str) -> tuple:
    try:
        shape = tuple(int(p) for p in text.split(",") if p.strip())
    except ValueError:
        raise argparse.ArgumentTypeError(f"bad shape {text!r}") from None
    if not shape or any(d <= 0 for d in shape):
        raise argparse.ArgumentTypeError(f"bad shape {text!r}")
    return shape


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="stream synthetic rows into a tensor with watermark "
                    "commits")
    ap.add_argument("--dir", required=True,
                    help="object-store root directory (LocalFSObjectStore)")
    ap.add_argument("--root", default="tensor_store",
                    help="store root key prefix inside --dir")
    ap.add_argument("--tensor", required=True, help="tensor id to ingest into")
    ap.add_argument("--rows", type=int, default=1024,
                    help="total rows to append this run")
    ap.add_argument("--row-shape", type=_parse_shape, default=(64,),
                    help="shape of ONE row, comma-separated (e.g. 64,8); "
                         "ignored when the tensor already exists")
    ap.add_argument("--dtype", default="float32",
                    help="row dtype for a new tensor (default float32)")
    ap.add_argument("--batch-rows", type=int, default=32,
                    help="rows per producer append call")
    ap.add_argument("--watermark-rows", type=int, default=256,
                    help="commit whenever this many rows are buffered")
    ap.add_argument("--watermark-s", type=float, default=None,
                    help="also commit when the oldest buffered row is this "
                         "old (seconds)")
    ap.add_argument("--target-file-bytes", type=int, default=None,
                    help="split sealed batches into files of about this "
                         "many bytes")
    ap.add_argument("--compression", default=None,
                    help="chunk-blob codec spec for new files "
                         "(e.g. zlib+shuffle)")
    ap.add_argument("--seed", type=int, default=0, help="synthetic-data seed")
    args = ap.parse_args(argv)
    if args.rows <= 0 or args.batch_rows <= 0:
        ap.error("--rows and --batch-rows must be positive")

    store = DeltaTensorStore(LocalFSObjectStore(args.dir), args.root,
                             compression=args.compression)
    with store.ingest(args.tensor,
                      watermark_rows=args.watermark_rows,
                      watermark_s=args.watermark_s,
                      target_file_bytes=args.target_file_bytes) as w:
        if w.row_count and w._row_shape is not None:
            shape, dtype = w._row_shape, w._dtype
            print(f"[ingest] resuming {args.tensor!r} at committed row "
                  f"{w.row_count} (row shape {tuple(shape)}, {dtype})")
        else:
            shape, dtype = args.row_shape, np.dtype(args.dtype)
            print(f"[ingest] creating {args.tensor!r} (row shape "
                  f"{tuple(shape)}, {dtype})")
        rng = np.random.default_rng(args.seed + w.row_count)
        t0 = time.perf_counter()
        done = 0
        while done < args.rows:
            k = min(args.batch_rows, args.rows - done)
            if np.issubdtype(dtype, np.floating):
                batch = rng.standard_normal((k,) + tuple(shape)).astype(dtype)
            else:
                batch = rng.integers(0, 2 ** 15, size=(k,) + tuple(shape),
                                     dtype=dtype)
            w.append_rows(batch)
            done += k
        w.close()
        dt = max(time.perf_counter() - t0, 1e-9)
        s = w.stats()
        print(f"[ingest] appended {done} rows in {dt:.2f}s "
              f"({done / dt:.0f} rows/s) across {s['flushes']} commits "
              f"({s['conflicts']} conflicts, {s['reencodes']} re-encodes)")
        print(f"[ingest] {args.tensor!r} now has {w.row_count} rows at "
              f"version {w.version}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
