"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]. SWA window 4096 => O(T*w) attention, so the
long_500k decode cell runs (window-capped KV).
"""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    rope_theta=10_000.0, window=4096,
    sharding_profile="tp",
    supports_long_context=True,
))
