"""whisper-tiny [audio] — enc-dec, conv frontend STUB. [arXiv:2212.04356].

input_specs() provides precomputed log-mel frame embeddings
(B, seq/4, d_model) for the encoder; the decoder is the assigned 4L stack
with self + cross attention. Decode cells exercise the decoder KV cache;
long_500k is skipped (out of family for 30-second audio windows).
"""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    rope_theta=10_000.0,
    is_encoder_decoder=True, n_encoder_layers=4, encoder_seq_divisor=4,
    sharding_profile="tp",
    supports_long_context=False,
))
