"""phi3-mini-3.8b [dense] — RoPE SwiGLU, kv=32 (full MHA).
[arXiv:2404.14219; unverified]."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    rope_theta=10_000.0,
    sharding_profile="tp",
    supports_long_context=False,
))
