"""Assigned-architecture configs (10) + the paper's own store config."""
from . import (glm4_9b, granite_3_8b, granite_moe_1b_a400m, h2o_danube_3_4b,
               llama_3_2_vision_11b, mixtral_8x22b, phi3_mini_3_8b,
               whisper_tiny, xlstm_1_3b, zamba2_2_7b)
from .paper_store import PAPER_STORE

ALL_ARCHS = [
    "glm4-9b", "granite-3-8b", "granite-moe-1b-a400m", "h2o-danube-3-4b",
    "llama-3.2-vision-11b", "mixtral-8x22b", "phi3-mini-3.8b",
    "whisper-tiny", "xlstm-1.3b", "zamba2-2.7b",
]
