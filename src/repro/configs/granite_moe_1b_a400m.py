"""granite-moe-1b-a400m [moe] — 32 experts, top-8, tiny expert FFN (512).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. n_experts % 16 == 0 so the
expert dim shards cleanly over the model axis (pure EP)."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    rope_theta=10_000.0,
    n_experts=32, top_k=8, moe_d_ff=512,
    sharding_profile="tp",
    supports_long_context=False,
))
