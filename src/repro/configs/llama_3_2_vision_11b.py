"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. The vision frontend is a
STUB per the assignment: input_specs() supplies precomputed patch embeddings
(B, n_image_tokens, d_model); the backbone interleaves 8 gated cross-attn
layers into the 40-layer stack (superblocks of 1 cross + 4 self).
"""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5, n_image_tokens=1024,
    sharding_profile="tp",
    supports_long_context=False,   # full attention -> long_500k skipped
))
