"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, d_ff=0 (blocks carry their own
up/down projections). [arXiv:2405.04517; unverified]. Superblocks of
7 mLSTM + 1 sLSTM (the paper's 7:1 ratio); recurrent decode state is O(1)
in sequence length so all long-context cells run."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    ssm_expand=2, ssm_chunk=128,
    xlstm_slstm_every=8,
    sharding_profile="tp",
    supports_long_context=True,
))
