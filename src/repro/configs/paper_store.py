"""The paper's own experiment configuration (§V): dataset shapes, block
shapes, chunk ranks, the 1 Gbps object-store latency model."""

PAPER_STORE = {
    # scenario 1: dense FFHQ-like tensor, FTSF with 3-D chunks
    "dense": {
        "shape": (5000, 3, 1024, 1024),     # paper scale
        "bench_shape": (256, 3, 128, 128),  # CPU-box scale (same structure)
        "chunk_dims": 3,
        "slice": (0, 100),                  # X[0:100] fiber read (Fig. 12)
    },
    # scenario 2: sparse Uber-pickups tensor
    "sparse": {
        "shape": (183, 24, 1140, 1717),
        "bench_shape": (183, 24, 285, 430),  # ~1/16 spatial grid
        "nnz_ratio": 0.00038,                # 0.038% non-zero (paper)
        "bsgs_block": (61, 24, 1, 1),   # time-major blocks: hot cells are
                                         # active across most (day,hour) slots
        "csr_split": 1,
        "slice_dim0": 1,                     # X[i] slice reads (Fig. 16)
    },
    "object_store": {"rtt_s": 0.010, "bandwidth_bps": 1e9},  # paper network
    "repeats": 5,
}
