"""zamba2-2.7b [hybrid] — Mamba2 backbone + one SHARED attention block
applied every 6 layers. [arXiv:2411.15242; hf]. ssm_state=64. SSM decode is
O(1)/token so all long-context cells run."""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    rope_theta=10_000.0,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    shared_attn_every=6,
    sharding_profile="tp",
    supports_long_context=True,
))
