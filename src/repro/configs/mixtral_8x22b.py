"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf].

140B params: bf16 weights alone exceed 16 GiB/chip at TP=16, so this arch
uses the fsdp_tp profile (params+optimizer sharded over data AND model).
SWA window 4096 => long_500k decode cell runs.
"""
from ..models.config import ArchConfig, register_arch

CONFIG = register_arch(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    rope_theta=1_000_000.0, window=4096,
    n_experts=8, top_k=2, moe_d_ff=16384,
    sharding_profile="fsdp_tp",
    supports_long_context=True,
))
