"""Delta Tensor (Bao et al., CS.DC 2024) as a multi-pod JAX/TPU framework.

The paper's five tensor-storage formats (FTSF, COO, CSR/CSC, CSF, BSGS)
implemented over a mini Delta Lake (`repro.lake`, `repro.core`) and
integrated into a distributed training/serving stack: FTSF data pipelines,
incremental delta-lake checkpointing with elastic restore, and BSGS
block-top-k gradient compression on the cross-pod link. See DESIGN.md and
EXPERIMENTS.md.
"""
