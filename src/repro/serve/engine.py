"""Serving engine: batched prefill + decode with continuous slot batching.

Slots are fixed (static shapes for jit); finished sequences free their slot
and the engine immediately prefill-admits the next queued request into it.
Per-slot KV caches live in one batched cache pytree, so decode is a single
jit'd step for the whole batch regardless of request boundaries.

Weights live in the Delta Tensor store as one FTSF tensor per param leaf,
managed through :class:`~repro.serve.repo.ModelRepo`
(``store.models(prefix)``): a snapshot-pinned, lease-holding handle whose
``load`` pulls the whole tree through one merged ``Catalog.read_many``
fetch plan on the shared :class:`~repro.lake.io.ReadExecutor` —
deduplicated keys, windowed submission, per-leaf decode overlapping
in-flight fetches — so cold-start weight load time is the makespan of
parallel object-store gets, not the serial sum. The old free functions
:func:`save_weights` / :func:`load_weights` survive as deprecated shims
over that handle. Multi-tenant admission control lives one layer up in
:class:`~repro.serve.gateway.Gateway`.
"""

from __future__ import annotations

import queue
import warnings
import weakref
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.store import DeltaTensorStore
from ..lake.io import ReadExecutor
from ..models import transformer
from ..models.config import ArchConfig
from .repo import ModelRepo


# -- weight load/store (deprecated shims over ModelRepo) ----------------------


def save_weights(store: DeltaTensorStore, params: Any, *,
                 prefix: str = "serve_weights") -> List[str]:
    """Deprecated: use ``store.models(prefix).save(params)``.

    Thin shim over :meth:`repro.serve.repo.ModelRepo.save` — identical
    behavior (one FTSF tensor per leaf, ONE atomic commit, re-save
    replaces the previous generation), kept for existing callers.
    """
    warnings.warn(
        "save_weights is deprecated; use store.models(prefix).save(params)",
        DeprecationWarning, stacklevel=2)
    with store.models(prefix) as repo:
        return repo.save(params)


def load_weights(store: DeltaTensorStore, template: Any, *,
                 prefix: str = "serve_weights",
                 io: Optional[ReadExecutor] = None) -> Any:
    """Deprecated: use ``store.models(prefix).load(template)``.

    Thin shim over :meth:`repro.serve.repo.ModelRepo.load` — identical
    behavior (whole tree through ONE merged fetch plan against one pinned
    catalog). ``io=`` now actually threads through to ``read_many``
    (historically it was accepted and silently ignored).
    """
    warnings.warn(
        "load_weights is deprecated; use store.models(prefix).load(template)",
        DeprecationWarning, stacklevel=2)
    with store.models(prefix) as repo:
        return repo.load(template, io=io)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching inference engine over store-resident weights.

    ``close()`` / context-manager exit / garbage collection release the
    engine's resources — in particular the snapshot lease of a weight
    repo passed as ``repo=`` (or via :meth:`from_repo`), which the engine
    then owns. Same lifecycle contract as ``TensorRef``, ``StreamLoader``,
    ``ModelRepo``, and ``Gateway``.
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int, max_len: int,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 enc_len: int = 1, repo: Optional[ModelRepo] = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.extra = extra_inputs or {}
        self.caches = transformer.init_caches(cfg, n_slots, max_len,
                                              enc_len=enc_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._repo = repo
        # GC backstop: a dropped engine must not pin its weight snapshot
        self._finalizer = (weakref.finalize(self, repo.close)
                           if repo is not None
                           else weakref.finalize(self, lambda: None))

        self._decode = jax.jit(
            lambda params, tok, caches, extra: transformer.decode_step(
                params, cfg, tok, caches, **extra))
        # prefill one slot at a time (batch=1 lane), written into the slot
        self._prefill = jax.jit(
            lambda params, tok, caches, extra: transformer.prefill(
                params, cfg, tok, caches, **extra),
            static_argnames=())

    # -- lifecycle -------------------------------------------------------------

    @classmethod
    def from_repo(cls, repo: ModelRepo, template: Any, cfg: ArchConfig, *,
                  n_slots: int, max_len: int, **kwargs) -> "ServeEngine":
        """Build an engine whose weights load from ``repo`` (one merged
        fetch plan); the engine owns the handle and releases its snapshot
        lease on ``close()``."""
        params = repo.load(template)
        return cls(params, cfg, n_slots=n_slots, max_len=max_len,
                   repo=repo, **kwargs)

    def close(self) -> None:
        """Release engine resources (idempotent): drop queued and in-slot
        requests and release the owned weight repo's snapshot lease."""
        self.slot_req = [None] * self.n_slots
        self.slot_len[:] = 0
        while not self.queue.empty():
            try:
                self.queue.get_nowait()
            except queue.Empty:  # pragma: no cover - racing drain
                break
        self._finalizer()

    @property
    def closed(self) -> bool:
        """Whether :meth:`close` has run (weight snapshot lease released)."""
        return not self._finalizer.alive

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- slot management -----------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.closed:
            raise RuntimeError("engine is closed")
        self.queue.put(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or self.queue.empty():
                continue
            req = self.queue.get()
            t = len(req.prompt)
            # single-request prefill on a 1-lane cache, then splice into slot s
            lane = transformer.init_caches(self.cfg, 1, self.max_len,
                                           enc_len=self.enc_len)
            tok = jnp.asarray(req.prompt[None], jnp.int32)
            extra = {k: v[:1] for k, v in self.extra.items()}
            logits, lane, _ = self._prefill(self.params, tok, lane, extra)
            first = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(first)

            def splice(full, one):
                # the batch axis is wherever the 1-lane shape differs
                # (layer-stacked cache leaves carry leading scan dims)
                if full.shape == one.shape:
                    return one if self.n_slots == 1 else full
                axis = next(d for d in range(full.ndim)
                            if full.shape[d] != one.shape[d])
                start = [0] * full.ndim
                start[axis] = s
                return jax.lax.dynamic_update_slice(full,
                                                    one.astype(full.dtype),
                                                    start)

            self.caches = jax.tree.map(splice, self.caches, lane)
            self.slot_req[s] = req
            # cache holds t entries; the pending token writes at index t
            self.slot_len[s] = t

    # -- decode loop -----------------------------------------------------------

    def _sync_index(self) -> None:
        # per-slot cache indices (ragged lengths under continuous batching)
        self.caches = dict(self.caches)
        self.caches["index"] = jnp.asarray(self.slot_len, jnp.int32)

    def step(self) -> int:
        """One engine iteration: admit, decode all active slots, retire."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        self._sync_index()
        tok = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            tok[s, 0] = self.slot_req[s].out_tokens[-1]
        logits, self.caches, _ = self._decode(
            self.params, jnp.asarray(tok), self.caches,
            {k: v[: self.n_slots] for k, v in self.extra.items()})
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(nxt[s]))
            self.slot_len[s] += 1
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            if (len(req.out_tokens) >= req.max_new_tokens or hit_eos
                    or self.slot_len[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None
                self.slot_len[s] = 0
        return len(active)

    def run_until_drained(self, max_iters: int = 10_000) -> None:
        for _ in range(max_iters):
            if self.step() == 0 and self.queue.empty():
                return
        raise RuntimeError("serve loop did not drain")
