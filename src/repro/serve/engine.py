"""Serving engine: batched prefill + decode with continuous slot batching.

Slots are fixed (static shapes for jit); finished sequences free their slot
and the engine immediately prefill-admits the next queued request into it.
Per-slot KV caches live in one batched cache pytree, so decode is a single
jit'd step for the whole batch regardless of request boundaries.

Weights live in the Delta Tensor store as one FTSF tensor per param leaf;
:func:`load_weights` pulls the whole tree through one merged
``Catalog.read_many`` fetch plan on the shared
:class:`~repro.lake.io.ReadExecutor` — deduplicated keys, windowed
submission, per-leaf decode overlapping in-flight fetches — so cold-start
weight load time is the makespan of parallel object-store gets, not the
serial sum.
"""

from __future__ import annotations

import queue
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.store import DeltaTensorStore
from ..lake.io import ReadExecutor
from ..models import transformer
from ..models.config import ArchConfig


# -- weight load/store -------------------------------------------------------

from ..dist.sharding import _path_str as _leaf_name


def save_weights(store: DeltaTensorStore, params: Any, *,
                 prefix: str = "serve_weights") -> List[str]:
    """Persist a param pytree: one FTSF tensor per leaf, one atomic commit.

    One :class:`~repro.core.batch.WriteBatch` holds the whole generation;
    re-saving under the same prefix atomically replaces the previous one
    (old files are removed in the same commit — a reader never sees two
    generations of one leaf).
    """
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    with store.batch(op=f"SAVE WEIGHTS {prefix}") as batch:
        tids = [batch.put(np.asarray(leaf), tensor_id=f"{prefix}/{_leaf_name(path)}",
                          layout="ftsf", overwrite=True)
                for path, leaf in leaves]
    return tids


def load_weights(store: DeltaTensorStore, template: Any, *,
                 prefix: str = "serve_weights",
                 io: Optional[ReadExecutor] = None) -> Any:
    """Load a param pytree saved by :func:`save_weights`.

    ``template`` (e.g. ``jax.eval_shape`` of ``init_params``, or a real
    params pytree) supplies the tree structure and leaf dtypes. The whole
    tree loads through ONE merged fetch plan
    (:meth:`~repro.core.catalog.Catalog.read_many`) against one pinned
    catalog — a consistent weight generation even if a re-save lands
    mid-load, with any chunk file shared across leaves fetched once and
    each leaf decoding as soon as its last file arrives.
    """
    io = io or store.io
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    catalog = store.catalog()
    arrays = catalog.read_many(
        [(f"{prefix}/{_leaf_name(p)}", None) for p, _ in flat])
    out = [arr.astype(np.dtype(leaf.dtype), copy=False)
           for arr, (_, leaf) in zip(arrays, flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, *, n_slots: int, max_len: int,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 enc_len: int = 1):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.enc_len = enc_len
        self.extra = extra_inputs or {}
        self.caches = transformer.init_caches(cfg, n_slots, max_len,
                                              enc_len=enc_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()

        self._decode = jax.jit(
            lambda params, tok, caches, extra: transformer.decode_step(
                params, cfg, tok, caches, **extra))
        # prefill one slot at a time (batch=1 lane), written into the slot
        self._prefill = jax.jit(
            lambda params, tok, caches, extra: transformer.prefill(
                params, cfg, tok, caches, **extra),
            static_argnames=())

    # -- slot management -----------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.put(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or self.queue.empty():
                continue
            req = self.queue.get()
            t = len(req.prompt)
            # single-request prefill on a 1-lane cache, then splice into slot s
            lane = transformer.init_caches(self.cfg, 1, self.max_len,
                                           enc_len=self.enc_len)
            tok = jnp.asarray(req.prompt[None], jnp.int32)
            extra = {k: v[:1] for k, v in self.extra.items()}
            logits, lane, _ = self._prefill(self.params, tok, lane, extra)
            first = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(first)

            def splice(full, one):
                # the batch axis is wherever the 1-lane shape differs
                # (layer-stacked cache leaves carry leading scan dims)
                if full.shape == one.shape:
                    return one if self.n_slots == 1 else full
                axis = next(d for d in range(full.ndim)
                            if full.shape[d] != one.shape[d])
                start = [0] * full.ndim
                start[axis] = s
                return jax.lax.dynamic_update_slice(full,
                                                    one.astype(full.dtype),
                                                    start)

            self.caches = jax.tree.map(splice, self.caches, lane)
            self.slot_req[s] = req
            # cache holds t entries; the pending token writes at index t
            self.slot_len[s] = t

    # -- decode loop -----------------------------------------------------------

    def _sync_index(self) -> None:
        # per-slot cache indices (ragged lengths under continuous batching)
        self.caches = dict(self.caches)
        self.caches["index"] = jnp.asarray(self.slot_len, jnp.int32)

    def step(self) -> int:
        """One engine iteration: admit, decode all active slots, retire."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        self._sync_index()
        tok = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            tok[s, 0] = self.slot_req[s].out_tokens[-1]
        logits, self.caches, _ = self._decode(
            self.params, jnp.asarray(tok), self.caches,
            {k: v[: self.n_slots] for k, v in self.extra.items()})
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(nxt[s]))
            self.slot_len[s] += 1
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            if (len(req.out_tokens) >= req.max_new_tokens or hit_eos
                    or self.slot_len[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None
                self.slot_len[s] = 0
        return len(active)

    def run_until_drained(self, max_iters: int = 10_000) -> None:
        for _ in range(max_iters):
            if self.step() == 0 and self.queue.empty():
                return
        raise RuntimeError("serve loop did not drain")
