"""Multi-tenant serving gateway: admission control over the tensor store.

``serve.engine`` knows how to load one weight tree; the north star is a
store serving *heavy traffic from millions of users*. This module is the
layer between request traffic and the store that makes that workload
survivable:

* **per-tenant quotas + weighted fair queueing** — every piece of work
  (weight loads, reads) is submitted on behalf of a tenant with a
  :class:`TenantPolicy`; a start-time-fair-queueing scheduler dispatches
  from per-tenant bounded queues by virtual start tag, so a flooding
  tenant cannot starve the others and each tenant's share tracks its
  weight;
* **cold-start coalescing** — concurrent ``load_model`` calls for the
  same ``(prefix, version)`` share ONE single-flight
  :meth:`~repro.serve.repo.ModelRepo.load` (one merged ``read_many``
  plan, the delta-variant base chunks fetched once); the flight key pins
  the resolved version vector, so two tenants joining one flight get
  byte-identical trees even when a re-save lands mid-load;
* **cache partitioning** — each tenant's policy names a block-cache
  priority class (:meth:`repro.lake.io.BlockCache.add_partition`); hot
  base-model weights live in a budgeted partition long-tail variant
  churn can never evict;
* **tail-latency SLOs** — per-tenant latency histograms on the virtual
  clock, per-tenant p99 targets wired onto the executor's request
  hedging (an explicit ``hedge_after_s``, or derived from the p99
  target), and **overload shedding**: a full tenant queue rejects with
  :class:`RetryAfter` (carrying an advisory backoff) instead of queueing
  into collapse.

``benchmarks/bench_serve_traffic.py`` drives an open-loop mixed
cold-start/warm workload across many tenants through this gateway and
gates p99, the Jain fairness index, and the coalescing hit-rate in CI.
"""

from __future__ import annotations

import threading
import weakref
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.store import DeltaTensorStore, VersionArg
from ..lake.io import LatencyHistogram
from .repo import ModelRepo

DEFAULT_PARTITION = "default"


def jain_index(values: Sequence[float]) -> Optional[float]:
    """Jain's fairness index over per-tenant allocations.

    ``(sum x)^2 / (n * sum x^2)`` — 1.0 when every tenant got an equal
    share, ``1/n`` when one tenant got everything. None for empty input.
    """
    xs = [float(v) for v in values]
    if not xs:
        return None
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sq)


class RetryAfter(RuntimeError):
    """Admission rejected: the tenant's queue is full (overload shedding).

    Carries an advisory ``retry_after_s`` (backlog / service rate, from
    the tenant's observed mean latency) — the gateway's equivalent of an
    HTTP 429 + Retry-After header. Bounded queues + rejection keep an
    overloaded gateway at its capacity instead of collapsing under an
    unbounded backlog.
    """

    def __init__(self, tenant: str, retry_after_s: float):
        self.tenant = tenant
        self.retry_after_s = float(retry_after_s)
        super().__init__(
            f"tenant {tenant!r} queue full; retry after "
            f"{self.retry_after_s:.3f}s")


@dataclass(frozen=True)
class TenantPolicy:
    """Admission/quota/SLO knobs for one tenant.

    ``weight`` sets the tenant's fair share under contention;
    ``max_inflight`` caps its concurrently executing requests;
    ``queue_limit`` bounds its wait queue (beyond it, submissions shed
    with :class:`RetryAfter`). ``p99_target_s`` is the tenant's
    tail-latency SLO: reported in :meth:`Gateway.slo_report` and — when
    ``hedge_after_s`` is not set explicitly — used to derive a hedge
    threshold of half the target. ``cache_partition`` names the
    block-cache priority class this tenant's reads fill (create it via
    ``Gateway(partitions={...})``).
    """

    weight: float = 1.0
    max_inflight: int = 2
    queue_limit: int = 64
    p99_target_s: Optional[float] = None
    hedge_after_s: Optional[float] = None
    cache_partition: str = DEFAULT_PARTITION

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.queue_limit < 0:
            raise ValueError("queue_limit must be >= 0")

    @property
    def effective_hedge_s(self) -> Optional[float]:
        """Hedge threshold: explicit, else half the p99 target, else off."""
        if self.hedge_after_s is not None:
            return self.hedge_after_s
        if self.p99_target_s is not None:
            return 0.5 * self.p99_target_s
        return None


@dataclass
class _Job:
    """One admitted unit of work waiting in a tenant queue."""

    fn: Callable[[], Any]
    future: Future
    cost: float
    stag: float           # SFQ virtual start tag
    t_enqueue: float      # clock() at submission (queueing counts in SLO)


class _TenantState:
    """Scheduler-side bookkeeping for one tenant."""

    __slots__ = ("name", "policy", "queue", "inflight", "vfinish",
                 "admitted", "completed", "failed", "rejected", "coalesced",
                 "work_done", "latency")

    def __init__(self, name: str, policy: TenantPolicy):
        self.name = name
        self.policy = policy
        self.queue: "deque[_Job]" = deque()
        self.inflight = 0
        self.vfinish = 0.0     # finish tag of this tenant's last-tagged job
        self.admitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.coalesced = 0
        self.work_done = 0.0
        self.latency = LatencyHistogram()


class Gateway:
    """Admission/scheduling layer between request traffic and the store.

    ``max_inflight`` bounds total concurrently executing requests (the
    gateway's service capacity — its private worker pool size).
    ``partitions`` maps block-cache priority-class names to byte budgets
    — an int, or ``{"bytes": n, "pinned": True}`` for a pinned class that
    rejects overflow instead of evicting (hot-base weights) — created on
    the store's executor at construction and nameable from tenant
    policies. ``clock`` timestamps per-request latency — benchmarks pass
    the modeled store's virtual clock. ``default_policy`` applies to
    tenants that were never :meth:`register`\\ ed.

    Lifecycle matches ``TensorRef``/``StreamLoader``/``ModelRepo``:
    ``close()``, context manager, and a GC weakref finalizer all release
    the worker pool; queued work is cancelled with :class:`RetryAfter`.
    """

    def __init__(self, store: DeltaTensorStore, *, max_inflight: int = 8,
                 partitions: Optional[Dict[str, int]] = None,
                 default_policy: Optional[TenantPolicy] = None,
                 load_cost: float = 4.0,
                 clock: Optional[Callable[[], float]] = None):
        self.store = store
        self.max_inflight = max(1, int(max_inflight))
        self.default_policy = default_policy or TenantPolicy()
        self.load_cost = float(load_cost)
        self.clock = clock or _default_clock()
        for name, spec in (partitions or {}).items():
            if isinstance(spec, dict):
                store.io.cache.add_partition(
                    name, int(spec["bytes"]),
                    pinned=bool(spec.get("pinned", False)))
            else:
                store.io.cache.add_partition(name, int(spec))
        self._lock = threading.RLock()
        self._tenants: Dict[str, _TenantState] = {}
        self._flights: Dict[Tuple[str, Tuple[int, ...]], Future] = {}
        self._vtime = 0.0
        self._inflight = 0
        self._closed = False
        self._flights_created = 0
        self._coalesced_total = 0
        self._pool = ThreadPoolExecutor(max_workers=self.max_inflight,
                                        thread_name_prefix="gateway")
        self._finalizer = weakref.finalize(self, self._pool.shutdown, False)

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        """Stop admitting, cancel queued work, release the pool (idempotent).

        In-flight requests run to completion; queued (not yet dispatched)
        jobs fail with :class:`RetryAfter`.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            dropped: List[_Job] = []
            for st in self._tenants.values():
                dropped.extend(st.queue)
                st.queue.clear()
        for job in dropped:
            job.future.set_exception(RetryAfter("<gateway closed>", 0.0))
        self._finalizer()

    @property
    def closed(self) -> bool:
        """Whether :meth:`close` has run (worker pool released)."""
        return self._closed or not self._finalizer.alive

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- tenants ---------------------------------------------------------------

    def register(self, tenant: str, policy: TenantPolicy) -> None:
        """Attach ``policy`` to ``tenant`` (before or between requests)."""
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                self._tenants[tenant] = _TenantState(tenant, policy)
            else:
                st.policy = policy

    def _tenant(self, name: str) -> _TenantState:
        st = self._tenants.get(name)
        if st is None:
            st = self._tenants[name] = _TenantState(name, self.default_policy)
        return st

    # -- admission + weighted fair queueing ------------------------------------

    def submit(self, tenant: str, fn: Callable[[], Any], *,
               cost: float = 1.0) -> "Future[Any]":
        """Admit one unit of work for ``tenant``; returns its Future.

        Work is tagged with a start-time-fair-queueing virtual tag
        (``max(V, tenant's last finish)``; finish = start +
        ``cost/weight``) and dispatched lowest-tag-first whenever the
        gateway and the tenant both have an inflight slot free — under
        contention each tenant's throughput share tracks its weight
        regardless of arrival order. A full tenant queue sheds the
        request with :class:`RetryAfter` instead of growing the backlog.
        """
        cost = max(float(cost), 1e-9)
        with self._lock:
            if self._closed:
                raise RuntimeError("gateway is closed")
            st = self._tenant(tenant)
            pol = st.policy
            no_slot = (self._inflight >= self.max_inflight
                       or st.inflight >= pol.max_inflight)
            if no_slot and len(st.queue) >= pol.queue_limit:
                st.rejected += 1
                raise RetryAfter(tenant, self._retry_after_locked(st))
            stag = max(self._vtime, st.vfinish)
            st.vfinish = stag + cost / pol.weight
            job = _Job(fn=fn, future=Future(), cost=cost, stag=stag,
                       t_enqueue=self.clock())
            st.queue.append(job)
            st.admitted += 1
            self._dispatch_locked()
            return job.future

    def _retry_after_locked(self, st: _TenantState) -> float:
        # advisory backoff: backlog ahead of the caller / service rate,
        # from the tenant's observed mean latency (floor: one mean)
        mean = st.latency.mean or 0.010
        backlog = len(st.queue) + st.inflight
        return mean * max(1.0, backlog / st.policy.max_inflight)

    def _dispatch_locked(self) -> None:
        while self._inflight < self.max_inflight:
            best: Optional[_TenantState] = None
            for st in self._tenants.values():
                if not st.queue or st.inflight >= st.policy.max_inflight:
                    continue
                if best is None or st.queue[0].stag < best.queue[0].stag:
                    best = st
            if best is None:
                return
            job = best.queue.popleft()
            best.inflight += 1
            self._inflight += 1
            self._vtime = max(self._vtime, job.stag)
            self._pool.submit(self._run, best, job)

    def _run(self, st: _TenantState, job: _Job) -> None:
        hedge = st.policy.effective_hedge_s
        try:
            if hedge is not None:
                result = self.store.io.hedged(job.fn, hedge_after_s=hedge)
            else:
                result = job.fn()
            err: Optional[BaseException] = None
        except BaseException as e:  # surfaced via the future
            result, err = None, e
        done = self.clock()
        with self._lock:
            st.inflight -= 1
            self._inflight -= 1
            st.latency.observe(done - job.t_enqueue)
            if err is None:
                st.completed += 1
                st.work_done += job.cost
            else:
                st.failed += 1
            if not self._closed:
                self._dispatch_locked()
        if err is None:
            job.future.set_result(result)
        else:
            job.future.set_exception(err)

    # -- serving verbs ---------------------------------------------------------

    def load_model(self, tenant: str, prefix: str, template: Any, *,
                   version: VersionArg = None) -> "Future[Any]":
        """Cold-start a model: coalesced, admission-controlled weight load.

        Resolves ``(prefix, version)`` to a concrete pinned version
        vector FIRST, then joins (or creates) the single-flight for that
        key: N concurrent tenants cold-starting one model share one
        :meth:`ModelRepo.load <repro.serve.repo.ModelRepo.load>` — one
        merged fetch plan, each chunk (and each delta-variant base
        chunk) fetched once — and all receive the same pinned
        generation, byte-identical, even if a re-save lands mid-flight.
        A save that commits *before* a later call resolves simply maps
        that call to a new key: fresh flight, fresh weights. Blocks land
        in the calling tenant's cache partition.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("gateway is closed")
            vector = self.store.catalog(version).version_vector
            key = (prefix, vector)
            flight = self._flights.get(key)
            if flight is not None:
                st = self._tenant(tenant)
                st.coalesced += 1
                self._coalesced_total += 1
                return flight
            part = self._tenant(tenant).policy.cache_partition

            def do_load() -> Any:
                with ModelRepo(self.store, prefix, version=vector) as repo:
                    return repo.load(template, cache_partition=part)

            fut = self.submit(tenant, do_load, cost=self.load_cost)
            self._flights[key] = fut
            self._flights_created += 1
        fut.add_done_callback(lambda _f: self._drop_flight(key))
        return fut

    def _drop_flight(self, key: Tuple[str, Tuple[int, ...]]) -> None:
        with self._lock:
            self._flights.pop(key, None)

    def read(self, tenant: str, tid: str,
             slices: Optional[Sequence] = None, *,
             version: VersionArg = None) -> "Future[Any]":
        """Admission-controlled tensor (or slice) read for ``tenant``.

        Pass a concrete ``version`` (vector) for warm-path reads: the
        pinned catalog is cached, so a fully block-cached read issues
        zero object-store requests. Blocks land in the tenant's cache
        partition — a hot tenant's base-model reads refill (and are
        protected by) its priority class.
        """
        part = self._tenant(tenant).policy.cache_partition
        return self.submit(
            tenant,
            lambda: self.store.read_many([(tid, slices)], version=version,
                                         cache_partition=part)[0])

    # -- observability ---------------------------------------------------------

    def tenant_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant counters + latency summary (admission, shedding,
        coalescing, fair-share work done, p50/p95/p99)."""
        with self._lock:
            return {name: {"admitted": st.admitted,
                           "completed": st.completed,
                           "failed": st.failed,
                           "rejected": st.rejected,
                           "coalesced": st.coalesced,
                           "queued": len(st.queue),
                           "inflight": st.inflight,
                           "work_done": st.work_done,
                           "weight": st.policy.weight,
                           "latency": st.latency.summary()}
                    for name, st in self._tenants.items()}

    def stats(self) -> Dict[str, Any]:
        """Gateway-wide counters: flights, coalescing, inflight, shed."""
        with self._lock:
            return {"tenants": len(self._tenants),
                    "inflight": self._inflight,
                    "max_inflight": self.max_inflight,
                    "flights_created": self._flights_created,
                    "coalesced_hits": self._coalesced_total,
                    "open_flights": len(self._flights),
                    "rejected": sum(st.rejected
                                    for st in self._tenants.values()),
                    "cache_partitions": self.store.io.cache.partitions()}

    def slo_report(self) -> Dict[str, Dict[str, Any]]:
        """Per-tenant p99 vs target: ``{p99_s, target_s, met, hedge_s}``.

        ``met`` is None when the tenant has no target or no samples.
        """
        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            tenants = list(self._tenants.items())
        for name, st in tenants:
            p99 = st.latency.p99()
            target = st.policy.p99_target_s
            met = (None if target is None or p99 is None
                   else bool(p99 <= target))
            out[name] = {"p99_s": p99, "target_s": target, "met": met,
                         "hedge_s": st.policy.effective_hedge_s}
        return out

    def fairness(self, tenants: Optional[Sequence[str]] = None,
                 metric: str = "work_done") -> Optional[float]:
        """Jain fairness index over per-tenant ``work_done`` (weighted:
        each tenant's share is divided by its policy weight first, so
        perfect weighted-fair service scores 1.0)."""
        with self._lock:
            states = [self._tenants[t] for t in tenants] if tenants \
                else list(self._tenants.values())
            vals = [getattr(st, metric) / st.policy.weight for st in states]
        return jain_index(vals)


def _default_clock() -> Callable[[], float]:
    import time
    return time.perf_counter
