"""ModelRepo: snapshot-pinned handle over one prefix of serve weights.

The old serving surface was a pair of stringly-typed free functions
(``serve.engine.save_weights(store, params, prefix=...)`` /
``load_weights(store, template, prefix=...)``): every call re-spelled the
prefix, nothing was pinned between calls, and the delta-variant write path
(``store.put_variant``) had no weights-level wrapper at all.
:class:`ModelRepo` is the handle redesign, mirroring
:class:`~repro.core.catalog.TensorRef`:

* ``store.models(prefix)`` returns a repo **pinned to one catalog
  snapshot** and holding a lease on it — concurrent re-saves and vacuum
  cannot change (or delete) what this handle reads. ``save`` advances the
  pin to the just-committed generation; ``refresh()`` re-pins at latest.
* ``repo.save(params)`` persists a param pytree — one FTSF tensor per
  leaf under ``<prefix>/<leaf>``, ONE atomic commit, old generation
  replaced in the same commit (a reader never sees two generations).
* ``repo.load(template)`` reads the whole tree through ONE merged
  :meth:`~repro.core.catalog.Catalog.read_many` fetch plan against the
  pinned catalog — deduplicated chunk keys, per-leaf decode overlapping
  in-flight fetches.
* ``repo.open_variant(name)`` returns a repo for ``<prefix>~<name>``
  whose ``save`` stores each leaf via
  :meth:`~repro.core.batch.WriteBatch.put_variant` against this repo's
  leaves — fine-tunes land as XOR byte-deltas of the base's chunks (the
  content-addressed variant path), and load back transparently.

The old free functions survive as deprecated shims over this class.
"""

from __future__ import annotations

import weakref
from typing import (TYPE_CHECKING, Any, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import numpy as np

from ..core.catalog import Catalog
from ..dist.sharding import _path_str as _leaf_name
from ..lake.io import ReadExecutor
from ..lake.log import ObjectNotFoundError

if TYPE_CHECKING:  # pragma: no cover - import cycle is typing-only
    from ..core.store import DeltaTensorStore, VersionArg


class ModelRepo:
    """Snapshot-pinned, lease-holding handle to one model's weight tree.

    Construct via ``store.models(prefix)``. The repo pins the store's
    catalog at construction (latest, or an explicit ``version=``) and
    leases that version vector until ``close()`` / context-manager exit /
    garbage collection — the same lifecycle every ``TensorRef`` and
    ``StreamLoader`` has. A repo over a prefix with no saved weights is
    valid (``exists()`` is False); the first ``save`` pins it.
    """

    def __init__(self, store: "DeltaTensorStore", prefix: str, *,
                 version: "VersionArg" = None,
                 base: Optional["ModelRepo"] = None):
        if not prefix:
            raise ValueError("model prefix must be a non-empty string")
        self.store = store
        self.prefix = prefix
        self._base = base
        self._catalog: Optional[Catalog] = None
        self._lease = None
        self._finalizer = weakref.finalize(self, lambda: None)
        self._pin(version)

    # -- lifecycle -------------------------------------------------------------

    def _pin(self, version: "VersionArg") -> None:
        """(Re)pin the repo's catalog snapshot, swapping the held lease."""
        try:
            catalog = self.store.catalog(version)
        except ObjectNotFoundError:
            if version is not None:
                raise
            catalog = None  # store has no table yet; first save pins
        old = self._finalizer
        if catalog is not None:
            self._catalog = catalog
            self._lease = self.store.leases.acquire(catalog.version_vector)
            self._finalizer = weakref.finalize(self, self._lease.release)
        else:
            self._finalizer = weakref.finalize(self, lambda: None)
        old()  # release the previous generation's lease (idempotent)

    def close(self) -> None:
        """Release the pinned snapshot lease (idempotent)."""
        self._finalizer()

    @property
    def closed(self) -> bool:
        """Whether the snapshot lease has been released."""
        return not self._finalizer.alive

    def __enter__(self) -> "ModelRepo":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def refresh(self) -> "ModelRepo":
        """Re-pin at the store's latest snapshot; returns self."""
        self._pin(None)
        return self

    # -- introspection ---------------------------------------------------------

    @property
    def version(self):
        """Pinned version (vector on sharded stores); None before any pin."""
        return None if self._catalog is None else self._catalog.version

    @property
    def base(self) -> Optional["ModelRepo"]:
        """The base repo this one stores delta variants against, if any."""
        return self._base

    def _tid(self, name: str) -> str:
        return f"{self.prefix}/{name}"

    def leaf_ids(self) -> List[str]:
        """Sorted tensor ids under this prefix at the pinned snapshot."""
        if self._catalog is None:
            return []
        want = self.prefix + "/"
        return [tid for tid in self._catalog if tid.startswith(want)]

    def exists(self) -> bool:
        """Whether the pinned snapshot holds any weights for this prefix."""
        return bool(self.leaf_ids())

    def __repr__(self) -> str:
        kind = f" variant-of={self._base.prefix!r}" if self._base else ""
        return (f"ModelRepo({self.prefix!r}, version={self.version}{kind}, "
                f"{'closed' if self.closed else 'live'})")

    # -- writes ----------------------------------------------------------------

    def save(self, params: Any) -> List[str]:
        """Persist a param pytree: one FTSF tensor per leaf, ONE commit.

        Re-saving atomically replaces the previous generation (old files
        removed in the same commit). On a variant repo each leaf stages
        via ``put_variant`` against the base repo's same-named leaf —
        identical chunks dedup to references, changed chunks store as XOR
        deltas. The repo re-pins to the just-committed snapshot, so a
        following ``load`` reads what was saved. Returns the leaf ids.
        """
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        with self.store.batch(op=f"SAVE WEIGHTS {self.prefix}") as batch:
            tids = []
            for path, leaf in leaves:
                name = _leaf_name(path)
                arr = np.asarray(leaf)
                if self._base is not None:
                    tids.append(batch.put_variant(
                        arr, base_tid=self._base._tid(name),
                        tensor_id=self._tid(name), overwrite=True))
                else:
                    tids.append(batch.put(arr, tensor_id=self._tid(name),
                                          layout="ftsf", overwrite=True))
        self._pin(None)
        return tids

    def open_variant(self, name: str, *,
                     version: "VersionArg" = None) -> "ModelRepo":
        """A repo for ``<prefix>~<name>`` storing deltas against this one.

        ``variant.save(params)`` writes each leaf as a delta variant of
        this repo's same-named leaf; ``variant.load`` reconstructs
        transparently (the read path XORs the base back). The variant is
        an ordinary model afterwards — same handles, deletes, vacuum
        refcounting.
        """
        return ModelRepo(self.store, f"{self.prefix}~{name}",
                         version=version, base=self)

    # -- reads -----------------------------------------------------------------

    def _requests(self, template: Any) -> Tuple[
            List[Tuple[str, Optional[Sequence]]], Any, List[Any]]:
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        reqs = [(self._tid(_leaf_name(p)), None) for p, _ in flat]
        return reqs, treedef, [leaf for _, leaf in flat]

    def load(self, template: Any, *, version: "VersionArg" = None,
             io: Optional[ReadExecutor] = None,
             cache_partition: Optional[str] = None) -> Any:
        """Load the weight tree shaped/typed like ``template``.

        ``template`` (e.g. ``jax.eval_shape`` of the init function, or a
        real params pytree) supplies tree structure and leaf dtypes. The
        whole tree loads through ONE merged fetch plan against the
        repo's pinned catalog — a consistent generation even if a
        re-save lands mid-load. ``version=`` reads a different pinned
        snapshot (time travel) without re-pinning the repo; ``io=``
        overrides the store's shared executor; ``cache_partition``
        routes the fetched blocks into that block-cache priority class
        (the gateway pins hot base models into a protected partition).
        """
        catalog = (self._catalog if version is None
                   else self.store.catalog(version))
        if catalog is None:
            raise KeyError(f"no weights saved under prefix "
                           f"{self.prefix!r} (empty store)")
        reqs, treedef, leaves = self._requests(template)
        arrays = catalog.read_many(reqs, io=io,
                                   cache_partition=cache_partition)
        out = [arr.astype(np.dtype(leaf.dtype), copy=False)
               for arr, leaf in zip(arrays, leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def stats(self) -> Dict[str, Any]:
        """Pinned-snapshot inventory: leaf count and stored bytes."""
        leaves = self.leaf_ids()
        nbytes = 0
        if self._catalog is not None:
            nbytes = sum(self._catalog.entry(t).nbytes for t in leaves)
        return {"prefix": self.prefix, "version": self.version,
                "leaves": len(leaves), "stored_bytes": nbytes,
                "is_variant": self._base is not None}
