from .engine import Request, ServeEngine, load_weights, save_weights

__all__ = ["Request", "ServeEngine", "load_weights", "save_weights"]
