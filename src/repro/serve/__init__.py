"""Serving layer: weight handles, the inference engine, and the gateway.

``repo.ModelRepo`` (via ``store.models(prefix)``) is the weights API,
``engine.ServeEngine`` runs continuous-batching inference, and
``gateway.Gateway`` is the multi-tenant admission/scheduling layer in
front of the store. ``save_weights`` / ``load_weights`` are deprecated
shims kept for existing callers.
"""

from .engine import Request, ServeEngine, load_weights, save_weights
from .gateway import Gateway, RetryAfter, TenantPolicy, jain_index
from .repo import ModelRepo

__all__ = [
    "Gateway",
    "ModelRepo",
    "Request",
    "RetryAfter",
    "ServeEngine",
    "TenantPolicy",
    "jain_index",
    "load_weights",
    "save_weights",
]
