"""Streaming training-speed data loader over the Delta Tensor store.

The paper optimizes one-shot tensor reads; the north-star workload is
*feeding a training loop at hardware speed* (Deep Lake's central claim: a
lakehouse can stream batches as fast as local disk). :class:`StreamLoader`
is that read path:

* **epoch pinning**: the loader leases one catalog snapshot (version
  vector) for its lifetime — a concurrent writer appending to the dataset
  tables changes nothing this loader reads, and vacuum cannot delete its
  files. Re-create the loader (or open a new one per epoch) to pick up
  freshly ingested data;
* **shard-aware shuffled sampling**: samples are the union of rows across
  one or more tensors (all sharing trailing shape + dtype); each epoch's
  order is a seeded deterministic shuffle that *interleaves* shard groups
  proportionally, so every batch spreads its reads across the store's
  shard tables instead of hammering one table's files at a time;
* **windowed prefetch**: up to ``window`` whole batches are in flight as
  jobs on the shared :class:`~repro.lake.io.ReadExecutor` work pool.
  Submission happens only as the consumer drains, so a stalled training
  step applies backpressure structurally and peak prefetch memory is
  bounded by ``window × batch_bytes`` (tracked in
  ``peak_inflight_bytes``);
* **merged batch fetch**: each batch's rows coalesce into per-tensor
  contiguous runs and fetch through ONE
  :meth:`~repro.core.catalog.Catalog.read_many` plan — shared chunk files
  dedup to a single get, decode overlaps in-flight fetches;
* **resumability**: the epoch plan is a pure function of ``(seed,
  epoch)``, so a ``(epoch, step)`` cursor restarts the stream mid-epoch
  bit-for-bit (elastic training restarts).

:class:`~repro.data.pipeline.FTSFLoader` is now a thin compatibility shim
over this class.
"""

from __future__ import annotations

import time
import weakref
from collections import OrderedDict
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from ..core.encodings.base import header_dtype, header_shape
from ..core.store import DeltaTensorStore, VersionArg
from ..lake.io import LatencyHistogram, ReadExecutor

Cursor = Tuple[int, int]  # (epoch, step within epoch)


class StreamLoader:
    """Epoch-pinned shuffled streaming reader (see module docstring).

    ``tensors`` is one tensor id or a list of them; every tensor's leading
    dimension indexes samples and all must share trailing shape and dtype
    (they may live in different store shards — that is the point: the
    shuffle interleaves them). Host ``host_index`` of ``n_hosts`` owns the
    sample subset ``h::H`` of the global id space.

    ``window`` bounds in-flight prefetched batches (and so prefetch
    memory: ``window × batch_size × row_nbytes``). ``device=True`` yields
    each batch as a jax device array (one transfer per batch off the
    reorder staging buffer; numpy when jax is absent or the dtype cannot
    be held bit-exactly — see :mod:`repro.lake.device`). ``epochs=None``
    streams forever. ``clock`` (default ``time.perf_counter``) timestamps
    per-batch fetch latency — benchmarks pass the virtual clock of a
    modeled store. ``close()`` releases the snapshot lease; the loader is
    a context manager and a dropped loader is finalized by GC (mirroring
    :class:`~repro.core.catalog.TensorRef`).
    """

    def __init__(self, store: DeltaTensorStore,
                 tensors: Union[str, Sequence[str]], *,
                 batch_size: int, host_index: int = 0, n_hosts: int = 1,
                 seed: int = 0, window: int = 4,
                 epochs: Optional[int] = None,
                 start_cursor: Cursor = (0, 0),
                 version: VersionArg = None,
                 hedge_after_s: Optional[float] = None,
                 io: Optional[ReadExecutor] = None,
                 read_window: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 device: bool = False):
        self.store = store
        self.device = bool(device)
        self.tensor_ids: List[str] = (
            [tensors] if isinstance(tensors, str) else list(tensors))
        if not self.tensor_ids:
            raise ValueError("StreamLoader needs at least one tensor")
        self.batch = int(batch_size)
        self.host_index = int(host_index)
        self.n_hosts = int(n_hosts)
        self.seed = int(seed)
        self.window = max(1, int(window))
        self.epochs = epochs
        self.hedge_after_s = hedge_after_s
        self.read_window = read_window
        self.io = io or store.io
        self.clock = clock or time.perf_counter

        # pin the dataset generation: every batch this loader ever yields
        # comes from this one catalog snapshot, lease-protected from vacuum
        self.catalog = store.catalog(version)
        self._lease = store.leases.acquire(self.catalog.version_vector)
        self._finalizer = weakref.finalize(self, self._lease.release)

        # sample space: union of rows across tensors, global ids in tensor
        # order; headers are warmed here so batch fetches start plan-ready
        offsets = [0]
        shard_of: List[int] = []
        row_shape: Optional[Tuple[int, ...]] = None
        dtype: Optional[np.dtype] = None
        for tid in self.tensor_ids:
            header = self.catalog.header(tid)
            shape = header_shape(header)
            dt = np.dtype(header_dtype(header))
            if row_shape is None:
                row_shape, dtype = shape[1:], dt
            elif shape[1:] != row_shape or dt != dtype:
                raise ValueError(
                    f"tensor {tid!r} rows {shape[1:]}:{dt} incompatible "
                    f"with {row_shape}:{dtype}")
            shard_of.append(self.catalog.entry(tid).shard)
            offsets.append(offsets[-1] + shape[0])
        assert row_shape is not None and dtype is not None
        self.row_shape = tuple(int(d) for d in row_shape)
        self.dtype = dtype
        self.row_nbytes = int(np.prod(self.row_shape,
                                      dtype=np.int64)) * dtype.itemsize
        self.batch_bytes = self.batch * self.row_nbytes
        self._offsets = np.asarray(offsets, dtype=np.int64)

        self.owned = np.arange(int(self._offsets[-1]),
                               dtype=np.int64)[self.host_index::self.n_hosts]
        if len(self.owned) < self.batch:
            raise ValueError("fewer owned samples than batch size")
        self.steps_per_epoch = len(self.owned) // self.batch
        tensor_idx = np.searchsorted(self._offsets, self.owned,
                                     side="right") - 1
        self._owned_shard = np.asarray([shard_of[t] for t in tensor_idx],
                                       dtype=np.int64)

        self._cursor: Cursor = (int(start_cursor[0]), int(start_cursor[1]))
        self._head: Cursor = self._cursor  # next batch to *submit*
        self._pending: "OrderedDict[Cursor, Tuple[Any, float, np.ndarray]]" = \
            OrderedDict()
        self._plan_cache: Tuple[Optional[int], Optional[np.ndarray]] = (None, None)
        self.batch_latency = LatencyHistogram()
        self.batches_yielded = 0
        self.inflight_bytes = 0
        self.peak_inflight_bytes = 0

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        """Cancel prefetch and release the snapshot lease (idempotent)."""
        for fut, _, _ in self._pending.values():
            fut.cancel()
        self._pending.clear()
        self.inflight_bytes = 0
        self._finalizer()

    @property
    def closed(self) -> bool:
        """Whether the snapshot lease has been released."""
        return not self._finalizer.alive

    def reopen(self, *, version: VersionArg = None,
               start_cursor: Cursor = (0, 0)) -> "StreamLoader":
        """Hand off to a fresh loader pinned at ``version`` (latest if None).

        The streaming-ingest handoff: this loader's snapshot is frozen by
        design — rows an :class:`~repro.data.ingest.IngestWriter` commits
        after the pin are invisible to it. Between epochs, call
        ``loader = loader.reopen()`` to re-pin at the store's current
        latest: the new loader has identical configuration (batch size,
        host split, seed, window, ...), sees every row committed since,
        and restarts its epoch/step counters at ``start_cursor``. This
        loader is closed (its lease released) once the new one holds its
        own lease, so there is no window where vacuum could reclaim either
        generation's files.
        """
        new = StreamLoader(
            self.store, list(self.tensor_ids), batch_size=self.batch,
            host_index=self.host_index, n_hosts=self.n_hosts,
            seed=self.seed, window=self.window, epochs=self.epochs,
            start_cursor=start_cursor, version=version,
            hedge_after_s=self.hedge_after_s, io=self.io,
            read_window=self.read_window,
            clock=None if self.clock is time.perf_counter else self.clock,
            device=self.device)
        self.close()
        return new

    def __enter__(self) -> "StreamLoader":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- deterministic epoch plan ----------------------------------------------

    def _epoch_plan(self, epoch: int) -> np.ndarray:
        """This epoch's full sample order: a pure function of (seed, epoch).

        Owned samples are partitioned by the store shard their tensor
        lives in, shuffled *within* each shard group, then interleaved
        proportionally across groups: the k-th sample of a c-long group
        sorts at key (k+1)/c, so any batch-sized window of the plan
        touches every shard in proportion to its share of the data — no
        shard table becomes the batch's hot spot.
        """
        cached_epoch, cached = self._plan_cache
        if cached_epoch == epoch:
            return cached  # type: ignore[return-value]
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + epoch) & 0x7FFFFFFF)
        n = len(self.owned)
        key = np.empty(n, np.float64)
        tie = np.empty(n, np.int64)
        for s in np.unique(self._owned_shard):
            grp = np.flatnonzero(self._owned_shard == s)
            perm = grp[rng.permutation(len(grp))]
            key[perm] = (np.arange(len(grp), dtype=np.float64) + 1.0) / len(grp)
            tie[perm] = s
        plan = self.owned[np.lexsort((tie, key))]
        self._plan_cache = (epoch, plan)
        return plan

    def _rows_for(self, epoch: int, step: int) -> np.ndarray:
        if not 0 <= step < self.steps_per_epoch:
            raise IndexError(f"step {step} outside epoch "
                             f"(steps_per_epoch={self.steps_per_epoch})")
        plan = self._epoch_plan(epoch)
        return plan[step * self.batch:(step + 1) * self.batch]

    # -- batch fetch (runs in the executor's work pool) ------------------------

    def _fetch_batch(self, rows: np.ndarray) -> Tuple[np.ndarray, float]:
        """Assemble one batch: per-tensor runs -> one read_many plan."""
        tensor_idx = np.searchsorted(self._offsets, rows, side="right") - 1
        requests: List[Tuple[str, Optional[Sequence]]] = []
        placements: List[np.ndarray] = []
        for t in np.unique(tensor_idx):
            pos = np.flatnonzero(tensor_idx == t)
            local = rows[pos] - self._offsets[t]
            order = np.argsort(local, kind="stable")
            pos, local = pos[order], local[order]
            # coalesce consecutive rows into contiguous slice requests so
            # file pruning (and key dedup in the plan) sees ranges
            cuts = np.flatnonzero(np.diff(local) != 1) + 1
            starts = np.concatenate(([0], cuts))
            ends = np.concatenate((cuts, [len(local)]))
            for a, b in zip(starts, ends):
                lo, hi = int(local[a]), int(local[b - 1]) + 1
                requests.append((self.tensor_ids[int(t)], [(lo, hi)]))
                placements.append(pos[a:b])

        def fetch() -> List[np.ndarray]:
            return self.catalog.read_many(requests, window=self.read_window)

        if self.hedge_after_s is not None:
            arrays = self.io.hedged(fetch, hedge_after_s=self.hedge_after_s)
        else:
            arrays = fetch()
        out = np.empty((len(rows),) + self.row_shape, self.dtype)
        for arr, pos in zip(arrays, placements):
            out[pos] = arr
        if self.device:
            # one staging buffer (needed anyway for the shuffle reorder),
            # one transfer: the batch first exists ordered on the device
            from ..lake import device as lake_device
            dev = lake_device.to_device(out)
            if lake_device.is_device_array(dev):
                self.io.stats.bump(bytes_to_device=int(out.nbytes))
            return dev, self.clock()
        return out, self.clock()

    # -- streaming -------------------------------------------------------------

    @property
    def cursor(self) -> Cursor:
        """``(epoch, step)`` of the next batch to yield — checkpoint this
        and pass it back as ``start_cursor`` to resume bit-for-bit."""
        return self._cursor

    def seek(self, epoch: int, step: int) -> None:
        """Reposition the stream (drops any prefetched batches)."""
        for fut, _, _ in self._pending.values():
            fut.cancel()
        self._pending.clear()
        self.inflight_bytes = 0
        self._cursor = self._head = (int(epoch), int(step))

    def _advance(self, cur: Cursor) -> Cursor:
        epoch, step = cur
        step += 1
        return (epoch + 1, 0) if step >= self.steps_per_epoch else (epoch, step)

    def _in_range(self, cur: Cursor) -> bool:
        return self.epochs is None or cur[0] < self.epochs

    def _submit(self, cur: Cursor) -> None:
        rows = self._rows_for(*cur)  # plan built on the consumer thread
        self._pending[cur] = (self.io.submit(self._fetch_batch, rows),
                              self.clock(), rows)
        self.inflight_bytes += self.batch_bytes
        if self.inflight_bytes > self.peak_inflight_bytes:
            self.peak_inflight_bytes = self.inflight_bytes

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        """Yield batches: ``{"data", "samples", "epoch", "step"}``.

        ``data`` is ``(batch_size, *row_shape)`` in plan order,
        ``samples`` the global sample ids it holds, ``step`` the global
        step (``epoch * steps_per_epoch + step_in_epoch``). Keeps at most
        ``window`` batches in flight; a slow consumer stalls submission,
        not the executor.
        """
        while not self.closed and self._in_range(self._cursor):
            while len(self._pending) < self.window and self._in_range(self._head):
                self._submit(self._head)
                self._head = self._advance(self._head)
            cur = self._cursor
            fut, t_submit, rows = self._pending.pop(cur)
            data, t_done = fut.result()
            self.inflight_bytes -= self.batch_bytes
            # submit -> ready: the consumer-visible fetch latency of this
            # batch (virtual seconds when clock= is a modeled store's)
            self.batch_latency.observe(t_done - t_submit)
            self.batches_yielded += 1
            epoch, step = cur
            self._cursor = self._advance(cur)
            yield {"data": data,
                   "samples": rows,
                   "epoch": epoch,
                   "step": epoch * self.steps_per_epoch + step}

    # -- reporting -------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Loader-side counters + per-batch fetch-latency percentiles."""
        return {"batches_yielded": self.batches_yielded,
                "steps_per_epoch": self.steps_per_epoch,
                "window": self.window,
                "batch_bytes": self.batch_bytes,
                "inflight_bytes": self.inflight_bytes,
                "peak_inflight_bytes": self.peak_inflight_bytes,
                "memory_bound_bytes": self.window * self.batch_bytes,
                "batch_latency": self.batch_latency.summary()}
