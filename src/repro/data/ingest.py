"""IngestWriter — streaming appends with watermark commits.

The paper's write path is batch ``put``: one tensor, one commit. The
north-star production store must also absorb *ever-growing datasets* while
training reads stream concurrently (the ingest half of Deep Lake's core
claim; the loader half is :class:`~repro.data.stream.StreamLoader`).
:class:`IngestWriter` is that write path:

* **micro-batching**: ``append_rows(rows)`` buffers sample rows in memory;
  nothing is uploaded until a **watermark** trips — ``watermark_rows``
  buffered rows, or ``watermark_s`` seconds since the buffer's first row
  (checked on every append; call :meth:`poll` from an idle producer loop
  to honor the time watermark without new data). ``flush()`` forces it;
* **sealing**: a flush seals the buffer into framed FTSF chunk rows —
  row ``i`` of the buffer becomes chunk ``row_count + i`` of the tensor —
  split into ~``target_file_bytes`` part files through the existing
  two-phase :meth:`~repro.lake.table.DeltaTable.append_split` upload path
  (upload guard registered, chunk-index dedup applied, store codec
  honored), plus a rewritten one-row header with the grown shape;
* **watermark commit**: the sealed files land as ONE fenced
  ``commit_adds`` (adds = chunks + new header, removes = old header) at
  ``op="INGEST"``. On :class:`~repro.lake.log.CommitConflict` the writer
  rebases like :class:`~repro.core.batch.WriteBatch`: a fence moved by an
  unrelated writer re-commits the same files against the new version; a
  concurrent change to *this* tensor (another ingest writer, an
  overwrite, a compact of its chunk files) abandons the staged uploads as
  vacuumable orphans, re-reads the committed row count, and re-seals the
  buffer at the new base indices — bounded by ``commit_retries``;
* **crash consistency**: the commit is the only visible transition. A
  writer killed between upload and commit leaves invisible orphans that
  ``vacuum`` reclaims — never a torn version. A commit whose
  acknowledgement is lost (the put landed, the response didn't) is
  detected by re-reading the snapshot before declaring failure, so those
  rows are not double-ingested. A restarted writer re-reads the committed
  row count and resumes exactly after the last durable row;
* **readers never blocked**: an epoch-pinned
  :class:`~repro.data.stream.StreamLoader` keeps reading its frozen
  leased snapshot while ingest commits land;
  :meth:`~repro.data.stream.StreamLoader.reopen` hands off to a fresh
  loader pinned at the latest version to pick up the new rows.

One writer instance is single-threaded by design (one buffer, one fence);
run concurrent writers as separate instances — their commits serialize
through the fenced retry loop, and writers on different shards never
conflict at all.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from ..core.batch import DEFAULT_COMMIT_RETRIES, _tensor_paths
from ..core.encodings.base import (first_scalar, header_dtype, header_shape,
                                   make_header)
from ..core.store import TARGET_FILE_BYTES, DeltaTensorStore
from ..lake.compression import CompressionSpec, parse_compression
from ..lake.log import CommitConflict, Snapshot


class IngestWriter:
    """Micro-batching appender onto one FTSF tensor (see module docstring).

    Built via :meth:`DeltaTensorStore.ingest`. The target tensor must be
    row-chunked FTSF (``chunk_dims == ndim - 1`` — what ``put`` writes by
    default), or not exist yet: a missing tensor is created on the first
    flush, its row shape and dtype inferred from the first appended rows.
    """

    def __init__(self, store: DeltaTensorStore, tensor_id: str, *,
                 watermark_rows: int = 64,
                 watermark_s: Optional[float] = None,
                 target_file_bytes: Optional[int] = None,
                 compression: Union[None, str, CompressionSpec] = None,
                 commit_retries: Optional[int] = None,
                 clock=None):
        if watermark_rows < 1:
            raise ValueError("watermark_rows must be >= 1")
        self.store = store
        self.tid = tensor_id
        self.shard = store.shard_of(tensor_id)
        self.table = store.tables[self.shard]
        self.watermark_rows = int(watermark_rows)
        self.watermark_s = watermark_s
        self.target = (TARGET_FILE_BYTES if target_file_bytes is None
                       else int(target_file_bytes))
        spec = parse_compression(compression)
        self.spec = spec if spec is not None else store.compression
        self.commit_retries = (DEFAULT_COMMIT_RETRIES if commit_retries is None
                               else max(0, int(commit_retries)))
        self.clock = clock or time.monotonic

        self._row_shape: Optional[Tuple[int, ...]] = None
        self._dtype: Optional[np.dtype] = None
        self._buffer: List[np.ndarray] = []
        self._buffered = 0
        self._first_ts: Optional[float] = None
        self._closed = False

        self.rows_buffered = 0      # rows ever handed to append_rows
        self.rows_committed = 0     # rows durably landed by this writer
        self.flushes = 0            # successful watermark commits
        self.conflicts = 0          # CommitConflicts hit (all retried)
        self.reencodes = 0          # conflict rebases that re-sealed

        self._pin(self.table.snapshot())

    # -- base snapshot ---------------------------------------------------------

    def _pin(self, snap: Snapshot) -> None:
        """Adopt ``snap`` as the commit fence: read the tensor's committed
        row count and live file set (what conflict rebase re-validates)."""
        self._base_version = snap.version
        self._tid_paths = sorted(_tensor_paths(snap).get(self.tid, []))
        header_add = None
        for add in snap.add_actions():
            pv = add.get("partitionValues") or {}
            if pv.get("tensor") == self.tid and pv.get("kind") == "header":
                header_add = add
                break
        if header_add is None:
            if self._tid_paths:
                raise ValueError(
                    f"tensor {self.tid!r} has chunk files but no header")
            self._row_count = 0
            self._header_path: Optional[str] = None
            return
        pv = header_add.get("partitionValues") or {}
        if pv.get("layout") != "ftsf":
            raise ValueError(
                f"ingest requires an ftsf tensor; {self.tid!r} is "
                f"{pv.get('layout')!r}")
        cols = self.store._header_for_path(header_add["path"], self.shard)
        shape = header_shape(cols)
        dtype = np.dtype(header_dtype(cols))
        chunk_dims = int(first_scalar(cols["chunk_dim_count"])) \
            if "chunk_dim_count" in cols else len(shape) - 1
        if chunk_dims != len(shape) - 1:
            raise ValueError(
                f"ingest requires row-chunked tensors (chunk_dims == ndim-1);"
                f" {self.tid!r} has chunk_dims={chunk_dims} at rank "
                f"{len(shape)}")
        row_shape = tuple(int(d) for d in shape[1:])
        if self._row_shape is not None and \
                (row_shape != self._row_shape or dtype != self._dtype):
            raise ValueError(
                f"tensor {self.tid!r} rows are {row_shape}:{dtype}, writer "
                f"buffered {self._row_shape}:{self._dtype}")
        self._row_shape, self._dtype = row_shape, dtype
        self._row_count = int(shape[0])
        self._header_path = header_add["path"]

    @property
    def row_count(self) -> int:
        """Rows durably committed for this tensor (the resume point: a
        restarted producer continues from here — rows that were only
        buffered when a writer died were never made visible)."""
        return self._row_count

    @property
    def rows_pending(self) -> int:
        """Rows buffered but not yet committed."""
        return self._buffered

    @property
    def version(self) -> int:
        """The shard version of the last commit this writer observed."""
        return self._base_version

    # -- buffering -------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("IngestWriter is closed")

    def _watermark_due(self) -> bool:
        if self._buffered >= self.watermark_rows:
            return True
        return (self.watermark_s is not None and self._first_ts is not None
                and self.clock() - self._first_ts >= self.watermark_s)

    def append_rows(self, rows: Any) -> Optional[int]:
        """Buffer ``rows`` (shape ``(k, *row_shape)``); commit on watermark.

        Returns the committed version when this append tripped a
        watermark flush, else None. The rows are copied into the buffer —
        the caller may reuse its array. Shape/dtype must match the
        tensor's rows exactly (inferred from the first append when the
        tensor does not exist yet).
        """
        self._check_open()
        rows = np.asarray(rows)
        if rows.ndim < 1:
            raise ValueError("append_rows wants (k, *row_shape), got a scalar")
        if len(rows) == 0:
            return None
        if self._row_shape is None:
            self._row_shape = tuple(int(d) for d in rows.shape[1:])
            self._dtype = rows.dtype
        elif tuple(rows.shape[1:]) != self._row_shape or \
                rows.dtype != self._dtype:
            raise ValueError(
                f"rows are {tuple(rows.shape[1:])}:{rows.dtype}, tensor "
                f"{self.tid!r} wants {self._row_shape}:{self._dtype}")
        self._buffer.append(np.array(rows, copy=True))
        self._buffered += len(rows)
        self.rows_buffered += len(rows)
        if self._first_ts is None:
            self._first_ts = self.clock()
        if self._watermark_due():
            return self.flush()
        return None

    def poll(self) -> Optional[int]:
        """Commit iff the time watermark has expired (idle-producer hook)."""
        self._check_open()
        if self._buffered and self._watermark_due():
            return self.flush()
        return None

    # -- sealing + committing --------------------------------------------------

    def _seal(self, rows: np.ndarray, guard) -> Tuple[List[Dict[str, Any]],
                                                      Tuple[str, Dict[str, Any]]]:
        """Upload the buffer as chunk rows ``row_count..row_count+k-1`` plus
        the grown header (two-phase: nothing visible until commit)."""
        base, k = self._row_count, int(len(rows))
        shape = (base + k,) + self._row_shape
        n = len(shape)
        flat = np.ascontiguousarray(rows).reshape(k, -1)
        cols: Dict[str, Any] = {
            "chunk_index": np.arange(base, base + k, dtype=np.int64),
            "chunk": [flat[i].tobytes() for i in range(k)],
            "dim_count": np.full(k, n, dtype=np.int32),
            "dimensions": [np.asarray(shape, dtype=np.int64)] * k,
            "chunk_dim_count": np.full(k, n - 1, dtype=np.int32),
            "dtype": [str(self._dtype)] * k,
        }
        adds = self.table.append_split(
            cols, target_bytes=self.target, guard=guard,
            compression=self.spec, shuffle_itemsize=self._dtype.itemsize,
            cas=self.table.cas, dedup_seen=set(),
            partition_values={"tensor": self.tid, "kind": "chunk",
                              "layout": "ftsf"})
        header = make_header(shape, self._dtype, chunk_dim_count=n - 1,
                             dimensions=np.asarray(shape, dtype=np.int64))
        h_add = self.table.append(
            header.columns, commit=False, guard=guard,
            partition_values={"tensor": self.tid, "kind": "header",
                              "layout": "ftsf"})
        return adds + [h_add], (h_add["path"], header.columns)

    def _landed_version(self, adds: List[Dict[str, Any]]) -> Optional[int]:
        """Did the staged commit actually land (lost-ack detection)?

        Part-file names are uuid-unique, so the staged paths appearing
        live in a fresh snapshot proves OUR commit succeeded even though
        the put's acknowledgement never arrived. Returns that snapshot's
        version, or None when the commit genuinely failed.
        """
        try:
            snap = self.table.snapshot()
        except Exception:
            return None
        staged = {a["path"] for a in adds}
        if staged and staged <= set(snap.files):
            return snap.version
        return None

    def flush(self) -> Optional[int]:
        """Seal + commit everything buffered; returns the version (None if
        the buffer was empty).

        On failure the buffer is KEPT — the rows were never made visible,
        and any uploaded part files are invisible orphans a later
        ``vacuum`` reclaims (the upload guard is closed on every exit).
        """
        self._check_open()
        if not self._buffered:
            return None
        rows = (self._buffer[0] if len(self._buffer) == 1
                else np.concatenate(self._buffer))
        k = int(len(rows))
        stats = self.store.commit_stats
        attempts = 0
        adds: Optional[List[Dict[str, Any]]] = None
        header_seed: Optional[Tuple[str, Dict[str, Any]]] = None
        guard = None
        try:
            while True:
                if adds is None:
                    guard = self.table.guard_uploads()
                    adds, header_seed = self._seal(rows, guard)
                removes = [self._header_path] if self._header_path else []
                try:
                    v = self.table.commit_adds(
                        adds, removes=removes, op="INGEST",
                        expected_version=self._base_version)
                except CommitConflict:
                    stats["conflicts"] += 1
                    self.conflicts += 1
                    attempts += 1
                    if attempts > self.commit_retries:
                        raise
                    stats["retries"] += 1
                    snap = self.table.snapshot()
                    live = sorted(_tensor_paths(snap).get(self.tid, []))
                    if live == self._tid_paths:
                        # fence moved for an unrelated reason (another
                        # tensor on this shard, maintenance elsewhere): the
                        # staged files still mean the same thing
                        self._base_version = snap.version
                        continue
                    # this tensor changed under us: abandon the staged
                    # uploads (vacuumable orphans) and re-seal on the new
                    # committed row count
                    guard.close()
                    guard, adds, header_seed = None, None, None
                    self._pin(snap)
                    self.reencodes += 1
                    continue
                except Exception:
                    landed = self._landed_version(adds)
                    if landed is None:
                        raise
                    # ambiguous commit: the put landed, its ack was lost.
                    # Failing here would re-ingest these rows on retry.
                    v = landed
                return self._committed(v, k, adds, header_seed)
        finally:
            if guard is not None:
                guard.close()

    def _committed(self, v: int, k: int, adds: List[Dict[str, Any]],
                   header_seed: Tuple[str, Dict[str, Any]]) -> int:
        self.store.commit_stats["commits"] += 1
        self._tid_paths = sorted(
            (set(self._tid_paths) - {self._header_path})
            | {a["path"] for a in adds})
        self._header_path = header_seed[0]
        self._row_count += k
        self._base_version = v
        # the new header is visible now and its path is immutable: safe to
        # seed the store's by-path cache (mirrors WriteBatch post-commit)
        self.store._seed_header(*header_seed)
        self.store._maybe_spill(self.shard, v, adds_hint=len(adds))
        self._buffer.clear()
        self._buffered = 0
        self._first_ts = None
        self.flushes += 1
        self.rows_committed += k
        return v

    # -- lifecycle -------------------------------------------------------------

    def close(self, *, flush: bool = True) -> Optional[int]:
        """Final flush (unless ``flush=False``), then refuse further use.

        Returns the final committed version (None when nothing was
        pending). ``flush=False`` abandons buffered rows — they were never
        visible, so nothing needs cleaning up.
        """
        if self._closed:
            return None
        v = self.flush() if flush and self._buffered else None
        self._closed = True
        self._buffer.clear()
        self._buffered = 0
        return v

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "IngestWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception abandons the buffer (mirroring WriteBatch): the
        # producer decides whether to re-append after recovery
        self.close(flush=exc_type is None)

    def stats(self) -> Dict[str, Any]:
        """Writer-side counters (commit_stats on the store aggregates
        across writers)."""
        return {"rows_buffered": self.rows_buffered,
                "rows_committed": self.rows_committed,
                "rows_pending": self._buffered,
                "row_count": self._row_count,
                "flushes": self.flushes,
                "conflicts": self.conflicts,
                "reencodes": self.reencodes,
                "watermark_rows": self.watermark_rows,
                "watermark_s": self.watermark_s}
