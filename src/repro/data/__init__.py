from . import pipeline, synthetic
