from . import ingest, pipeline, stream, synthetic
from .ingest import IngestWriter
from .stream import StreamLoader

__all__ = ["ingest", "pipeline", "stream", "synthetic", "IngestWriter",
           "StreamLoader"]
