"""Synthetic datasets shaped like the paper's two scenarios + LM streams."""

from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.encodings.base import SparseCOO


def ffhq_like(shape: Tuple[int, ...] = (256, 3, 128, 128), seed: int = 0,
              dtype=np.uint8) -> np.ndarray:
    """Dense image tensor with realistic spatial correlation (compressible
    like PNG-decoded faces, not iid noise)."""
    rng = np.random.default_rng(seed)
    n, c, h, w = shape
    base = rng.integers(0, 256, (n, c, h // 8, w // 8)).astype(np.float32)
    img = np.repeat(np.repeat(base, 8, axis=2), 8, axis=3)
    # smooth gradients + mild quantized noise: PNG-decoded faces compress
    # moderately (they are not iid noise)
    img += np.linspace(0, 24, w)[None, None, None, :]
    img += rng.normal(0, 2, (n, c, h, w)).round()
    return np.clip(img, 0, 255).astype(dtype)


def uber_like(shape: Tuple[int, ...] = (183, 24, 285, 430),
              nnz_ratio: float = 0.00038, seed: int = 1) -> SparseCOO:
    """Sparse 4-D (day, hour, lat, lon) pickup counts with the real Uber
    data's structure: a compact hot core (Manhattan analog) where a few
    hundred grid cells stay active across a large share of (day, hour)
    slots, plus a popularity long tail. This joint space-time clustering is
    exactly what CSF fiber trees and BSGS time-major blocks exploit."""
    rng = np.random.default_rng(seed)
    total = int(np.prod(shape))
    nnz = int(total * nnz_ratio)
    d, h, la, lo = shape
    # hot core: ~0.15% of the grid, tightly packed around a few hubs
    n_cells = max(32, int(la * lo * 0.0015))
    n_hubs = 6
    hubs = np.stack([rng.integers(la // 8, la - la // 8, n_hubs),
                     rng.integers(lo // 8, lo - lo // 8, n_hubs)], axis=1)
    hub_of = rng.integers(0, n_hubs, n_cells)
    cells = np.stack([
        np.clip(hubs[hub_of, 0] + rng.normal(0, 3, n_cells).astype(int), 0, la - 1),
        np.clip(hubs[hub_of, 1] + rng.normal(0, 3, n_cells).astype(int), 0, lo - 1),
    ], axis=1)
    cells = np.unique(cells, axis=0)
    # zipf-ish popularity: hottest cells get most pickups
    pop = 1.0 / np.arange(1, len(cells) + 1) ** 0.7
    pop /= pop.sum()
    which = rng.choice(len(cells), size=nnz, p=pop)
    day = rng.integers(0, d, nnz)
    hour = (rng.normal(18, 5, nnz).astype(int)) % h
    idx = np.stack([day, hour, cells[which, 0], cells[which, 1]],
                   axis=1).astype(np.int64)
    # dedupe collisions (counts sum, like real pickup counts)
    key = np.ravel_multi_index(idx.T, shape)
    ukey, counts = np.unique(key, return_counts=True)
    uidx = np.stack(np.unravel_index(ukey, shape), axis=1)
    return SparseCOO(uidx, counts.astype(np.float32), shape)


def token_stream(n_samples: int, seq_len: int, vocab: int, seed: int = 2
                 ) -> np.ndarray:
    """Markov-ish token stream (learnable structure, not uniform noise)."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(0, vocab, (256, 8))
    out = np.empty((n_samples, seq_len), np.int32)
    state = rng.integers(0, 256, n_samples)
    for t in range(seq_len):
        pick = rng.integers(0, 8, n_samples)
        out[:, t] = trans[state, pick] % vocab
        state = (state * 31 + out[:, t]) % 256
    return out
