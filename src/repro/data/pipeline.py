"""FTSF-backed training-data pipeline (compatibility shim).

This is the paper's headline use case (its §V.A discussion): datasets live
as FTSF chunk rows in a delta table; an SGD batch fetch is a slice read
that touches only the covering chunk files. The machinery now lives in
:class:`~repro.data.stream.StreamLoader` — epoch-pinned leased snapshot,
shard-aware deterministic shuffle, windowed batch prefetch through the
shared executor, and one merged ``read_many`` fetch plan per batch.
:class:`FTSFLoader` keeps the original single-tensor token-batch API as a
thin wrapper over it:

* **per-host sharding**: host *h* of *H* owns sample rows ``h::H``;
* **prefetch**: ``prefetch_depth`` maps onto the stream loader's batch
  window (bounded in-flight memory, structural backpressure);
* **hedged reads**: an optional duplicate attempt for a slow batch fetch
  (object-store reads are idempotent, so racing duplicates is safe);
* **determinism**: batch order is a pure function of (seed, epoch), so an
  elastic restart at ``start_step`` replays exactly the remaining stream;
* **lifecycle**: context-manager support, and a dropped loader releases
  its snapshot lease via GC finalizer (mirroring ``TensorRef``) — a
  forgotten ``close()`` no longer pins the snapshot forever.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from ..core.store import DeltaTensorStore
from ..lake.io import ReadExecutor
from .stream import StreamLoader


def write_token_dataset(store: DeltaTensorStore, tokens: np.ndarray, *,
                        tensor_id: str = "train_tokens",
                        target_file_bytes: int = 1 << 20) -> str:
    """tokens: (n_samples, seq_len) int32 -> FTSF rows (one chunk per sample)."""
    assert tokens.ndim == 2
    return store.put(tokens.astype(np.int32), layout="ftsf", tensor_id=tensor_id,
                     chunk_dims=1, target_file_bytes=target_file_bytes)


class FTSFLoader:
    """Single-tensor token-batch loader: the original pipeline API, now a
    shim over :class:`~repro.data.stream.StreamLoader`.

    Yields ``{"tokens", "labels", "step"}`` dicts where labels are the
    next-token shift of tokens (−1 fill on the last position) and ``step``
    is the global step (``start_step`` resumes there deterministically).
    """

    def __init__(self, store: DeltaTensorStore, tensor_id: str, *,
                 batch_size: int, host_index: int = 0, n_hosts: int = 1,
                 seed: int = 0, prefetch_depth: int = 2,
                 start_step: int = 0, hedge_after_s: Optional[float] = None,
                 io: Optional[ReadExecutor] = None):
        self.store = store
        self.tid = tensor_id
        self.batch = batch_size
        self.host = host_index
        self.n_hosts = n_hosts
        self.hedge_after_s = hedge_after_s
        self._stream = StreamLoader(
            store, tensor_id, batch_size=batch_size,
            host_index=host_index, n_hosts=n_hosts, seed=seed,
            window=max(1, prefetch_depth), hedge_after_s=hedge_after_s,
            io=io)
        self.io = self._stream.io
        self.seed = seed
        if start_step:
            self._stream.seek(*divmod(int(start_step),
                                      self._stream.steps_per_epoch))

    @property
    def owned(self) -> np.ndarray:
        """Sample rows this host owns (``host_index::n_hosts``)."""
        return self._stream.owned

    @property
    def step(self) -> int:
        """Global step of the next batch to yield."""
        epoch, s = self._stream.cursor
        return epoch * self._stream.steps_per_epoch + s

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        for b in self._stream:
            tokens = b["data"]
            labels = np.concatenate([tokens[:, 1:],
                                     np.full((len(tokens), 1), -1, np.int32)],
                                    axis=1)
            yield {"tokens": tokens, "labels": labels, "step": b["step"]}

    def close(self) -> None:
        """Cancel prefetch and release the snapshot lease (idempotent)."""
        self._stream.close()

    @property
    def closed(self) -> bool:
        """Whether the snapshot lease has been released."""
        return self._stream.closed

    def __enter__(self) -> "FTSFLoader":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
