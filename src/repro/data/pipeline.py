"""FTSF-backed training-data pipeline.

This is the paper's headline use case (its §V.A discussion): datasets live
as FTSF chunk rows in a delta table; an SGD batch fetch is a slice read
that touches only the covering chunk files. The loader adds the
scale-out machinery:

* **per-host sharding**: host *h* of *H* owns sample rows ``h::H`` — each
  host's reads prune to its own files (no shared-prefix hot-spotting);
* **prefetch**: a background thread keeps ``depth`` batches decoded ahead;
* **hedged reads** (straggler mitigation): an optional second attempt for
  a slow chunk fetch, racing the original (object-store tail latencies);
* **determinism**: batch order is a pure function of (seed, step), so an
  elastic restart at step *s* replays exactly the remaining stream.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from ..core.store import DeltaTensorStore


def write_token_dataset(store: DeltaTensorStore, tokens: np.ndarray, *,
                        tensor_id: str = "train_tokens",
                        target_file_bytes: int = 1 << 20) -> str:
    """tokens: (n_samples, seq_len) int32 -> FTSF rows (one chunk per sample)."""
    assert tokens.ndim == 2
    return store.put(tokens.astype(np.int32), layout="ftsf", tensor_id=tensor_id,
                     chunk_dims=1, target_file_bytes=target_file_bytes)


def hedged(fn, *, hedge_after_s: float = 0.5, attempts: int = 2):
    """Run ``fn`` with tail-latency hedging: if the first attempt hasn't
    finished after ``hedge_after_s``, race a duplicate; first result wins.
    Object-store reads are idempotent, so duplicates are safe — this is the
    classic straggler mitigation for p99 fetches on large fleets."""
    import concurrent.futures as cf

    def run():
        ex = cf.ThreadPoolExecutor(max_workers=attempts)
        try:
            futures = [ex.submit(fn)]
            done, _ = cf.wait(futures, timeout=hedge_after_s)
            if not done and attempts > 1:
                futures.append(ex.submit(fn))     # race a duplicate
            done, _ = cf.wait(futures, return_when=cf.FIRST_COMPLETED)
            return next(iter(done)).result()
        finally:
            ex.shutdown(wait=False)               # abandon the straggler

    return run


class FTSFLoader:
    def __init__(self, store: DeltaTensorStore, tensor_id: str, *,
                 batch_size: int, host_index: int = 0, n_hosts: int = 1,
                 seed: int = 0, prefetch_depth: int = 2,
                 start_step: int = 0, hedge_after_s: Optional[float] = None):
        self.store = store
        self.tid = tensor_id
        self.batch = batch_size
        self.host = host_index
        self.n_hosts = n_hosts
        self.hedge_after_s = hedge_after_s
        n_samples = store.shape_of(tensor_id)[0]
        self.owned = np.arange(n_samples)[host_index::n_hosts]
        if len(self.owned) < batch_size:
            raise ValueError("fewer owned samples than batch size")
        self.seed = seed
        self.step = start_step
        self.depth = prefetch_depth
        self._q: "queue.Queue[Tuple[int, np.ndarray]]" = queue.Queue(prefetch_depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # deterministic sample plan: pure function of (seed, step)
    def _plan(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        return np.sort(rng.choice(self.owned, size=self.batch, replace=False))

    def _fetch(self, step: int) -> np.ndarray:
        rows = self._plan(step)
        # coalesce consecutive rows into range slice reads (file pruning)
        parts = []
        run_start = rows[0]
        prev = rows[0]
        for r in rows[1:]:
            if r != prev + 1:
                parts.append((run_start, prev + 1))
                run_start = r
            prev = r
        parts.append((run_start, prev + 1))

        def read(a, b):
            fn = lambda: self.store.get_slice(self.tid, [(int(a), int(b))])
            if self.hedge_after_s is not None:
                return hedged(fn, hedge_after_s=self.hedge_after_s)()
            return fn()

        return np.concatenate([read(a, b) for a, b in parts], axis=0)

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._fetch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            step, tokens = self._q.get()
            labels = np.concatenate([tokens[:, 1:],
                                     np.full((len(tokens), 1), -1, np.int32)],
                                    axis=1)
            yield {"tokens": tokens, "labels": labels, "step": step}

    def close(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
            self._thread = None
