"""FTSF-backed training-data pipeline.

This is the paper's headline use case (its §V.A discussion): datasets live
as FTSF chunk rows in a delta table; an SGD batch fetch is a slice read
that touches only the covering chunk files. The loader adds the
scale-out machinery:

* **per-host sharding**: host *h* of *H* owns sample rows ``h::H`` — each
  host's reads prune to its own files (no shared-prefix hot-spotting);
* **prefetch**: up to ``depth`` future batches are fetched ahead as jobs on
  the shared :class:`~repro.lake.io.ReadExecutor` (no private threads —
  chunk gets inside each batch also fan out on the same executor);
* **hedged reads** (straggler mitigation): an optional duplicate attempt
  for a slow batch fetch via ``ReadExecutor.hedged`` (object-store reads
  are idempotent, so racing duplicates is safe);
* **determinism**: batch order is a pure function of (seed, step), so an
  elastic restart at step *s* replays exactly the remaining stream. The
  loader holds a snapshot-pinned :class:`~repro.core.catalog.TensorRef`,
  so even a concurrent writer appending to the dataset table cannot change
  what this epoch reads (and no batch pays a table-version probe).
"""

from __future__ import annotations

from concurrent.futures import Future
from typing import Dict, Iterator, Optional

import numpy as np

from ..core.store import DeltaTensorStore
from ..lake.io import ReadExecutor


def write_token_dataset(store: DeltaTensorStore, tokens: np.ndarray, *,
                        tensor_id: str = "train_tokens",
                        target_file_bytes: int = 1 << 20) -> str:
    """tokens: (n_samples, seq_len) int32 -> FTSF rows (one chunk per sample)."""
    assert tokens.ndim == 2
    return store.put(tokens.astype(np.int32), layout="ftsf", tensor_id=tensor_id,
                     chunk_dims=1, target_file_bytes=target_file_bytes)


class FTSFLoader:
    def __init__(self, store: DeltaTensorStore, tensor_id: str, *,
                 batch_size: int, host_index: int = 0, n_hosts: int = 1,
                 seed: int = 0, prefetch_depth: int = 2,
                 start_step: int = 0, hedge_after_s: Optional[float] = None,
                 io: Optional[ReadExecutor] = None):
        self.store = store
        self.tid = tensor_id
        self.batch = batch_size
        self.host = host_index
        self.n_hosts = n_hosts
        self.hedge_after_s = hedge_after_s
        self.io = io or store.io
        # pin the dataset version for the lifetime of this loader
        self.ref = store.open(tensor_id)
        n_samples = self.ref.shape[0]
        self.owned = np.arange(n_samples)[host_index::n_hosts]
        if len(self.owned) < batch_size:
            raise ValueError("fewer owned samples than batch size")
        self.seed = seed
        self.step = start_step
        self.depth = max(1, prefetch_depth)
        self._pending: Dict[int, Future] = {}
        self._closed = False

    # deterministic sample plan: pure function of (seed, step)
    def _plan(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        return np.sort(rng.choice(self.owned, size=self.batch, replace=False))

    def _fetch(self, step: int) -> np.ndarray:
        rows = self._plan(step)
        # coalesce consecutive rows into range slice reads (file pruning)
        parts = []
        run_start = rows[0]
        prev = rows[0]
        for r in rows[1:]:
            if r != prev + 1:
                parts.append((run_start, prev + 1))
                run_start = r
            prev = r
        parts.append((run_start, prev + 1))

        def read(a, b):
            fn = lambda: self.ref.read_slice([(int(a), int(b))])
            if self.hedge_after_s is not None:
                return self.io.hedged(fn, hedge_after_s=self.hedge_after_s)
            return fn()

        return np.concatenate([read(a, b) for a, b in parts], axis=0)

    def _ensure_prefetch(self) -> None:
        for step in range(self.step, self.step + self.depth):
            if step not in self._pending:
                self._pending[step] = self.io.submit(self._fetch, step)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while not self._closed:
            self._ensure_prefetch()
            step = self.step
            tokens = self._pending.pop(step).result()
            self.step = step + 1
            labels = np.concatenate([tokens[:, 1:],
                                     np.full((len(tokens), 1), -1, np.int32)],
                                    axis=1)
            yield {"tokens": tokens, "labels": labels, "step": step}

    def close(self):
        self._closed = True
        for fut in self._pending.values():
            fut.cancel()
        self._pending.clear()
