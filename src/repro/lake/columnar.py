"""parq-lite: a minimal Parquet-style columnar file format.

The paper leans on two Parquet behaviours: (1) hybrid row/column layout so a
row-group can be fetched and then decoded column-by-column, and (2)
dictionary/RLE encoding so per-row metadata that repeats across rows ("the
same dense_shape on every row of a tensor") compresses to almost nothing.
parq-lite reproduces exactly those two behaviours with stdlib-only code:

    file := magic "PQL1" | u32 header_len | header JSON | column blocks

Column kinds
  array : one fixed-dtype scalar per row               (chunk_index, nnz, ...)
  list  : one variable-length 1-D array per row        (dimensions, indices)
  bytes : one blob per row                              (chunk payloads)
  str   : one unicode string per row                    (id, layout)

Encodings (chosen automatically per column):
  plain : raw buffer
  dict  : unique values + per-row codes    — the Parquet dictionary page
  rle   : (value, run_length) pairs        — repeated/sorted columns (id)

Each block is optionally zlib-compressed when that actually shrinks it.
min/max stats are computed per column at write time and returned to the
caller so the delta log can store them for data skipping (the reader never
needs to fetch a file whose [min,max] chunk_index range misses the slice).
"""

from __future__ import annotations

import json
import struct
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

MAGIC = b"PQL1"

# ---------------------------------------------------------------------------
# block encoders
# ---------------------------------------------------------------------------


def _maybe_compress(raw: bytes) -> Tuple[bytes, bool]:
    if len(raw) < 64:
        return raw, False
    comp = zlib.compress(raw, 3)
    if len(comp) < len(raw) * 0.9:
        return comp, True
    return raw, False


def _decompress(raw: bytes, compressed: bool) -> bytes:
    return zlib.decompress(raw) if compressed else raw


def _pack_arrays(arrays: Sequence[np.ndarray]) -> bytes:
    """Concatenate buffers with a small length-prefixed framing."""
    parts = [struct.pack("<I", len(arrays))]
    for a in arrays:
        buf = np.ascontiguousarray(a).tobytes()
        parts.append(struct.pack("<Q", len(buf)))
        parts.append(buf)
    return b"".join(parts)


def _unpack_arrays(raw: bytes, dtypes: Sequence[str]) -> List[np.ndarray]:
    (n,) = struct.unpack_from("<I", raw, 0)
    off = 4
    out = []
    for i in range(n):
        (ln,) = struct.unpack_from("<Q", raw, off)
        off += 8
        out.append(np.frombuffer(raw, dtype=dtypes[i], count=ln // np.dtype(dtypes[i]).itemsize, offset=off).copy())
        off += ln
    return out


def _min_code_dtype(n: int) -> str:
    if n < 2**8:
        return "uint8"
    if n < 2**16:
        return "uint16"
    return "uint32"


def _encode_array(values: np.ndarray) -> Tuple[bytes, Dict[str, Any]]:
    """Pick plain/dict/rle for a 1-D fixed-dtype array."""
    values = np.asarray(values)
    n = len(values)
    meta: Dict[str, Any] = {"dtype": str(values.dtype), "rows": n}
    if n == 0:
        meta["encoding"] = "plain"
        return b"", meta

    plain_sz = values.nbytes
    # float payloads (tensor values) essentially never dict/RLE-compress and
    # np.unique on 100M+ elements costs seconds — leave those to zlib
    heavy = values.nbytes > (8 << 20) or values.dtype.kind == "f"
    if heavy:
        return values.tobytes(), dict(meta, encoding="plain")

    # run-length candidate
    change = np.flatnonzero(np.concatenate(([True], values[1:] != values[:-1])))
    n_runs = len(change)
    # dictionary candidate
    uniques, codes = np.unique(values, return_inverse=True)
    n_uniq = len(uniques)

    rle_sz = n_runs * (values.itemsize + 4)
    dict_sz = n_uniq * values.itemsize + n * np.dtype(_min_code_dtype(n_uniq)).itemsize

    best = min(plain_sz, rle_sz, dict_sz)
    if best == rle_sz and rle_sz < plain_sz:
        run_vals = values[change]
        run_lens = np.diff(np.concatenate((change, [n]))).astype("uint32")
        raw = _pack_arrays([run_vals, run_lens])
        meta["encoding"] = "rle"
    elif best == dict_sz and dict_sz < plain_sz:
        code_dt = _min_code_dtype(n_uniq)
        raw = _pack_arrays([uniques, codes.astype(code_dt)])
        meta["encoding"] = "dict"
        meta["code_dtype"] = code_dt
    else:
        raw = values.tobytes()
        meta["encoding"] = "plain"
    return raw, meta


def _decode_array(raw: bytes, meta: Dict[str, Any]) -> np.ndarray:
    dt = meta["dtype"]
    if meta["rows"] == 0:
        return np.empty(0, dtype=dt)
    enc = meta["encoding"]
    if enc == "plain":
        return np.frombuffer(raw, dtype=dt).copy()
    if enc == "rle":
        run_vals, run_lens = _unpack_arrays(raw, [dt, "uint32"])
        return np.repeat(run_vals, run_lens)
    if enc == "dict":
        uniques, codes = _unpack_arrays(raw, [dt, meta["code_dtype"]])
        return uniques[codes]
    raise ValueError(f"unknown encoding {enc}")


# ---------------------------------------------------------------------------
# column-level encode/decode
# ---------------------------------------------------------------------------


def _stats_of(values: np.ndarray) -> Optional[Dict[str, Any]]:
    if values.size == 0 or values.dtype.kind not in "iuf":
        return None
    return {"min": values.min().item(), "max": values.max().item()}


def _encode_column(name: str, col: Any, num_rows: int, *,
                   compress_blocks: bool = True) -> Tuple[bytes, Dict[str, Any]]:
    # --- classify ---
    if isinstance(col, np.ndarray) and col.ndim == 1 and col.dtype.kind != "O":
        kind = "array"
    elif len(col) and isinstance(col[0], (bytes, bytearray, memoryview)):
        kind = "bytes"
    elif len(col) and isinstance(col[0], str):
        kind = "str"
    elif isinstance(col, np.ndarray) and col.dtype.kind == "O" or isinstance(col, (list, tuple)):
        kind = "list"
    elif isinstance(col, np.ndarray) and col.ndim == 1:
        kind = "array"
    else:
        raise TypeError(f"column {name!r}: unsupported value {type(col)}")
    if len(col) != num_rows:
        raise ValueError(f"column {name!r}: {len(col)} rows != {num_rows}")

    meta: Dict[str, Any] = {"name": name, "kind": kind}
    if kind == "array":
        raw, emeta = _encode_array(np.asarray(col))
        meta.update(emeta)
        meta["stats"] = _stats_of(np.asarray(col))
    elif kind == "str":
        # dictionary-encode strings through their codes
        arr = np.asarray(col, dtype=object)
        uniques, codes = np.unique(arr, return_inverse=True)
        code_raw, emeta = _encode_array(codes.astype("uint32"))
        udata = "\x00".join(str(u) for u in uniques).encode("utf-8")
        raw = struct.pack("<Q", len(udata)) + udata + code_raw
        meta["code_meta"] = emeta
        meta["dtype"] = "str"
        meta["rows"] = num_rows
    elif kind == "bytes":
        offsets = np.zeros(num_rows + 1, dtype="uint64")
        for i, b in enumerate(col):
            offsets[i + 1] = offsets[i] + len(b)
        body = b"".join(bytes(b) for b in col)
        raw = _pack_arrays([offsets]) + body
        meta["dtype"] = "bytes"
        meta["rows"] = num_rows
    else:  # list
        arrays = [np.asarray(a) for a in col]
        dt = np.result_type(*[a.dtype for a in arrays]) if arrays else np.dtype("int64")
        flat = (np.concatenate([a.astype(dt, copy=False).ravel() for a in arrays])
                if arrays else np.empty(0, dt))
        lens = np.asarray([a.size for a in arrays], dtype="uint32")
        len_raw, len_meta = _encode_array(lens)
        flat_raw, flat_meta = _encode_array(flat)
        raw = struct.pack("<Q", len(len_raw)) + len_raw + flat_raw
        meta["rows"] = num_rows
        meta["dtype"] = str(dt)
        meta["len_meta"] = len_meta
        meta["flat_meta"] = flat_meta
        meta["stats"] = _stats_of(flat)

    comp, was = _maybe_compress(raw) if compress_blocks else (raw, False)
    meta["compressed"] = was
    return comp, meta


def _decode_column(raw: bytes, meta: Dict[str, Any]) -> Any:
    raw = _decompress(raw, meta["compressed"])
    kind = meta["kind"]
    if kind == "array":
        return _decode_array(raw, meta)
    if kind == "str":
        (ulen,) = struct.unpack_from("<Q", raw, 0)
        udata = bytes(raw[8:8 + ulen]).decode("utf-8")
        uniques = np.asarray(udata.split("\x00"), dtype=object) if ulen else np.asarray([""], dtype=object)
        codes = _decode_array(raw[8 + ulen:], meta["code_meta"])
        return uniques[codes]
    if kind == "bytes":
        (n,) = struct.unpack_from("<I", raw, 0)
        (ln,) = struct.unpack_from("<Q", raw, 4)
        offsets = np.frombuffer(raw, dtype="uint64", count=ln // 8, offset=12)
        body_start = 12 + ln
        return [raw[body_start + int(offsets[i]): body_start + int(offsets[i + 1])]
                for i in range(meta["rows"])]
    if kind == "list":
        (ln,) = struct.unpack_from("<Q", raw, 0)
        lens = _decode_array(raw[8:8 + ln], meta["len_meta"])
        flat = _decode_array(raw[8 + ln:], meta["flat_meta"])
        splits = np.cumsum(lens)[:-1].astype(np.int64)
        return np.split(flat, splits)
    raise ValueError(f"unknown kind {kind}")


# ---------------------------------------------------------------------------
# file-level API
# ---------------------------------------------------------------------------


def write_table(columns: Dict[str, Any], *,
                compress_blocks: bool = True) -> Tuple[bytes, Dict[str, Any]]:
    """Encode a column dict into a parq-lite file.

    Returns (file_bytes, stats) where stats = {column: {min,max}} for numeric
    columns — callers persist these in the delta-log add-action for skipping.

    ``compress_blocks=False`` skips the built-in opportunistic per-block
    zlib: callers that frame the whole file under a file-level codec (see
    :mod:`repro.lake.compression`) must hand it raw blocks, or the outer
    codec would grind against already-compressed high-entropy bytes.
    """
    if not columns:
        raise ValueError("empty table")
    num_rows = len(next(iter(columns.values())))
    blocks: List[bytes] = []
    metas: List[Dict[str, Any]] = []
    offset = 0
    for name, col in columns.items():
        raw, meta = _encode_column(name, col, num_rows,
                                   compress_blocks=compress_blocks)
        meta["offset"] = offset
        meta["length"] = len(raw)
        offset += len(raw)
        blocks.append(raw)
        metas.append(meta)
    header = json.dumps({"num_rows": num_rows, "columns": metas},
                        separators=(",", ":")).encode("utf-8")
    out = b"".join([MAGIC, struct.pack("<I", len(header)), header] + blocks)
    stats = {m["name"]: m["stats"] for m in metas if m.get("stats")}
    return out, {"num_rows": num_rows, "column_stats": stats}


def _header(data: bytes) -> Tuple[Dict[str, Any], int]:
    if data[:4] != MAGIC:
        raise ValueError("not a parq-lite file")
    (hlen,) = struct.unpack_from("<I", data, 4)
    # bytes(): json.loads rejects memoryviews, which the zero-copy frame
    # decode path now hands us
    header = json.loads(bytes(data[8:8 + hlen]))
    return header, 8 + hlen


def read_table(data: bytes, columns: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Decode a parq-lite file; optionally project a subset of columns."""
    header, base = _header(data)
    want = set(columns) if columns is not None else None
    out: Dict[str, Any] = {}
    for meta in header["columns"]:
        if want is not None and meta["name"] not in want:
            continue
        raw = data[base + meta["offset"]: base + meta["offset"] + meta["length"]]
        out[meta["name"]] = _decode_column(raw, meta)
    if want is not None and want - set(out):
        raise KeyError(f"missing columns: {sorted(want - set(out))}")
    return out


def num_rows(data: bytes) -> int:
    """Row count of a parq-lite file, read from the header only."""
    return _header(data)[0]["num_rows"]
