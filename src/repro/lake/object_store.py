"""Object-store abstraction underneath the delta log.

Cloud object stores (S3/GCS/ABS) are immutable key-value stores with
put / get / list / delete and (on some providers) put-if-absent. The delta
log only needs those five verbs, so the whole lake runs against this
interface. Two implementations:

* ``LocalFSObjectStore`` — keys are files under a root dir; put-if-absent is
  ``O_CREAT|O_EXCL`` (atomic on POSIX), which is how delta-on-HDFS commits.
* ``InMemoryObjectStore`` — dict-backed, with an optional latency/bandwidth
  model so benchmarks can reproduce the paper's 1 Gbps object-store setting
  on a CPU box (per-request RTT + bytes/bandwidth sleep, or virtual-clock
  accounting when ``virtual_clock=True`` so benchmarks don't actually sleep).
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple


class PutIfAbsentError(Exception):
    """Raised when a conditional put loses the race (key already exists)."""


class ObjectNotFoundError(KeyError):
    """Raised by ``get``/``head`` for a key that does not exist."""


class ObjectStore:
    """Interface: immutable blobs addressed by '/'-separated string keys."""

    def put(self, key: str, data: bytes, *, if_absent: bool = False) -> None:
        """Store ``data`` at ``key``; with ``if_absent`` raise
        :class:`PutIfAbsentError` instead of overwriting (the atomic
        commit primitive)."""
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        """The blob at ``key``; raises :class:`ObjectNotFoundError`."""
        raise NotImplementedError

    def list(self, prefix: str = "") -> Iterator[str]:
        """All keys starting with ``prefix``, in sorted order."""
        raise NotImplementedError

    def delete(self, key: str) -> None:
        """Remove ``key``; deleting a missing key is a no-op."""
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        """Whether ``key`` exists (a HEAD probe; costs one RTT)."""
        try:
            self.head(key)
            return True
        except ObjectNotFoundError:
            return False

    def head(self, key: str) -> int:
        """Size in bytes; raises ObjectNotFoundError."""
        raise NotImplementedError


class LocalFSObjectStore(ObjectStore):
    """Keys are files under a root directory (delta-on-HDFS style)."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, key: str) -> str:
        p = os.path.normpath(os.path.join(self.root, key))
        if not p.startswith(self.root):
            raise ValueError(f"key escapes root: {key!r}")
        return p

    def put(self, key: str, data: bytes, *, if_absent: bool = False) -> None:
        """Durably write ``data``; ``if_absent`` uses O_CREAT|O_EXCL
        (atomic on POSIX — the delta commit primitive)."""
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        if if_absent:
            # O_EXCL gives atomic put-if-absent on POSIX — the delta commit
            # primitive. No tmp+rename: rename would clobber a racer.
            try:
                fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
            except FileExistsError as e:
                raise PutIfAbsentError(key) from e
            try:
                os.write(fd, data)
                os.fsync(fd)
            finally:
                os.close(fd)
        else:
            tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)

    def get(self, key: str) -> bytes:
        """Read the file at ``key``; raises :class:`ObjectNotFoundError`."""
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError as e:
            raise ObjectNotFoundError(key) from e

    def list(self, prefix: str = "") -> Iterator[str]:
        """Sorted keys under ``prefix`` (in-flight .tmp files hidden)."""
        base = self.root
        out = []
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in filenames:
                if fn.endswith(".tmp") or ".tmp." in fn:
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn), base)
                rel = rel.replace(os.sep, "/")
                if rel.startswith(prefix):
                    out.append(rel)
        return iter(sorted(out))

    def delete(self, key: str) -> None:
        """Remove the file at ``key``; missing keys are a no-op."""
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def head(self, key: str) -> int:
        """Size in bytes; raises :class:`ObjectNotFoundError`."""
        try:
            return os.stat(self._path(key)).st_size
        except FileNotFoundError as e:
            raise ObjectNotFoundError(key) from e


@dataclass
class LatencyModel:
    """Paper setting: 1 Gbps network, object-store request overhead.

    ``rtt_s`` is charged per request; payload bytes are charged at
    ``bandwidth_bps``. With ``virtual_clock`` the cost is accumulated in
    ``elapsed_s`` instead of sleeping, so benchmarks measure modeled I/O time
    plus real encode/decode CPU time separately.

    ``parallelism`` models concurrent object-store channels (the read
    executor's width) and ``elapsed_s`` becomes the **makespan**. Causality
    is respected via the issuing thread: a request starts in virtual time
    no earlier than (a) its thread's previous request finished — a serial
    caller gets serial time regardless of the configured width — and (b)
    the least-loaded channel frees up. Only requests issued by genuinely
    concurrent threads (the executor's pool) overlap. Payload bytes still
    contend for the one shared link, so the makespan never beats
    ``total_bytes / bandwidth``. ``serial_s`` keeps the width-1 sum so
    benchmarks can report both without re-running.
    """

    rtt_s: float = 0.010
    bandwidth_bps: float = 1e9  # 1 Gbps, as in the paper's testbed
    virtual_clock: bool = True
    parallelism: int = 1
    # virtual-clock mode only: hold the calling thread for cost*scale real
    # seconds. In-memory gets return instantly, so without this one pool
    # worker can drain the whole fetch queue and the per-thread causality
    # rule under-models achievable parallelism; a small occupancy makes
    # thread scheduling mirror modeled request durations.
    occupancy_scale: float = 0.0
    elapsed_s: float = 0.0
    serial_s: float = 0.0
    compute_s: float = 0.0
    io_elapsed_s: float = 0.0
    requests: int = 0
    bytes_moved: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _channels: list = field(default_factory=list, repr=False)
    _thread_done: dict = field(default_factory=dict, repr=False)
    _thread_latency: dict = field(default_factory=dict, repr=False)
    _transfer_s: float = field(default=0.0, repr=False)

    def charge(self, nbytes: int) -> None:
        """Account one request moving ``nbytes`` payload bytes.

        Charged at the size the store actually moves — for
        frame-compressed part files that is the *compressed* size, which
        is how benchmarks see the bandwidth win honestly."""
        transfer = (nbytes * 8.0) / self.bandwidth_bps
        cost = self.rtt_s + transfer
        tid = threading.get_ident()
        with self._lock:
            self.requests += 1
            self.bytes_moved += nbytes
            self.serial_s += cost
            if self.parallelism <= 1:
                self.elapsed_s += cost
                self.io_elapsed_s += cost
                self._thread_latency[tid] = cost
                self._thread_done[tid] = self.elapsed_s
            else:
                if len(self._channels) != self.parallelism:
                    self._channels = [0.0] * self.parallelism
                i = min(range(self.parallelism), key=self._channels.__getitem__)
                prev = self._thread_done.get(tid)
                start = max(self._channels[i], prev or 0.0)
                done = start + cost
                # request latency as the caller experiences it: from the
                # moment this thread became free (its previous request's
                # completion — or now, for its first request) until this
                # one finishes. Queueing behind busy channels is latency;
                # the thread's own earlier work is not.
                ready = prev if prev is not None else self._channels[i]
                self._thread_latency[tid] = done - ready
                self._channels[i] = done
                self._thread_done[tid] = done
                self._transfer_s += transfer
                # channels hold pure wire time; compute charges (decode
                # stage) only ever push elapsed_s past io_elapsed_s
                self.io_elapsed_s = max(max(self._channels), self._transfer_s)
                self.elapsed_s = max(self.elapsed_s, self.io_elapsed_s)
        if not self.virtual_clock:
            time.sleep(cost)
        elif self.occupancy_scale > 0.0:
            time.sleep(cost * self.occupancy_scale)

    def charge_compute(self, seconds: float, *,
                       not_before: Optional[float] = None) -> None:
        """Charge real CPU seconds (frame decode) onto the virtual timeline.

        The calling thread's virtual clock advances by ``seconds`` starting
        at max(its previous virtual completion, ``not_before``) —
        ``not_before`` carries the producing fetch's virtual completion, so
        decode causally follows the bytes it decodes while overlapping
        other threads' wire time. Compute is off-channel: it never occupies
        an object-store channel, so ``io_elapsed_s`` stays the pure-I/O
        makespan and ``elapsed_s`` becomes the pipelined makespan.
        """
        s = max(0.0, float(seconds))
        tid = threading.get_ident()
        with self._lock:
            self.compute_s += s
            self.serial_s += s
            if self.parallelism <= 1:
                self.elapsed_s += s
                self._thread_done[tid] = self.elapsed_s
                return
            start = max(self._thread_done.get(tid, 0.0), not_before or 0.0)
            done = start + s
            self._thread_done[tid] = done
            if done > self.elapsed_s:
                self.elapsed_s = done

    def thread_done_s(self) -> Optional[float]:
        """The calling thread's virtual completion time (None if it has
        not been charged yet, or in real-sleep mode). The decode stage
        reads this on the fetch thread to timestamp when a frame's bytes
        exist in virtual time."""
        if not self.virtual_clock:
            return None
        with self._lock:
            return self._thread_done.get(threading.get_ident())

    def request_latency_s(self) -> Optional[float]:
        """Virtual-clock latency of the calling thread's last request.

        Read by the :class:`~repro.lake.io.ReadExecutor` right after a
        ``get`` returns, so latency histograms on modeled stores record
        deterministic virtual durations instead of wall-clock noise.
        Returns None if this thread has not issued a request (or in
        real-sleep mode, where wall clock is already the truth)."""
        if not self.virtual_clock:
            return None
        with self._lock:
            return self._thread_latency.get(threading.get_ident())

    def reset(self) -> None:
        """Zero the accumulated time/request/byte accounting."""
        with self._lock:
            self.elapsed_s = 0.0
            self.serial_s = 0.0
            self.compute_s = 0.0
            self.io_elapsed_s = 0.0
            self.requests = 0
            self.bytes_moved = 0
            self._channels = []
            self._thread_done = {}
            self._thread_latency = {}
            self._transfer_s = 0.0


class InMemoryObjectStore(ObjectStore):
    """Dict-backed store with an optional modeled-latency account."""

    def __init__(self, latency: Optional[LatencyModel] = None,
                 fail_after_puts: Optional[int] = None):
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.latency = latency
        # Fault-injection hook: raise IOError after N puts (tests crash-mid-
        # checkpoint recovery).
        self.fail_after_puts = fail_after_puts
        self._puts = 0

    def put(self, key: str, data: bytes, *, if_absent: bool = False) -> None:
        """Store ``data`` (charging modeled latency); ``if_absent``
        raises :class:`PutIfAbsentError` when the key exists."""
        if self.latency:
            self.latency.charge(len(data))
        with self._lock:
            if self.fail_after_puts is not None and self._puts >= self.fail_after_puts:
                raise IOError(f"injected fault after {self._puts} puts")
            if if_absent and key in self._data:
                raise PutIfAbsentError(key)
            self._data[key] = bytes(data)
            self._puts += 1

    def get(self, key: str) -> bytes:
        """The stored blob (charging modeled latency for its size)."""
        with self._lock:
            if key not in self._data:
                raise ObjectNotFoundError(key)
            data = self._data[key]
        if self.latency:
            self.latency.charge(len(data))
        return data

    def list(self, prefix: str = "") -> Iterator[str]:
        """Sorted keys under ``prefix`` (one modeled list request)."""
        if self.latency:
            self.latency.charge(0)
        with self._lock:
            keys = sorted(k for k in self._data if k.startswith(prefix))
        return iter(keys)

    def delete(self, key: str) -> None:
        """Drop ``key``; missing keys are a no-op."""
        with self._lock:
            self._data.pop(key, None)

    def head(self, key: str) -> int:
        """Size in bytes; raises :class:`ObjectNotFoundError`."""
        # a HEAD is a real round-trip on S3/GCS — charge the RTT (0 bytes)
        # so latest_version() probing shows up in modeled I/O accounting
        if self.latency:
            self.latency.charge(0)
        with self._lock:
            if key not in self._data:
                raise ObjectNotFoundError(key)
            return len(self._data[key])


class InjectedFault(IOError):
    """The error a firing :class:`FaultRule` raises (distinguishable from
    real I/O failures in test assertions)."""


@dataclass
class FaultRule:
    """One deterministic failure in a :class:`FaultInjectingObjectStore`.

    Fires on matching operations number ``nth`` .. ``nth + count - 1``
    (1-based, counted per rule across the wrapper's lifetime). ``key``
    is a substring filter on the object key (None matches every key; for
    ``list`` it matches the prefix argument). Actions:

    * ``"raise"`` — raise :class:`InjectedFault` *before* the operation
      has any effect (a request that never reached the store);
    * ``"raise-after"`` — apply the operation fully, then raise (a lost
      acknowledgement: the classic ambiguous-commit failure);
    * ``"partial"`` — ``put`` only: store the first
      ``int(len(data) * partial_frac)`` bytes, then raise (a torn upload).
      Conditional puts (``if_absent=True``) are the store's atomic commit
      primitive — real object stores never tear them — so a partial rule
      on one degrades to ``"raise"`` (no effect);
    * ``"notfound"`` — ``get``/``head`` raise
      :class:`ObjectNotFoundError` despite the key existing (HEAD-after-PUT
      eventual consistency);
    * ``"latency"`` — charge ``latency_s`` extra seconds (virtual when the
      inner store has a virtual-clock :class:`LatencyModel`, a real sleep
      otherwise), then proceed normally.
    """

    op: str                       # "put" | "get" | "head" | "delete" | "list"
    action: str = "raise"
    key: Optional[str] = None
    nth: int = 1
    count: int = 1
    latency_s: float = 0.0
    partial_frac: float = 0.5
    seen: int = field(default=0, repr=False)   # matching ops observed so far

    def matches(self, op: str, key: str) -> bool:
        return op == self.op and (self.key is None or self.key in key)

    @property
    def exhausted(self) -> bool:
        """Whether this rule can never fire again."""
        return self.seen >= self.nth + self.count - 1


class FaultInjectingObjectStore(ObjectStore):
    """Wraps any :class:`ObjectStore` with deterministic failure schedules.

    The reusable crash-testing harness: tests hand it a list of
    :class:`FaultRule` and drive the writer under test until a rule fires —
    "kill the writer after the 3rd data put", "lose the ack of the commit
    put", "tear the 2nd upload halfway". Every operation (faulted or not)
    is appended to ``op_log`` as ``(op, key)`` so assertions can reconstruct
    exactly what reached the store.

    Unknown attributes delegate to the wrapped store (``latency``, ``root``,
    ``_data``...), and the io-cache identity token is shared with the inner
    instance, so upload guards, leases, and block-cache entries key to the
    same physical store whether a component holds the wrapper or the
    wrapped instance.
    """

    def __init__(self, inner: ObjectStore,
                 rules: Optional[List[FaultRule]] = None):
        self.inner = inner
        self.rules: List[FaultRule] = list(rules or ())
        self.op_log: List[Tuple[str, str]] = []
        self.injected = 0
        self._fault_lock = threading.Lock()

    def __getattr__(self, name: str):
        if name == "inner":
            raise AttributeError(name)
        if name == "_io_cache_token":
            # force the inner store to own the token, then mirror it: both
            # handles must resolve to one store_scope / lease scope
            from .io import _store_token
            tok = _store_token(self.inner)
            self._io_cache_token = tok
            return tok
        return getattr(self.inner, name)

    def add_rule(self, rule: FaultRule) -> FaultRule:
        """Arm one more rule (occurrence counting starts now)."""
        with self._fault_lock:
            self.rules.append(rule)
        return rule

    def clear_rules(self) -> None:
        """Disarm every rule (the op log and counters are kept)."""
        with self._fault_lock:
            self.rules = []

    def _check(self, op: str, key: str) -> Optional[FaultRule]:
        """Record the op; return the first rule due to fire on it."""
        with self._fault_lock:
            self.op_log.append((op, key))
            firing = None
            for rule in self.rules:
                if not rule.matches(op, key):
                    continue
                rule.seen += 1
                if firing is None and \
                        rule.nth <= rule.seen < rule.nth + rule.count:
                    firing = rule
            if firing is not None:
                self.injected += 1
            return firing

    def _spike(self, seconds: float) -> None:
        lm = getattr(self.inner, "latency", None)
        if lm is not None and getattr(lm, "virtual_clock", False):
            # model the spike as extra wire time: one request whose
            # transfer takes exactly `seconds` on top of the RTT
            lm.charge(int(seconds * lm.bandwidth_bps / 8))
        else:
            time.sleep(seconds)

    def put(self, key: str, data: bytes, *, if_absent: bool = False) -> None:
        rule = self._check("put", key)
        if rule is not None:
            if rule.action == "raise-after":
                self.inner.put(key, data, if_absent=if_absent)
                raise InjectedFault(f"lost ack of put {key!r}")
            if rule.action == "partial" and not if_absent:
                self.inner.put(key, data[:int(len(data) * rule.partial_frac)])
                raise InjectedFault(f"torn put {key!r}")
            if rule.action == "latency":
                self._spike(rule.latency_s)
            else:
                raise InjectedFault(f"injected fault on put {key!r}")
        self.inner.put(key, data, if_absent=if_absent)

    def get(self, key: str) -> bytes:
        rule = self._check("get", key)
        if rule is not None:
            if rule.action == "notfound":
                raise ObjectNotFoundError(key)
            if rule.action == "latency":
                self._spike(rule.latency_s)
            else:
                raise InjectedFault(f"injected fault on get {key!r}")
        return self.inner.get(key)

    def head(self, key: str) -> int:
        rule = self._check("head", key)
        if rule is not None:
            if rule.action == "notfound":
                raise ObjectNotFoundError(key)
            if rule.action == "latency":
                self._spike(rule.latency_s)
            else:
                raise InjectedFault(f"injected fault on head {key!r}")
        return self.inner.head(key)

    def list(self, prefix: str = "") -> Iterator[str]:
        rule = self._check("list", prefix)
        if rule is not None:
            if rule.action == "latency":
                self._spike(rule.latency_s)
            else:
                raise InjectedFault(f"injected fault on list {prefix!r}")
        return self.inner.list(prefix)

    def delete(self, key: str) -> None:
        rule = self._check("delete", key)
        if rule is not None:
            if rule.action == "raise-after":
                self.inner.delete(key)
                raise InjectedFault(f"lost ack of delete {key!r}")
            if rule.action == "latency":
                self._spike(rule.latency_s)
            else:
                raise InjectedFault(f"injected fault on delete {key!r}")
        self.inner.delete(key)
