"""Shared parallel read-path subsystem for the lake layer.

Every read consumer in the framework (``DeltaTable.scan``, the tensor
store's ``get``/``get_coo``/``get_slice``, the FTSF training loader, serve
weight loading) funnels its object-store fetches through one
:class:`ReadExecutor`, which provides:

* a **bounded I/O thread pool** so a multi-chunk read costs the makespan of
  concurrent gets, not the sum of per-file RTTs (Deep Lake's streaming
  fetch layer is the reference design here);
* an **LRU block cache** keyed by ``(store, object key)`` holding immutable
  data-file bytes — delta data files are write-once, so cached blocks can
  never go stale; log/metadata reads bypass the cache. The cache is split
  into **priority-class partitions** with independent byte budgets
  (``cache.add_partition``): long-tail churn in one class can never evict
  another class's working set — how the serving gateway keeps a hot base
  model resident while variant traffic churns;
* **transparent decompression**: part files framed by a chunk-blob codec
  (:mod:`repro.lake.compression`) are unframed as they arrive off the
  wire, so the cache stores *decoded* blocks — a warm read pays neither
  the bandwidth nor the decode cost — while the object store (and any
  modeled :class:`~repro.lake.object_store.LatencyModel`) charges the
  compressed size; unframed bytes pass through untouched. Decode runs on
  a **staged pool** (``decode_workers``) with a bounded handoff queue, so
  decompression of chunk *k* overlaps the fetch of chunk *k+1* instead of
  serializing behind the wire — ``ReadStats.decode_s`` /
  ``decode_overlap_frac`` carry the evidence;
* **request hedging** (straggler mitigation): if a get hasn't finished
  after ``hedge_after_s`` a duplicate is raced against it and the first
  result wins — object-store reads are idempotent so duplicates are safe;
* a **work pool** for composite background jobs (loader prefetch steps,
  parallel weight loads). Composite jobs may block on I/O futures; I/O
  tasks never submit work, so the two-pool split is deadlock-free by
  construction.
"""

from __future__ import annotations

import itertools
import math
import queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from .compression import decode_frame, frame_info, is_framed

DEFAULT_MAX_WORKERS = 8
DEFAULT_CACHE_BYTES = 64 << 20
# staged decode: frames are unwrapped on a small dedicated pool so the
# fetch thread goes straight back to the wire — decompression of chunk k
# overlaps the fetch of chunk k+1. 0 disables the stage (decode inline on
# the fetch thread, the pre-pipeline behavior).
DEFAULT_DECODE_WORKERS = 2

# delta frames may chain (defensively bounded; writers only ever target
# non-delta bases, so a well-formed store needs depth 1)
MAX_DELTA_DEPTH = 4


def content_cache_key(content_hash: str) -> str:
    """Block-cache name for content-addressed bytes.

    Two add-actions aliasing the same stored object (dedup) or a delta
    frame reconstructing against a base share one cache entry when their
    fetches are named by content hash instead of object key.
    """
    return "cas:" + content_hash

# monotonically increasing token per object-store instance: cache keys must
# survive id() reuse after GC, so the token rides on the store object itself
_store_tokens = itertools.count()


def _store_token(store: Any) -> int:
    tok = getattr(store, "_io_cache_token", None)
    if tok is None:
        tok = next(_store_tokens)
        try:
            store._io_cache_token = tok
        except AttributeError:  # __slots__ store: fall back to identity
            return id(store)
    return tok


def store_scope(store: Any) -> tuple:
    """Stable in-process identity for one *physical* object store.

    Filesystem-backed stores identify by their root path, so two
    ``LocalFSObjectStore`` clients of the same directory compare equal —
    cross-client coordination (snapshot leases, in-flight upload guards)
    keys on this. Stores without a path identity fall back to per-instance
    identity via the cache token.
    """
    root = getattr(store, "root", None)
    if isinstance(root, str):
        return ("fs", root)
    return ("instance", _store_token(store))


class LatencyHistogram:
    """Thread-safe log-bucketed latency histogram with quantile accessors.

    Buckets are geometric (HDR-histogram style): ~4% relative resolution
    from 1 µs up past 1000 s in O(1) memory, so recording a sample is a
    lock + an integer increment — cheap enough to sit on every object get.
    Quantiles interpolate inside the winning bucket, which keeps p50/p95/
    p99 honest to within one bucket width. On the modeled object store the
    recorded samples are **virtual-clock** durations (queueing + RTT +
    transfer, see :meth:`LatencyModel.request_latency_s`), so benchmark
    tail latencies are deterministic rather than scheduler noise.
    """

    MIN_S = 1e-6
    GROWTH = 1.04
    N_BUCKETS = 560  # MIN_S * GROWTH**560 ≈ 3.3e3 s — covers any sane read

    def __init__(self):
        self._counts = [0] * self.N_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def _bucket(self, seconds: float) -> int:
        if seconds <= self.MIN_S:
            return 0
        b = int(math.log(seconds / self.MIN_S) / math.log(self.GROWTH))
        return min(b, self.N_BUCKETS - 1)

    def observe(self, seconds: float) -> None:
        """Record one latency sample (negative samples clamp to 0)."""
        s = max(0.0, float(seconds))
        with self._lock:
            self._counts[self._bucket(s)] += 1
            self._count += 1
            self._sum += s
            if s > self._max:
                self._max = s

    @property
    def count(self) -> int:
        """Number of recorded samples."""
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        """Mean recorded latency in seconds (0.0 when empty)."""
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    @property
    def max(self) -> float:
        """Largest recorded sample in seconds."""
        with self._lock:
            return self._max

    def quantile(self, q: float) -> Optional[float]:
        """The ``q`` quantile (0..1) in seconds; None when empty.

        Returns the bucket's geometric midpoint, capped at the observed
        max so p99 of a single-valued distribution equals that value.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            if self._count == 0:
                return None
            rank = q * (self._count - 1)
            seen = 0
            for b, c in enumerate(self._counts):
                seen += c
                if seen > rank:
                    lo = self.MIN_S * self.GROWTH ** b
                    return min(lo * math.sqrt(self.GROWTH), self._max)
            return self._max  # pragma: no cover - rank < count always hits

    def p50(self) -> Optional[float]:
        """Median latency in seconds (None when empty)."""
        return self.quantile(0.50)

    def p95(self) -> Optional[float]:
        """95th-percentile latency in seconds (None when empty)."""
        return self.quantile(0.95)

    def p99(self) -> Optional[float]:
        """99th-percentile latency in seconds (None when empty)."""
        return self.quantile(0.99)

    def summary(self) -> Dict[str, Optional[float]]:
        """``{count, mean_s, p50_s, p95_s, p99_s, max_s}`` for reporting."""
        return {"count": self.count, "mean_s": self.mean,
                "p50_s": self.p50(), "p95_s": self.p95(),
                "p99_s": self.p99(), "max_s": self.max}

    def reset(self) -> None:
        """Drop every recorded sample (benchmark epochs)."""
        with self._lock:
            self._counts = [0] * self.N_BUCKETS
            self._count = 0
            self._sum = 0.0
            self._max = 0.0


@dataclass
class ReadStats:
    """Counters for the read path (thread-safe)."""

    gets: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    hedges_launched: int = 0
    hedges_won: int = 0
    # chunk-blob decompression: frames unwrapped off the wire, and the
    # compressed (wire) vs decoded sizes they moved — the space claim
    frames_decoded: int = 0
    frame_bytes_wire: int = 0
    frame_bytes_decoded: int = 0
    # variant delta frames reconstructed against their base object
    deltas_reconstructed: int = 0
    # read_many fetch scheduling: merged plans built, requests they
    # covered, unique keys actually fetched, and references that were
    # deduplicated away (a shared chunk key counted once per extra
    # requester) — the "shared chunk fetched once per plan" claim
    plans: int = 0
    plan_requests: int = 0
    plan_keys_fetched: int = 0
    plan_keys_deduped: int = 0
    # staged decode: real seconds spent unwrapping frames, the portion of
    # that time during which at least one fetch was in flight (wall-clock
    # sampled — the overlap evidence), frames decoded off the fetch
    # thread, and bytes handed to an accelerator device by device reads
    decode_s: float = 0.0
    decode_overlap_s: float = 0.0
    decodes_offloaded: int = 0
    bytes_to_device: int = 0
    # per-request latency histogram (virtual-clock durations on a modeled
    # store, wall-clock otherwise); see LatencyHistogram
    latency: LatencyHistogram = field(default_factory=LatencyHistogram,
                                      repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    @property
    def decode_overlap_frac(self) -> float:
        """Fraction of decode seconds that overlapped an in-flight fetch."""
        return self.decode_overlap_s / self.decode_s if self.decode_s else 0.0

    def bump(self, **deltas: float) -> None:
        """Atomically add ``deltas`` to the named counters."""
        with self._lock:
            for k, d in deltas.items():
                setattr(self, k, getattr(self, k) + d)

    def reset(self) -> None:
        """Zero every counter (benchmark epochs)."""
        with self._lock:
            self.gets = self.cache_hits = self.cache_misses = 0
            self.hedges_launched = self.hedges_won = 0
            self.frames_decoded = 0
            self.frame_bytes_wire = self.frame_bytes_decoded = 0
            self.deltas_reconstructed = 0
            self.plans = self.plan_requests = 0
            self.plan_keys_fetched = self.plan_keys_deduped = 0
            self.decode_s = self.decode_overlap_s = 0.0
            self.decodes_offloaded = 0
            self.bytes_to_device = 0
        self.latency.reset()


DEFAULT_PARTITION = "default"


class _Partition:
    """One priority class inside the block cache: its own LRU + budget."""

    __slots__ = ("capacity", "pinned", "blocks", "nbytes", "evictions")

    def __init__(self, capacity_bytes: int, pinned: bool = False):
        self.capacity = int(capacity_bytes)
        self.pinned = pinned
        self.blocks: "OrderedDict[Tuple[int, str], bytes]" = OrderedDict()
        self.nbytes = 0
        self.evictions = 0


class BlockCache:
    """Thread-safe LRU over immutable blocks, bounded by per-partition bytes.

    The cache is split into **partitions** (priority classes), each with
    its own byte budget and LRU order. Eviction pressure never crosses a
    partition boundary: a long-tail scan churning the ``default``
    partition cannot evict blocks a higher-priority class (a pinned hot
    base model) holds — the serving gateway's cache-isolation story.
    Lookups are partition-blind (one global key -> partition map), so a
    block cached by any class serves every reader; a ``get`` that names a
    different partition *promotes* the block into it (a hot-class read
    rescues a base-model block that first arrived as a long-tail delta
    prefetch). ``add_partition(pinned=True)`` makes a class reject inserts
    past its budget instead of evicting — a hard pin for working sets
    that must never churn — and its residents never demote: lower-priority
    readers are served from the pinned copy in place.

    ``BlockCache(capacity_bytes)`` with no extra partitions behaves
    exactly like the old single-LRU cache (one ``default`` partition).
    """

    def __init__(self, capacity_bytes: int = DEFAULT_CACHE_BYTES):
        self.capacity = int(capacity_bytes)
        self._parts: Dict[str, _Partition] = {
            DEFAULT_PARTITION: _Partition(self.capacity)}
        self._where: Dict[Tuple[int, str], str] = {}
        self._lock = threading.Lock()

    # -- partition management -------------------------------------------------

    def add_partition(self, name: str, capacity_bytes: int, *,
                      pinned: bool = False) -> None:
        """Create (or resize) priority class ``name`` with its own budget.

        ``pinned`` partitions reject inserts past their budget instead of
        evicting — resident blocks can only leave via ``invalidate`` /
        ``clear``. Re-adding an existing partition adjusts its budget (and
        evicts down to it for LRU partitions) without dropping blocks.
        """
        if name == DEFAULT_PARTITION:
            raise ValueError("the default partition always exists; "
                             "size it via the cache capacity")
        with self._lock:
            part = self._parts.get(name)
            if part is None:
                self._parts[name] = _Partition(capacity_bytes, pinned)
                return
            part.capacity = int(capacity_bytes)
            part.pinned = pinned
            if not pinned:
                self._evict_locked(part)

    def partitions(self) -> Dict[str, Dict[str, int]]:
        """Per-partition occupancy: name -> {capacity, nbytes, blocks,
        evictions} (the gateway's cache-isolation observability)."""
        with self._lock:
            return {name: {"capacity_bytes": p.capacity, "nbytes": p.nbytes,
                           "blocks": len(p.blocks), "evictions": p.evictions,
                           "pinned": int(p.pinned)}
                    for name, p in self._parts.items()}

    def _evict_locked(self, part: _Partition) -> None:
        while part.nbytes > part.capacity:
            key, evicted = part.blocks.popitem(last=False)
            part.nbytes -= len(evicted)
            part.evictions += 1
            self._where.pop(key, None)

    # -- block access ----------------------------------------------------------

    def get(self, key: Tuple[int, str],
            partition: Optional[str] = None) -> Optional[bytes]:
        """The cached block (refreshing its LRU position) or None.

        Lookup spans all partitions. When ``partition`` names a different
        class than the block's current home, the hit **promotes** the
        block into the named partition (subject to that partition's
        budget), so priority follows the readers actually touching it —
        unless the home is *pinned*: a pinned class never loses residents
        to lower-priority readers (the long-tail variant churn reading a
        hot tenant's base chunks must not demote them into its own
        churning partition).
        """
        with self._lock:
            home = self._where.get(key)
            if home is None:
                return None
            part = self._parts[home]
            data = part.blocks[key]
            if partition is not None and partition != home \
                    and partition in self._parts and not part.pinned:
                self._put_locked(key, data, partition)
            else:
                part.blocks.move_to_end(key)
            return data

    def _put_locked(self, key: Tuple[int, str], data: bytes,
                    partition: str) -> None:
        part = self._parts[partition]
        if len(data) > part.capacity:
            return  # never churn a whole partition for one oversized block
        if part.pinned and part.nbytes + len(data) > part.capacity:
            return  # pinned class is full: reject, never evict residents
        home = self._where.get(key)
        if home is not None:
            old_part = self._parts[home]
            if old_part.pinned and home != partition:
                old_part.blocks.move_to_end(key)
                return  # pinned residents never demote to another class
            old = old_part.blocks.pop(key)
            old_part.nbytes -= len(old)
        part.blocks[key] = data
        part.nbytes += len(data)
        self._where[key] = partition
        self._evict_locked(part)

    def put(self, key: Tuple[int, str], data: bytes,
            partition: Optional[str] = None) -> None:
        """Insert a block into ``partition`` (default class when None),
        evicting that partition's LRU entries past its byte budget."""
        name = partition if partition in self._parts else DEFAULT_PARTITION
        with self._lock:
            self._put_locked(key, data, name)

    def invalidate(self, key: Tuple[int, str]) -> None:
        """Drop one block (deleted objects must not serve from cache)."""
        with self._lock:
            home = self._where.pop(key, None)
            if home is not None:
                part = self._parts[home]
                old = part.blocks.pop(key, None)
                if old is not None:
                    part.nbytes -= len(old)

    def clear(self) -> None:
        """Drop every cached block (all partitions; budgets survive)."""
        with self._lock:
            for part in self._parts.values():
                part.blocks.clear()
                part.nbytes = 0
            self._where.clear()

    @property
    def nbytes(self) -> int:
        """Total bytes currently cached across all partitions."""
        with self._lock:
            return sum(p.nbytes for p in self._parts.values())

    def __len__(self) -> int:
        with self._lock:
            return sum(len(p.blocks) for p in self._parts.values())


class ReadExecutor:
    """Bounded thread pool + block cache + hedging for object-store reads.

    ``max_workers`` bounds concurrent in-flight gets (the paper's 1 Gbps
    testbed saturates around 8 streams; width is configurable so benchmarks
    can sweep it). ``cache_bytes=0`` disables caching. ``hedge_after_s``
    enables hedged gets on every fetch routed through this executor.

    ``decode_workers`` sizes the staged-decode pool: framed (compressed)
    blocks come off the wire on an I/O thread but are decompressed on this
    separate stage, so decode of chunk *k* overlaps the fetch of *k+1*.
    ``decode_queue`` bounds frames parked between the stages (backpressure:
    when decoders fall behind, fetch threads block handing off rather than
    buffering the whole scan). ``decode_workers=0`` restores inline decode
    on the fetch thread.
    """

    def __init__(self, max_workers: int = DEFAULT_MAX_WORKERS, *,
                 cache_bytes: int = DEFAULT_CACHE_BYTES,
                 hedge_after_s: Optional[float] = None,
                 hedge_attempts: int = 2,
                 decode_workers: Optional[int] = None,
                 decode_queue: Optional[int] = None):
        self.max_workers = max(1, int(max_workers))
        self.cache = BlockCache(cache_bytes)
        self.stats = ReadStats()
        self.hedge_after_s = hedge_after_s
        self.hedge_attempts = max(1, int(hedge_attempts))
        self.decode_workers = (DEFAULT_DECODE_WORKERS if decode_workers is None
                               else max(0, int(decode_workers)))
        self._io = ThreadPoolExecutor(
            max_workers=self.max_workers, thread_name_prefix="lakeio")
        self._work = ThreadPoolExecutor(
            max_workers=self.max_workers, thread_name_prefix="lakework")
        self._decode: Optional[ThreadPoolExecutor] = None
        if self.decode_workers:
            self._decode = ThreadPoolExecutor(
                max_workers=self.decode_workers,
                thread_name_prefix="lakedecode")
            slots = (4 * self.decode_workers if decode_queue is None
                     else max(1, int(decode_queue)))
            self._decode_slots = threading.BoundedSemaphore(slots)
        # gets currently on the wire (sampled by the decode stage as the
        # wall-clock overlap evidence)
        self._inflight = 0
        self._inflight_lock = threading.Lock()

    # -- raw gets ------------------------------------------------------------

    def _timed_get(self, store: Any, key: str) -> bytes:
        # one *attempt* = one histogram sample (hedged retries each record
        # their own latency on their own thread). On a modeled store the
        # sample is the deterministic virtual-clock duration of this
        # request (queueing + RTT + transfer); otherwise wall clock.
        t0 = time.perf_counter()
        with self._inflight_lock:
            self._inflight += 1
        try:
            data = store.get(key)
        finally:
            with self._inflight_lock:
                self._inflight -= 1
        lm = getattr(store, "latency", None)
        lat = getattr(lm, "request_latency_s", lambda: None)()
        if lat is None:
            lat = time.perf_counter() - t0
        self.stats.latency.observe(lat)
        return data

    def _get_raw(self, store: Any, key: str) -> bytes:
        self.stats.bump(gets=1)
        if self.hedge_after_s is None or self.hedge_attempts <= 1:
            return self._timed_get(store, key)
        return self.hedged(lambda: self._timed_get(store, key),
                           hedge_after_s=self.hedge_after_s,
                           attempts=self.hedge_attempts)

    def _decode_wire(self, store: Any, data: bytes, depth: int = 0,
                     partition: Optional[str] = None) -> bytes:
        # unframe compressed part files here, off the wire: the cache (and
        # every consumer above) sees decoded bytes, while the store charged
        # bandwidth for the compressed size it actually moved. Delta frames
        # additionally reconstruct against their base object (fetched
        # inline on this thread — never re-submitted to the I/O pool, so a
        # saturated pool cannot deadlock on its own dependencies).
        info = frame_info(data)
        if info is None:
            return data
        if info.get("delta_base") is not None:
            if depth >= MAX_DELTA_DEPTH:
                raise ValueError(
                    f"delta base chain deeper than {MAX_DELTA_DEPTH}")
            self.stats.bump(deltas_reconstructed=1)
        wire = len(data)
        data = decode_frame(
            data,
            base_fetch=lambda bk, bh: self._base_bytes(store, bk, bh,
                                                       depth + 1, partition))
        self.stats.bump(frames_decoded=1, frame_bytes_wire=wire,
                        frame_bytes_decoded=len(data))
        return data

    def _base_bytes(self, store: Any, key: str,
                    content_hash: Optional[str] = None,
                    depth: int = 1,
                    partition: Optional[str] = None) -> bytes:
        # decoded bytes of a delta frame's base: content-hash-named cache
        # lookup first (shared with dedup'd reads of the base itself),
        # then a plain inline get + decode
        ck: Optional[Tuple[int, str]] = None
        if self.cache.capacity:
            name = content_cache_key(content_hash) if content_hash else key
            ck = (_store_token(store), name)
            hit = self.cache.get(ck, partition)
            if hit is not None:
                self.stats.bump(cache_hits=1)
                return hit
            self.stats.bump(cache_misses=1)
        data = self._decode_wire(store, self._get_raw(store, key), depth,
                                 partition)
        if ck is not None:
            self.cache.put(ck, data, partition)
        return data

    def _fetch_miss(self, store: Any, key: str,
                    cache_key: Optional[Tuple[int, str]],
                    partition: Optional[str] = None) -> bytes:
        # inline path (decode stage disabled): fetch and decode on the same
        # I/O thread, decode serializing ahead of this thread's next fetch
        raw = self._get_raw(store, key)
        data = self._decode_timed(store, raw, partition,
                                  self._virtual_done(store))
        if cache_key is not None:
            self.cache.put(cache_key, data, partition)
        return data

    # -- staged decode -------------------------------------------------------

    def _virtual_done(self, store: Any) -> Optional[float]:
        # the calling thread's virtual completion on a modeled store: the
        # moment the bytes it just fetched exist, which the decode stage
        # passes along as the causal floor for its compute charge. (Hedged
        # gets land on daemon threads, so the winner's completion may not
        # be visible here — the decode charge then floors at the decode
        # thread's own timeline, a benign underestimate.)
        fn = getattr(getattr(store, "latency", None), "thread_done_s", None)
        return fn() if fn is not None else None

    def _decode_timed(self, store: Any, raw: bytes,
                      partition: Optional[str],
                      ready: Optional[float]) -> bytes:
        """Decode ``raw`` with time accounting; unframed bytes pass through.

        Real decode seconds are bumped into the stats and — on a modeled
        store — charged onto the virtual timeline via ``charge_compute``
        (starting no earlier than ``ready``, the fetch's virtual
        completion), so ``elapsed_s`` reports the pipelined makespan while
        ``io_elapsed_s`` keeps the pure wire time.
        """
        if not is_framed(raw):
            return raw
        t0 = time.perf_counter()
        overlapped = self._inflight > 0
        data = self._decode_wire(store, raw, partition=partition)
        d = time.perf_counter() - t0
        overlapped = overlapped or self._inflight > 0
        self.stats.bump(decode_s=d, decode_overlap_s=d if overlapped else 0.0)
        lm = getattr(store, "latency", None)
        if lm is not None and getattr(lm, "virtual_clock", False):
            charge = getattr(lm, "charge_compute", None)
            if charge is not None:
                charge(d, not_before=ready)
        return data

    def _submit_miss(self, store: Any, key: str,
                     cache_key: Optional[Tuple[int, str]],
                     partition: Optional[str]) -> Future:
        """Submit one cache-miss fetch; decode rides the staged pool."""
        if self._decode is None:
            return self._io.submit(self._fetch_miss, store, key, cache_key,
                                   partition)
        out: Future = Future()
        self._io.submit(self._wire_stage, store, key, cache_key, partition,
                        out)
        return out

    def _wire_stage(self, store: Any, key: str,
                    cache_key: Optional[Tuple[int, str]],
                    partition: Optional[str], out: Future) -> None:
        if not out.set_running_or_notify_cancel():
            return
        try:
            raw = self._get_raw(store, key)
        except BaseException as e:
            out.set_exception(e)
            return
        if not is_framed(raw):
            # nothing to decode — complete on the wire thread, no handoff
            if cache_key is not None:
                self.cache.put(cache_key, raw, partition)
            out.set_result(raw)
            return
        ready = self._virtual_done(store)
        # bounded handoff: when decoders fall behind, the fetch thread
        # blocks here instead of buffering unbounded frames
        self._decode_slots.acquire()
        self.stats.bump(decodes_offloaded=1)
        self._decode.submit(self._decode_stage, store, raw, cache_key,
                            partition, ready, out)

    def _decode_stage(self, store: Any, raw: bytes,
                      cache_key: Optional[Tuple[int, str]],
                      partition: Optional[str], ready: Optional[float],
                      out: Future) -> None:
        try:
            data = self._decode_timed(store, raw, partition, ready)
            if cache_key is not None:
                self.cache.put(cache_key, data, partition)
            out.set_result(data)
        except BaseException as e:
            out.set_exception(e)
        finally:
            self._decode_slots.release()

    # -- public fetch API ----------------------------------------------------

    def fetch(self, store: Any, key: str, *, cacheable: bool = True,
              cache_name: Optional[str] = None,
              cache_partition: Optional[str] = None) -> bytes:
        """One object get through cache + pool + hedging.

        ``cache_name`` overrides the cache key (object key by default):
        content-addressed reads pass :func:`content_cache_key` of the
        block's hash so aliased paths share one cache entry.
        ``cache_partition`` names the block-cache priority class the
        fetched (or promoted) block lands in — see :class:`BlockCache`.
        """
        ck = ((_store_token(store), cache_name or key)
              if cacheable and self.cache.capacity else None)
        if ck is not None:
            hit = self.cache.get(ck, cache_partition)
            if hit is not None:
                self.stats.bump(cache_hits=1)
                return hit
            self.stats.bump(cache_misses=1)
        return self._submit_miss(store, key, ck, cache_partition).result()

    def fetch_ordered(self, store: Any, keys: Sequence[str], *,
                      cacheable: bool = True,
                      window: Optional[int] = None,
                      cache_names: Optional[Sequence[Optional[str]]] = None,
                      cache_partition: Optional[str] = None,
                      ) -> Iterator[bytes]:
        """Fetch ``keys`` concurrently, yield results in input order.

        Submission is windowed (default ``2 * max_workers`` outstanding
        gets) so a scan over thousands of files doesn't swamp the pool
        queue or starve concurrent readers; decode of block *i* overlaps
        the in-flight fetches of blocks > *i*. Pass ``window=`` to bound
        it explicitly — the stream loader's backpressure rides on this.
        ``cache_names`` (aligned with ``keys``; None entries fall back to
        the object key) names cache entries by content hash, as in
        :meth:`fetch`. ``cache_partition`` routes every fetched block
        into that priority class of the block cache.
        """
        keys = list(keys)
        names: List[Optional[str]] = (list(cache_names) if cache_names
                                      else [None] * len(keys))
        if len(names) != len(keys):
            raise ValueError("cache_names must align with keys")
        if window is None:
            window = 2 * self.max_workers
        window = max(int(window), 2)
        pending: List[Future] = []

        def submit(i: int) -> Future:
            key = keys[i]
            ck = ((_store_token(store), names[i] or key)
                  if cacheable and self.cache.capacity else None)
            if ck is not None:
                hit = self.cache.get(ck, cache_partition)
                if hit is not None:
                    self.stats.bump(cache_hits=1)
                    f: Future = Future()
                    f.set_result(hit)
                    return f
                self.stats.bump(cache_misses=1)
            return self._submit_miss(store, key, ck, cache_partition)

        try:
            for i in range(min(window, len(keys))):
                pending.append(submit(i))
            for i in range(len(keys)):
                if i + window < len(keys):
                    pending.append(submit(i + window))
                yield pending[i].result()
        finally:
            for f in pending:
                f.cancel()

    def fetch_all(self, store: Any, keys: Sequence[str], *,
                  cacheable: bool = True) -> List[bytes]:
        """Materialized :meth:`fetch_ordered` (all blobs, input order)."""
        return list(self.fetch_ordered(store, keys, cacheable=cacheable))

    def invalidate(self, store: Any, keys: Sequence[str]) -> None:
        """Evict cached blocks for ``keys`` of ``store``.

        Data-file paths are immutable, so the cache normally never needs
        invalidation — EXCEPT when maintenance deletes the objects
        themselves: a vacuumed path must not keep serving from cache, or
        the cache masks a read that would fail against the real store.
        """
        tok = _store_token(store)
        for key in keys:
            self.cache.invalidate((tok, key))

    # -- composite work ------------------------------------------------------

    def submit(self, fn: Callable, *args: Any, **kwargs: Any) -> Future:
        """Run a composite job (may itself call ``fetch``) in the work pool."""
        return self._work.submit(fn, *args, **kwargs)

    def map(self, fn: Callable, items: Sequence[Any]) -> List[Any]:
        """Apply ``fn`` to each item concurrently; results in input order."""
        futures = [self._work.submit(fn, it) for it in items]
        return [f.result() for f in futures]

    # -- hedging -------------------------------------------------------------

    def hedged(self, fn: Callable[[], Any], *,
               hedge_after_s: Optional[float] = None,
               attempts: Optional[int] = None) -> Any:
        """Run ``fn`` with tail-latency hedging; first result wins.

        Generalizes the loader's old ad-hoc helper: attempts run on
        dedicated daemon threads (never pool workers), so hedging can never
        deadlock the I/O or work pools even under full saturation. Losing
        stragglers are abandoned — safe because reads are idempotent.
        """
        after = self.hedge_after_s if hedge_after_s is None else hedge_after_s
        n = self.hedge_attempts if attempts is None else max(1, int(attempts))
        if after is None or n <= 1:
            return fn()

        results: "queue.SimpleQueue[Tuple[int, bool, Any]]" = queue.SimpleQueue()

        def attempt(i: int) -> None:
            try:
                results.put((i, True, fn()))
            except BaseException as e:  # surfaced below
                results.put((i, False, e))

        def launch(i: int) -> None:
            t = threading.Thread(target=attempt, args=(i,), daemon=True,
                                 name=f"lakehedge-{i}")
            t.start()

        launch(0)
        launched, outstanding = 1, 1
        last_err: Optional[BaseException] = None
        while True:
            try:
                timeout = after if launched < n else None
                i, ok, val = results.get(timeout=timeout)
            except queue.Empty:
                self.stats.bump(hedges_launched=1)
                launch(launched)
                launched += 1
                outstanding += 1
                continue
            outstanding -= 1
            if ok:
                if i > 0:
                    self.stats.bump(hedges_won=1)
                return val
            last_err = val
            if outstanding == 0:
                raise last_err

    # -- lifecycle -----------------------------------------------------------

    def shutdown(self, wait: bool = False) -> None:
        """Release pool threads. Pools spawn threads lazily (an idle
        executor holds none), but long-lived processes that churn through
        private executors should close them — or use ``with`` blocks."""
        self._work.shutdown(wait=wait)
        self._io.shutdown(wait=wait)
        if self._decode is not None:
            self._decode.shutdown(wait=wait)

    def __enter__(self) -> "ReadExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(wait=False)


# -- process-wide default ----------------------------------------------------

_default_lock = threading.Lock()
_default_executor: Optional[ReadExecutor] = None


def get_default_executor() -> ReadExecutor:
    """Process-wide shared executor (lazily created)."""
    global _default_executor
    with _default_lock:
        if _default_executor is None:
            _default_executor = ReadExecutor()
        return _default_executor


def set_default_executor(executor: Optional[ReadExecutor]) -> None:
    """Swap the process-wide executor (tests / width sweeps)."""
    global _default_executor
    with _default_lock:
        _default_executor = executor
