"""DeltaTable — append/scan over parq-lite files tracked by the delta log.

Data skipping: every ``add`` action carries per-column min/max stats from
``columnar.write_table``; ``scan(filters=...)`` prunes whole files whose
[min,max] envelope misses the predicate before any byte of data is fetched.
That file-pruning is the mechanism behind the paper's read-slice wins: a
slice of tensor rows touches only the files whose chunk_index range overlaps
the slice.

The read path is split in two phases: :meth:`plan_scan` resolves a snapshot
and prunes add-actions using only log metadata (no data bytes touched);
:meth:`scan` hands the surviving files to the shared :class:`ReadExecutor`,
which fetches them concurrently (with block caching and optional hedging)
while batches decode in plan order as their bytes arrive.
"""

from __future__ import annotations

import hashlib
import threading
import uuid
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from . import columnar
from .compression import (CompressionSpec, DeltaBase, encode_frame,
                          parse_compression)
from .io import (ReadExecutor, content_cache_key, get_default_executor,
                 store_scope)
from .log import (CommitConflict, DeltaLog, Snapshot, catalog_index_version)
from .object_store import ObjectNotFoundError, ObjectStore

# filter := {column: (lo, hi)} inclusive range; None bound = open
Filters = Dict[str, Tuple[Optional[float], Optional[float]]]


def chunk_hash(data: bytes) -> str:
    """Content address of a part file's *decoded* bytes (blake2b-160).

    Hashing pre-codec bytes makes the address independent of codec,
    level, and shuffle settings, so re-encodes of identical content still
    dedup. 160 bits keeps accidental collisions out of reach; the chunk
    index additionally pairs every hash with its raw size and verifies
    object existence on reuse (collision paranoia, see
    :mod:`repro.core.cas`).
    """
    return hashlib.blake2b(data, digest_size=20).hexdigest()


def physical_path(add: Dict[str, Any]) -> str:
    """Relative object path holding this add-action's bytes.

    Content-addressed dedup keeps the logical ``path`` unique per
    add-action (the delta log's file map is path-keyed — two live adds
    can never share a literal ``path``) while ``physPath`` points at the
    shared stored object. Adds without ``physPath`` store their own
    bytes.
    """
    return add.get("physPath") or add["path"]


# in-flight two-phase uploads, per (store scope, table path) -> {rel path:
# refcount}. A data file uploaded but not yet committed is referenced by NO
# snapshot, so vacuum would reclassify it as an orphan and delete it out
# from under the writer — the commit would then land referencing dead
# paths. Writers (WriteBatch, compact) register their uploads here; vacuum
# treats registered paths as live. In-process protection only: it shares
# the lease model's scope (cross-process writers need an out-of-band
# grace period, as in production Delta).
_inflight_lock = threading.Lock()
_inflight: Dict[Tuple[Any, str], Dict[str, int]] = {}

# paths a running (in-process) vacuum has committed to deleting, per the
# same key. Dedup's reuse check races vacuum's liveness scan: a writer may
# look up a chunk the instant before vacuum deletes it. Vacuum condemns its
# doomed paths here (under _inflight_lock, re-checking _inflight) before
# the first delete; UploadGuard.reserve refuses condemned paths, so the
# writer falls back to a fresh upload instead of referencing a dying object.
_condemned: Dict[Tuple[Any, str], Set[str]] = {}


class UploadGuard:
    """Registers two-phase upload paths until the owning writer closes.

    ``add`` BEFORE the object put (the path is chosen first), ``close``
    after the commit lands (paths now live in a snapshot) or the writer
    abandons (paths become vacuumable orphans). Idempotent close.
    """

    def __init__(self, key: Tuple[Any, str]):
        self._key = key
        self._paths: List[str] = []
        self._closed = False

    def add(self, path: str) -> None:
        """Register one relative ``path`` as in-flight (pre-upload)."""
        with _inflight_lock:
            bucket = _inflight.setdefault(self._key, {})
            bucket[path] = bucket.get(path, 0) + 1
        self._paths.append(path)

    def reserve(self, path: str) -> bool:
        """Atomically register ``path`` unless a running vacuum condemned it.

        The dedup reuse path pins an *existing* object through the commit
        window with this: False means the object is mid-deletion and the
        caller must upload fresh bytes instead of referencing it.
        """
        with _inflight_lock:
            if path in _condemned.get(self._key, ()):
                return False
            bucket = _inflight.setdefault(self._key, {})
            bucket[path] = bucket.get(path, 0) + 1
        self._paths.append(path)
        return True

    def close(self) -> None:
        """Deregister every path this guard added (idempotent)."""
        if self._closed:
            return
        self._closed = True
        with _inflight_lock:
            bucket = _inflight.get(self._key)
            if bucket is None:
                return
            for p in self._paths:
                n = bucket.get(p, 0) - 1
                if n > 0:
                    bucket[p] = n
                else:
                    bucket.pop(p, None)
            if not bucket:
                _inflight.pop(self._key, None)

    def __enter__(self) -> "UploadGuard":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def _inflight_paths(key: Tuple[Any, str]) -> set:
    with _inflight_lock:
        return set(_inflight.get(key, ()))


@dataclass
class CompactResult:
    """What one OPTIMIZE pass did. Falsy when it was a no-op."""

    files_compacted: int = 0            # input files rewritten away
    files_written: int = 0              # merged files added
    files_recompressed: int = 0         # inputs rewritten under a new codec
    files_skipped_shared: int = 0       # left alone: dedup'd/delta-stored
    bytes_rewritten: int = 0            # physical bytes of the new files
    version: Optional[int] = None       # committed version (None = no commit)
    removed_paths: List[str] = field(default_factory=list)

    def __bool__(self) -> bool:
        return self.files_compacted > 0


@dataclass
class VacuumResult:
    """What one vacuum pass deleted (or would delete, under dry_run)."""

    files_deleted: int = 0
    bytes_reclaimed: int = 0
    index_files_deleted: int = 0        # pruned _catalog/<v>.index.json files
    deleted_paths: List[str] = field(default_factory=list)
    retained_versions: List[int] = field(default_factory=list)
    dry_run: bool = False

    def __bool__(self) -> bool:
        return self.files_deleted > 0


def file_overlaps(add: Dict[str, Any], filters: Optional[Filters]) -> bool:
    """True unless the add-action's min/max stats prove no row can match."""
    if not filters:
        return True
    stats = add.get("stats", {}).get("column_stats", {})
    for col, (lo, hi) in filters.items():
        st = stats.get(col)
        if st is None:
            continue  # no stats -> cannot prune
        if lo is not None and st["max"] < lo:
            return False
        if hi is not None and st["min"] > hi:
            return False
    return True


def _row_mask(batch: Dict[str, Any], filters: Optional[Filters]) -> Optional[np.ndarray]:
    if not filters:
        return None
    mask = None
    for col, (lo, hi) in filters.items():
        if col not in batch:
            continue
        v = batch[col]
        if not isinstance(v, np.ndarray) or v.dtype.kind not in "iuf":
            continue
        m = np.ones(len(v), dtype=bool)
        if lo is not None:
            m &= v >= lo
        if hi is not None:
            m &= v <= hi
        mask = m if mask is None else (mask & m)
    return mask


def _apply_mask(batch: Dict[str, Any], mask: Optional[np.ndarray]) -> Dict[str, Any]:
    if mask is None or mask.all():
        return batch
    out = {}
    idx = np.flatnonzero(mask)
    for k, v in batch.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[idx]
        else:
            out[k] = [v[i] for i in idx]
    return out


def filter_rows(batch: Dict[str, Any],
                filters: Optional[Filters]) -> Dict[str, Any]:
    """Row-wise filter application on one decoded column batch.

    The public face of the scan path's mask step, for consumers that fetch
    and decode blocks themselves (the catalog's ``read_many`` scheduler
    decodes each shared file ONCE, then applies each request's own filters
    to the same decoded batch). No filters (or an all-true mask) returns
    the batch unchanged, so sharing the dict across requests stays safe.
    """
    return _apply_mask(batch, _row_mask(batch, filters))


def _columns_itemsize(columns: Dict[str, Any]) -> int:
    """Best-effort shuffle itemsize for a decoded column dict.

    Prefers a per-row ``dtype`` string column (FTSF/CSF/BSGS chunk rows
    record the tensor dtype), then the widest-by-bytes fixed-dtype array
    column (COO values/indices), else 1 (shuffle becomes the identity).
    Only ever used when an add-action predates recorded itemsizes.
    """
    dt = columns.get("dtype")
    if dt is not None and len(dt):
        try:
            return np.dtype(str(dt[0])).itemsize
        except TypeError:
            pass
    best, best_bytes = 1, -1
    for v in columns.values():
        if isinstance(v, np.ndarray) and v.dtype.kind in "iuf" \
                and v.nbytes > best_bytes:
            best, best_bytes = v.dtype.itemsize, v.nbytes
    return best


def _output_compression(adds: List[Dict[str, Any]],
                        merged_columns: Dict[str, Any],
                        target) -> Tuple[Any, int]:
    """(spec, shuffle_itemsize) a compact rewrite should encode under.

    With a ``recompress`` target, that target wins. Otherwise the inputs'
    codec is preserved — the codec of the largest input file, so compact
    never silently decompresses a table (nor compresses a raw one). The
    itemsize comes from the inputs' recorded ``itemsize`` when present,
    else it is derived from the decoded rows (legacy-file migration).
    """
    spec = target
    if spec is None:
        biggest = max(adds, key=lambda a: int(a.get("size", 0)))
        codec_id = biggest.get("codecRequested",
                               biggest.get("codec", "none"))
        if codec_id == "none":
            return None, 1  # raw inputs stay raw (legacy byte layout)
        spec = parse_compression(codec_id)
    itemsize = max((int(a.get("itemsize", 0)) for a in adds), default=0)
    if itemsize < 1:
        itemsize = _columns_itemsize(merged_columns)
    return spec, itemsize


def approx_row_bytes(columns: Dict[str, Any], rows: int) -> float:
    """Estimated bytes per row of a column dict (payload bytes only).

    What :meth:`DeltaTable.append_split` sizes part files with: ndarray
    columns count their buffer, object columns count per-item bytes/array
    sizes (8 bytes for anything else, e.g. a dtype string).
    """
    total = 0
    for v in columns.values():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            total += v.nbytes
        else:
            for item in v:
                if isinstance(item, (bytes, bytearray)):
                    total += len(item)
                elif isinstance(item, np.ndarray):
                    total += item.nbytes
                else:
                    total += 8
    return total / max(rows, 1)


def slice_columns(columns: Dict[str, Any], lo: int, hi: int) -> Dict[str, Any]:
    """Row window ``[lo, hi)`` of a column dict (ndarray views, list copies)."""
    out = {}
    for k, v in columns.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[lo:hi]
        else:
            out[k] = list(v[lo:hi])
    return out


def _merge_batches(batches: List[Dict[str, Any]]) -> Dict[str, Any]:
    if not batches:
        return {}
    out: Dict[str, Any] = {}
    for key in batches[0]:
        vals = [b[key] for b in batches if key in b]
        if vals and isinstance(vals[0], np.ndarray) and vals[0].dtype.kind != "O":
            out[key] = np.concatenate(vals)
        else:
            merged: List[Any] = []
            for v in vals:
                merged.extend(v)
            out[key] = merged
    return out


class DeltaTable:
    """Append/scan/maintain one delta-logged table of parq-lite files."""

    def __init__(self, store: ObjectStore, path: str,
                 io: Optional[ReadExecutor] = None):
        self.store = store
        self.path = path.rstrip("/")
        self.log = DeltaLog(store, self.path)
        self.io = io or get_default_executor()
        # content-addressed chunk index (duck-typed; see repro.core.cas).
        # The tensor store assigns one per table when dedup is on; a bare
        # DeltaTable stays index-free and every append uploads its bytes.
        self.cas: Optional[Any] = None

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(cls, store: ObjectStore, path: str,
               metadata: Optional[Dict[str, Any]] = None,
               io: Optional[ReadExecutor] = None) -> "DeltaTable":
        """Open the table at ``path``, committing CREATE if it is new."""
        t = cls(store, path, io=io)
        if t.exists():
            return t
        t.log.commit([{"metaData": metadata or {}}], op="CREATE TABLE")
        return t

    def exists(self) -> bool:
        """Whether any version has ever been committed here."""
        return self.log.latest_version() >= 0

    def version(self) -> int:
        """Latest committed version (-1 for a nonexistent table)."""
        return self.log.latest_version()

    # -- write ----------------------------------------------------------------

    def guard_uploads(self) -> UploadGuard:
        """Guard for two-phase uploads: registered paths are treated as
        live by concurrent (in-process) :meth:`vacuum` until closed."""
        return UploadGuard((store_scope(self.store), self.path))

    def append(self, columns: Dict[str, Any], *, partition_values: Optional[Dict[str, str]] = None,
               commit: bool = True,
               guard: Optional[UploadGuard] = None,
               compression: Union[None, str, CompressionSpec] = None,
               shuffle_itemsize: int = 1,
               cas: Optional[Any] = None,
               dedup_seen: Optional[Set[str]] = None,
               delta_base: Optional[DeltaBase] = None) -> Dict[str, Any]:
        """Write one parq-lite file; optionally defer the commit.

        With ``commit=False`` the data file is uploaded but invisible; the
        returned add-action must be passed to :meth:`commit_adds` later.
        This two-phase path is what the distributed checkpointer uses:
        every host uploads its shard files, then a single coordinator commit
        makes the checkpoint atomic. Pass a :meth:`guard_uploads` guard so
        a concurrent vacuum cannot mistake the not-yet-committed file for
        an orphan (registered before the first byte is uploaded).

        ``compression`` (a spec like ``"zlib+shuffle"`` or
        ``"zlib:9+shuffle"``) frames the file under a chunk-blob codec;
        ``shuffle_itemsize`` is the stored dtype width the byte-shuffle
        filter groups on (1 disables shuffling). The add-action then
        records ``codec`` (what actually happened — incompressible
        payloads fall back to ``"none"``), ``rawSize`` (the
        pre-compression length; ``size`` stays the stored length vacuum
        and the wire account in), and ``itemsize`` so later recompression
        (:meth:`compact`) can re-shuffle without re-learning the dtype.
        ``compression=None`` writes the exact pre-compression byte layout.

        ``cas`` (a :class:`repro.core.cas.ChunkIndex`-shaped object)
        enables content-addressed dedup: when the encoded file's decoded
        bytes hash to an already-stored chunk, the returned add-action
        references the existing object via ``physPath`` and **no bytes
        are uploaded**. ``dedup_seen`` (a shared per-writer set of content
        hashes) stops two files of ONE staged tensor from aliasing the
        same object — the read scheduler's per-request completion
        accounting assumes a tensor's files are distinct objects.
        ``delta_base`` stores this file as an XOR delta against an
        existing base object (recorded as ``deltaBase``/``deltaBaseHash``
        on the add-action; reads reconstruct transparently).
        """
        spec = parse_compression(compression)
        if delta_base is not None and (spec is None or not spec.active):
            # an uncompressed XOR residue is exactly as large as the raw
            # bytes — deltas only pay off under a codec, so default one
            spec = parse_compression("zlib")
        framed = spec is not None and spec.active
        # under a file-level codec the built-in per-block zlib must stay
        # off: shuffling/compressing already-compressed blocks only burns
        # CPU and hides the codec's real ratio
        data, stats = columnar.write_table(columns, compress_blocks=not framed)
        add = {"path": f"part-{uuid.uuid4().hex}.pql", "stats": stats,
               "partitionValues": partition_values or {}, "dataChange": True}
        content_hash: Optional[str] = None
        if cas is not None or delta_base is not None:
            content_hash = chunk_hash(data)
            add["contentHash"] = content_hash
        if cas is not None and content_hash is not None and \
                (dedup_seen is None or content_hash not in dedup_seen):
            reused = cas.reuse(self, content_hash, len(data), guard=guard)
            if reused is not None:
                add.update(reused)
                if dedup_seen is not None:
                    dedup_seen.add(content_hash)
                if commit:
                    self.log.commit([{"add": add}], op="WRITE")
                return add
        if framed:
            raw_len = len(data)
            data, codec_id = encode_frame(data, spec,
                                          itemsize=shuffle_itemsize,
                                          delta_base=delta_base)
            if codec_id != "none":
                add["codec"] = codec_id
                add["rawSize"] = raw_len
                add["itemsize"] = int(shuffle_itemsize)
            # else: incompressible fallback — stored raw and UNFRAMED, the
            # file is byte-identical to an uncompressed write, so no
            # codec/rawSize is recorded (ratio stays exactly 1.0)
            if codec_id != spec.id:
                # what actually happened differs from what was asked (raw
                # fallback, or shuffle skipped for 1-byte dtypes): record
                # the request so recompress-to-this-spec stays idempotent
                add["codecRequested"] = spec.id
            if delta_base is not None:
                # mirrored from the frame header so vacuum's liveness scan
                # and the read planner see the base dependency without
                # fetching a single data byte
                add["deltaBase"] = delta_base.key
                if delta_base.content_hash:
                    add["deltaBaseHash"] = delta_base.content_hash
        add["size"] = len(data)
        if guard is not None:
            guard.add(add["path"])
        self.store.put(f"{self.path}/{add['path']}", data)
        if cas is not None and content_hash is not None:
            cas.record(add)
            if dedup_seen is not None:
                dedup_seen.add(content_hash)
        if commit:
            self.log.commit([{"add": add}], op="WRITE")
        return add

    def append_split(self, columns: Dict[str, Any], *,
                     target_bytes: int,
                     partition_values: Optional[Dict[str, str]] = None,
                     guard: Optional[UploadGuard] = None,
                     compression: Union[None, str, CompressionSpec] = None,
                     shuffle_itemsize: int = 1,
                     cas: Optional[Any] = None,
                     dedup_seen: Optional[Set[str]] = None,
                     ) -> List[Dict[str, Any]]:
        """Seal ``columns`` into ~``target_bytes`` part files (no commit).

        The partial-chunk sealing step shared by the tensor store's batch
        write path and the streaming ingest writer: rows are windowed into
        files of roughly ``target_bytes`` payload (estimated via
        :func:`approx_row_bytes`), each uploaded through :meth:`append`
        with ``commit=False`` — so every flag (``guard``, ``compression``,
        ``cas``/``dedup_seen`` content dedup) applies per sealed file.
        Returns the add-actions in row order; the caller commits them via
        :meth:`commit_adds`.
        """
        rows = len(next(iter(columns.values())))
        per_file = max(1, int(target_bytes //
                              max(approx_row_bytes(columns, rows), 1)))
        adds: List[Dict[str, Any]] = []
        for lo in range(0, rows, per_file):
            adds.append(self.append(
                slice_columns(columns, lo, min(rows, lo + per_file)),
                commit=False, guard=guard, compression=compression,
                shuffle_itemsize=shuffle_itemsize, cas=cas,
                dedup_seen=dedup_seen, partition_values=partition_values))
        return adds

    def commit_adds(self, adds: List[Dict[str, Any]], *, removes: Sequence[str] = (),
                    op: str = "WRITE",
                    expected_version: Optional[int] = None) -> int:
        """Commit staged adds/removes as one version.

        ``expected_version`` fences the commit against exactly that snapshot
        (raises :class:`~repro.lake.log.CommitConflict` if a concurrent
        writer landed first) — the serializable-writer primitive that
        ``WriteBatch``'s commit-retry/rebase loop is built on. Without it,
        losers of the log race blindly rebase and retry, which is only safe
        for append-only action lists.
        """
        actions: List[Dict[str, Any]] = [{"add": a} for a in adds]
        actions += [{"remove": {"path": p}} for p in removes]
        return self.log.commit(actions, op=op, expected_version=expected_version)

    # -- read -----------------------------------------------------------------

    def plan_scan(self, *, filters: Optional[Filters] = None,
                  partition_filters: Optional[Dict[str, str]] = None,
                  version: Optional[int] = None) -> List[Dict[str, Any]]:
        """Phase 1 of a read: pruned add-actions, metadata only.

        Partition pruning and min/max data skipping run against the log
        snapshot; nothing is fetched. The returned actions (in deterministic
        path order) are what the fetch phase — or an external scheduler —
        turns into object gets.
        """
        snap = self.log.snapshot(version)
        plan = []
        for add in snap.add_actions():
            if partition_filters:
                pv = add.get("partitionValues", {})
                if any(pv.get(k) != v for k, v in partition_filters.items()):
                    continue
            if not file_overlaps(add, filters):
                continue
            plan.append(add)
        return plan

    def fetch_adds(self, adds: Sequence[Dict[str, Any]],
                   columns: Optional[Sequence[str]] = None, *,
                   filters: Optional[Filters] = None) -> Iterator[Dict[str, Any]]:
        """Phase 2 of a read: fetch an externally-built plan.

        ``adds`` is any list of this table's add-actions (from
        :meth:`plan_scan`, or an O(1) catalog lookup that avoided the full
        snapshot walk). Files are fetched concurrently through the shared
        executor; batches decode and yield in plan order, with ``filters``
        applied row-wise exactly as :meth:`scan` would.
        """
        keys = [f"{self.path}/{physical_path(add)}" for add in adds]
        names = [content_cache_key(add["contentHash"])
                 if add.get("contentHash") else None for add in adds]
        for data in self.io.fetch_ordered(self.store, keys,
                                          cache_names=names):
            batch = columnar.read_table(data, columns)
            yield _apply_mask(batch, _row_mask(batch, filters))

    def scan(self, columns: Optional[Sequence[str]] = None, *,
             filters: Optional[Filters] = None,
             partition_filters: Optional[Dict[str, str]] = None,
             version: Optional[int] = None,
             prune_only: bool = False) -> Iterator[Dict[str, Any]]:
        """Yield column batches (one per surviving data file).

        Phase 2 of a read: the planned files are fetched concurrently
        through the shared executor; batches decode and yield in plan order
        as their gets complete, so results are bit-for-bit identical to a
        serial scan while I/O time is the makespan of parallel fetches.
        """
        plan = self.plan_scan(filters=filters, partition_filters=partition_filters,
                              version=version)
        if prune_only:
            for add in plan:
                yield {"__path__": add["path"], "__size__": add["size"]}
            return
        yield from self.fetch_adds(plan, columns, filters=filters)

    def read_all(self, columns: Optional[Sequence[str]] = None, *,
                 filters: Optional[Filters] = None,
                 partition_filters: Optional[Dict[str, str]] = None,
                 version: Optional[int] = None) -> Dict[str, Any]:
        """Concatenate all surviving batches into one column dict."""
        return _merge_batches(list(self.scan(
            columns, filters=filters, partition_filters=partition_filters,
            version=version)))

    def files(self, version: Optional[int] = None) -> List[Dict[str, Any]]:
        """Live add-actions at ``version`` (latest if None)."""
        return self.log.snapshot(version).add_actions()

    def total_bytes(self, version: Optional[int] = None) -> int:
        """Sum of live files' *stored* sizes at ``version``."""
        return sum(a["size"] for a in self.files(version))

    def snapshot(self, version: Optional[int] = None) -> Snapshot:
        """The log's materialized state at ``version`` (latest if None)."""
        return self.log.snapshot(version)

    # -- maintenance -----------------------------------------------------------

    def compact(self, max_rows_per_file: int = 1 << 20, *,
                max_retries: int = 3,
                recompress: Union[None, str, CompressionSpec] = None,
                ) -> CompactResult:
        """Rewrite multi-file partition groups into one file each.

        Files are compacted **per partition group** so the rewritten
        add-actions keep their ``partitionValues`` — merging across
        partitions would silently break ``partition_filters`` pruning (and
        would fuse incompatible row schemas, e.g. tensor headers with chunk
        rows) after OPTIMIZE.

        Rewritten files keep their inputs' chunk-blob codec (the codec of
        the largest input file): compacting a compressed table must not
        silently inflate it back to raw bytes. ``recompress=`` (a spec
        like ``"zlib+shuffle"``) instead re-encodes under that codec and
        ALSO rewrites single-file groups whose codec differs — the
        migration path for tables written before compression existed (see
        ``repro.launch.gc --recompress``). Header partitions are left
        alone (tiny, latency-critical, deliberately stored raw).

        When nothing needs rewriting this is a **commit-free no-op**
        returning a falsy result — maintenance crons must not grow the log
        (and invalidate pinned version vectors) doing nothing.

        The commit is **fenced** at the snapshot compact planned against:
        a concurrent writer that lands first (e.g. deleting a tensor whose
        files are being merged — re-adding them would resurrect it) forces
        a re-plan from the fresh snapshot rather than a blind rebase.
        Compact never deletes bytes; the rewritten-away files stay in the
        object store for older snapshots until :meth:`vacuum`.

        Content-addressed adds are preserved, never exploded: files whose
        stored object is shared (dedup references via ``physPath``, or a
        physical path referenced by more than one live add), and
        delta-stored files (``deltaBase``), are skipped rather than
        rewritten — merging them into per-group copies would multiply the
        physical bytes dedup saved. ``bytes_rewritten`` in the result is
        the *physical* size of the new files (what compact actually
        uploaded), never the sum over referencing add-actions.
        """
        target = parse_compression(recompress)
        attempt = 0
        with self.guard_uploads() as guard:
            while True:
                snap = self.log.snapshot()
                refs = Counter(physical_path(a) for a in snap.add_actions())
                groups: Dict[Tuple[Tuple[str, str], ...], List[Dict[str, Any]]] = {}
                for add in snap.add_actions():
                    pv = add.get("partitionValues", {}) or {}
                    groups.setdefault(tuple(sorted(pv.items())), []).append(add)
                new_adds: List[Dict[str, Any]] = []
                removes: List[str] = []
                recompressed = 0
                skipped_shared = 0
                for pv_items, adds in groups.items():
                    rewritable = []
                    for a in adds:
                        if a.get("physPath") or a.get("deltaBase") \
                                or refs[physical_path(a)] > 1:
                            skipped_shared += 1
                            continue
                        rewritable.append(a)
                    mismatched = 0
                    if target is not None and \
                            dict(pv_items).get("kind") != "header":
                        mismatched = sum(
                            1 for a in rewritable
                            if a.get("codecRequested",
                                     a.get("codec", "none")) != target.id)
                    if len(rewritable) <= 1 and not mismatched:
                        continue  # one file, right codec: nothing to do
                    keys = [f"{self.path}/{a['path']}" for a in rewritable]
                    batches = [columnar.read_table(data)
                               for data in self.io.fetch_ordered(self.store, keys)]
                    merged = _merge_batches(batches)
                    spec, itemsize = _output_compression(rewritable, merged,
                                                         target)
                    removes.extend(a["path"] for a in rewritable)
                    recompressed += mismatched
                    new_adds.append(self.append(
                        merged, commit=False,
                        partition_values=dict(pv_items), guard=guard,
                        compression=spec, shuffle_itemsize=itemsize))
                if not new_adds:
                    return CompactResult(files_skipped_shared=skipped_shared)
                try:
                    v = self.commit_adds(new_adds, removes=removes, op="OPTIMIZE",
                                         expected_version=snap.version)
                except CommitConflict:
                    attempt += 1
                    if attempt > max_retries:
                        raise
                    continue  # somebody landed first: re-plan on their snapshot
                return CompactResult(files_compacted=len(removes),
                                     files_written=len(new_adds),
                                     files_recompressed=recompressed,
                                     files_skipped_shared=skipped_shared,
                                     bytes_rewritten=sum(
                                         int(a.get("size", 0))
                                         for a in new_adds),
                                     version=v,
                                     removed_paths=removes)

    def retained_versions(self, *, horizon: Optional[int] = None,
                          extra_versions: Sequence[int] = ()) -> Set[int]:
        """The versions a vacuum under these arguments would keep.

        ``[horizon, latest]`` plus every in-range ``extra_versions`` entry
        (leased snapshots). Empty for a nonexistent table.
        """
        latest = self.log.latest_version()
        if latest < 0:
            return set()
        lo = latest if horizon is None else max(0, min(int(horizon), latest))
        retained = set(range(lo, latest + 1))
        retained.update(int(v) for v in extra_versions if 0 <= int(v) <= latest)
        return retained

    def vacuum(self, *, horizon: Optional[int] = None,
               extra_versions: Sequence[int] = (),
               dry_run: bool = False,
               extra_live: Sequence[str] = ()) -> VacuumResult:
        """Delete data files referenced by no retained snapshot.

        ``horizon`` is the oldest version whose files must survive: every
        file live at any version in ``[horizon, latest]`` — plus any
        version in ``extra_versions`` (leased snapshots, whatever their
        age) — is kept, so time travel to retained versions keeps working.
        ``horizon=None`` keeps only the latest snapshot's files (the
        classic vacuum). Orphans from crashed two-phase writers are
        deleted (no snapshot references them) — but uploads a live
        in-process writer has registered via :meth:`guard_uploads` are
        treated as live: deleting them would corrupt the commit about to
        reference them.

        Liveness is **reference-counted at the physical level**: an object
        survives while ANY retained add-action references it — through
        its own ``path``, through a dedup alias (``physPath``), or as the
        ``deltaBase`` a delta-stored file reconstructs from. Deleting a
        tensor therefore only reclaims the chunks nothing else shares.
        ``extra_live`` injects additional relative paths to keep (the
        sharded store passes cross-shard delta-base references here).

        Deleted paths are evicted from the shared executor's block cache —
        a vacuumed file must not keep serving from cache. Spilled catalog
        indexes (``_catalog/<v>.index.json``) for non-retained versions
        are pruned alongside their snapshots; other ``_``-prefixed
        metadata (including the ``_cas/`` chunk index) is never touched.
        With ``dry_run`` nothing is deleted; the result reports what
        would be.
        """
        retained = self.retained_versions(horizon=horizon,
                                          extra_versions=extra_versions)
        if not retained:
            return VacuumResult(dry_run=dry_run)
        prefix = f"{self.path}/"
        live: set = set(extra_live)
        for v in sorted(retained):
            for path, a in self.log.snapshot(v).files.items():
                live.add(a.get("physPath") or path)
                db = a.get("deltaBase")
                if db and db.startswith(prefix):
                    live.add(db[len(prefix):])
        ikey = (store_scope(self.store), self.path)
        live |= _inflight_paths(ikey)

        res = VacuumResult(retained_versions=sorted(retained), dry_run=dry_run)
        doomed: List[Tuple[str, Optional[str]]] = []
        for key in list(self.store.list(prefix)):
            rel = key[len(prefix):]
            if rel.startswith("_"):
                # metadata trees (_delta_log/, _catalog/, _cas/, manifests)
                # are never data files; indexes are pruned separately below
                iv = catalog_index_version(self.path, key)
                if iv is not None and iv not in retained:
                    doomed.append((key, None))
                    res.index_files_deleted += 1
                continue
            if rel not in live:
                doomed.append((key, rel))
                res.files_deleted += 1
                res.deleted_paths.append(rel)
        condemned: Set[str] = set()
        if not dry_run and doomed:
            # freeze the doomed set against concurrent dedup reuse: from
            # here a writer's reserve() of any of these paths fails (it
            # re-uploads instead); paths a writer registered in-flight
            # since the liveness scan above are spared below
            with _inflight_lock:
                inflight_now = set(_inflight.get(ikey, ()))
                condemned = {rel for _, rel in doomed
                             if rel is not None and rel not in inflight_now}
                _condemned.setdefault(ikey, set()).update(condemned)
        try:
            spared: Set[str] = set()
            if not dry_run and condemned:
                # close the commit/vacuum race: a writer that uploaded
                # before the physical listing may have committed — and
                # closed its guard — after the snapshot replay above but
                # before the condemn check. A guard closed by that check
                # means its commit already landed, so re-listing the log
                # here surfaces every such version; anything it references
                # is live, not an orphan.
                latest_now = self.log.refresh_latest()
                for v in range(max(retained) + 1, latest_now + 1):
                    for path, a in self.log.snapshot(v).files.items():
                        live.add(a.get("physPath") or path)
                        db = a.get("deltaBase")
                        if db and db.startswith(prefix):
                            live.add(db[len(prefix):])
                fresh = {rel for rel in condemned if rel in live}
                if fresh:
                    condemned -= fresh
                    with _inflight_lock:
                        s = _condemned.get(ikey)
                        if s is not None:
                            s -= fresh
            for key, rel in doomed:
                if not dry_run and rel is not None and rel not in condemned:
                    spared.add(rel)
                    continue  # re-referenced mid-plan: now live
                try:
                    res.bytes_reclaimed += self.store.head(key)
                except ObjectNotFoundError:
                    continue  # raced another vacuum
                if not dry_run:
                    self.store.delete(key)
            if spared:
                res.files_deleted -= len(spared)
                res.deleted_paths = [p for p in res.deleted_paths
                                     if p not in spared]
        finally:
            if condemned:
                with _inflight_lock:
                    s = _condemned.get(ikey)
                    if s is not None:
                        s -= condemned
                        if not s:
                            _condemned.pop(ikey, None)
        if not dry_run and doomed:
            self.io.invalidate(self.store, [k for k, _ in doomed])
        return res
