"""DeltaTable — append/scan over parq-lite files tracked by the delta log.

Data skipping: every ``add`` action carries per-column min/max stats from
``columnar.write_table``; ``scan(filters=...)`` prunes whole files whose
[min,max] envelope misses the predicate before any byte of data is fetched.
That file-pruning is the mechanism behind the paper's read-slice wins: a
slice of tensor rows touches only the files whose chunk_index range overlaps
the slice.
"""

from __future__ import annotations

import uuid
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import columnar
from .log import DeltaLog, Snapshot
from .object_store import ObjectStore

# filter := {column: (lo, hi)} inclusive range; None bound = open
Filters = Dict[str, Tuple[Optional[float], Optional[float]]]


def _file_overlaps(add: Dict[str, Any], filters: Optional[Filters]) -> bool:
    if not filters:
        return True
    stats = add.get("stats", {}).get("column_stats", {})
    for col, (lo, hi) in filters.items():
        st = stats.get(col)
        if st is None:
            continue  # no stats -> cannot prune
        if lo is not None and st["max"] < lo:
            return False
        if hi is not None and st["min"] > hi:
            return False
    return True


def _row_mask(batch: Dict[str, Any], filters: Optional[Filters]) -> Optional[np.ndarray]:
    if not filters:
        return None
    mask = None
    for col, (lo, hi) in filters.items():
        if col not in batch:
            continue
        v = batch[col]
        if not isinstance(v, np.ndarray) or v.dtype.kind not in "iuf":
            continue
        m = np.ones(len(v), dtype=bool)
        if lo is not None:
            m &= v >= lo
        if hi is not None:
            m &= v <= hi
        mask = m if mask is None else (mask & m)
    return mask


def _apply_mask(batch: Dict[str, Any], mask: Optional[np.ndarray]) -> Dict[str, Any]:
    if mask is None or mask.all():
        return batch
    out = {}
    idx = np.flatnonzero(mask)
    for k, v in batch.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[idx]
        else:
            out[k] = [v[i] for i in idx]
    return out


class DeltaTable:
    def __init__(self, store: ObjectStore, path: str):
        self.store = store
        self.path = path.rstrip("/")
        self.log = DeltaLog(store, self.path)

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(cls, store: ObjectStore, path: str,
               metadata: Optional[Dict[str, Any]] = None) -> "DeltaTable":
        t = cls(store, path)
        if t.exists():
            return t
        t.log.commit([{"metaData": metadata or {}}], op="CREATE TABLE")
        return t

    def exists(self) -> bool:
        return self.log.latest_version() >= 0

    def version(self) -> int:
        return self.log.latest_version()

    # -- write ----------------------------------------------------------------

    def append(self, columns: Dict[str, Any], *, partition_values: Optional[Dict[str, str]] = None,
               commit: bool = True) -> Dict[str, Any]:
        """Write one parq-lite file; optionally defer the commit.

        With ``commit=False`` the data file is uploaded but invisible; the
        returned add-action must be passed to :meth:`commit_adds` later.
        This two-phase path is what the distributed checkpointer uses:
        every host uploads its shard files, then a single coordinator commit
        makes the checkpoint atomic.
        """
        data, stats = columnar.write_table(columns)
        fname = f"part-{uuid.uuid4().hex}.pql"
        self.store.put(f"{self.path}/{fname}", data)
        add = {"path": fname, "size": len(data), "stats": stats,
               "partitionValues": partition_values or {}, "dataChange": True}
        if commit:
            self.log.commit([{"add": add}], op="WRITE")
        return add

    def commit_adds(self, adds: List[Dict[str, Any]], *, removes: Sequence[str] = (),
                    op: str = "WRITE") -> int:
        actions: List[Dict[str, Any]] = [{"add": a} for a in adds]
        actions += [{"remove": {"path": p}} for p in removes]
        return self.log.commit(actions, op=op)

    # -- read -----------------------------------------------------------------

    def scan(self, columns: Optional[Sequence[str]] = None, *,
             filters: Optional[Filters] = None,
             partition_filters: Optional[Dict[str, str]] = None,
             version: Optional[int] = None,
             prune_only: bool = False) -> Iterator[Dict[str, Any]]:
        """Yield column batches (one per surviving data file)."""
        snap = self.log.snapshot(version)
        for add in snap.add_actions():
            if partition_filters:
                pv = add.get("partitionValues", {})
                if any(pv.get(k) != v for k, v in partition_filters.items()):
                    continue
            if not _file_overlaps(add, filters):
                continue
            if prune_only:
                yield {"__path__": add["path"], "__size__": add["size"]}
                continue
            data = self.store.get(f"{self.path}/{add['path']}")
            batch = columnar.read_table(data, columns)
            yield _apply_mask(batch, _row_mask(batch, filters))

    def read_all(self, columns: Optional[Sequence[str]] = None, *,
                 filters: Optional[Filters] = None,
                 partition_filters: Optional[Dict[str, str]] = None,
                 version: Optional[int] = None) -> Dict[str, Any]:
        """Concatenate all surviving batches into one column dict."""
        batches = list(self.scan(columns, filters=filters,
                                 partition_filters=partition_filters, version=version))
        if not batches:
            return {}
        out: Dict[str, Any] = {}
        for key in batches[0]:
            vals = [b[key] for b in batches if key in b]
            if vals and isinstance(vals[0], np.ndarray) and vals[0].dtype.kind != "O":
                out[key] = np.concatenate(vals)
            else:
                merged: List[Any] = []
                for v in vals:
                    merged.extend(v)
                out[key] = merged
        return out

    def files(self, version: Optional[int] = None) -> List[Dict[str, Any]]:
        return self.log.snapshot(version).add_actions()

    def total_bytes(self, version: Optional[int] = None) -> int:
        return sum(a["size"] for a in self.files(version))

    def snapshot(self, version: Optional[int] = None) -> Snapshot:
        return self.log.snapshot(version)

    # -- maintenance -----------------------------------------------------------

    def compact(self, max_rows_per_file: int = 1 << 20) -> int:
        """Rewrite small files into bigger ones (single commit)."""
        snap = self.log.snapshot()
        batches, removes = [], []
        for add in snap.add_actions():
            data = self.store.get(f"{self.path}/{add['path']}")
            batches.append(columnar.read_table(data))
            removes.append(add["path"])
        if not batches:
            return snap.version
        merged: Dict[str, Any] = {}
        for key in batches[0]:
            vals = [b[key] for b in batches]
            if isinstance(vals[0], np.ndarray) and vals[0].dtype.kind != "O":
                merged[key] = np.concatenate(vals)
            else:
                acc: List[Any] = []
                for v in vals:
                    acc.extend(v)
                merged[key] = acc
        add = self.append(merged, commit=False)
        return self.commit_adds([add], removes=removes, op="OPTIMIZE")

    def vacuum(self) -> int:
        """Delete unreferenced data files (expired by remove actions)."""
        live = {a["path"] for a in self.files()}
        n = 0
        prefix = f"{self.path}/"
        for key in list(self.store.list(prefix)):
            rel = key[len(prefix):]
            if rel.startswith("_delta_log/"):
                continue
            if rel not in live:
                self.store.delete(key)
                n += 1
        return n
