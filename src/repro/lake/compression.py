"""Chunk-blob compression: pluggable codecs + byte-shuffle for part files.

The paper's headline claim is *space* efficiency of tensor storage in Delta
Lake, yet until this module every chunk blob landed as raw bytes. Following
TStore (tensor-centric compression for model hubs) and Deep Lake (chunked,
compressed lakehouse layout), compression here is **per part file** with a
tensor-aware filter in front of a general-purpose codec:

* a **codec registry** — stdlib-first (``zlib``, ``lzma``, ``none``) with
  ``zstd`` / ``lz4`` registered automatically when their packages are
  importable (the container does not bake them in, so they are optional);
* a **byte-shuffle filter** for fixed-width dtypes: the bytes of a float32
  stream are transposed from ``[b0 b1 b2 b3][b0 b1 b2 b3]...`` to
  ``[b0 b0 ...][b1 b1 ...]...`` so the low-entropy exponent/sign bytes of
  neighboring values become long runs a byte-level codec crushes (the HDF5
  shuffle filter / Blosc trick). Shuffle is a pure permutation — applying
  it with any itemsize is always reversible, so correctness never depends
  on guessing the dtype right;
* a tiny **frame format** wrapping compressed part files:

      frame := magic "PQZ1" | u32 header_len | header JSON | payload

  The header records ``codec``, ``shuffle``, ``itemsize`` and ``raw_size``,
  so a reader needs nothing but the bytes themselves to decode. Files that
  do not start with the magic are passed through untouched — which is the
  whole back-compat story: pre-compression tables (parq-lite ``PQL1``
  files) and JSON metadata read back byte-identically with zero probes.

Where it hooks in: ``DeltaTable.append(compression=...)`` frames data files
at write time (recording codec + raw/encoded sizes in the add-action), the
shared :class:`~repro.lake.io.ReadExecutor` unframes on fetch (so the block
cache stores *decoded* blocks and repeat reads never pay decode twice), and
``DeltaTable.compact(recompress=...)`` rewrites existing files under a new
codec — the migration path for old tables (``repro.launch.gc
--recompress``). Bytes-over-wire are charged by the object store at the
*stored* (compressed) size, so the modeled
:class:`~repro.lake.object_store.LatencyModel` shows the bandwidth win
honestly.

Spec strings name a codec, an optional per-codec level, and the optional
filter: ``"zlib"``, ``"zlib:9+shuffle"``, ``"lzma+shuffle"``, ``"none"``.
Parse with :func:`parse_compression`; list what this process supports with
:func:`available_codecs`.

Frames can additionally be **delta frames** (the TStore variant-storage
trick): :func:`encode_frame` accepts a :class:`DeltaBase` — the decoded
bytes of an already-stored base object — and XORs the new bytes against it
*before* shuffle + codec, recording ``delta_base`` (the base's absolute
object key) and ``delta_base_hash`` in the header. A fine-tuned variant
that perturbs a few percent of a base tensor XORs to long zero runs that
any byte codec crushes. :func:`decode_frame` reverses this given a
``base_fetch`` callback supplying the base's decoded bytes.
"""

from __future__ import annotations

import json
import lzma
import struct
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

# Byte buffers on the decode path are bytes on the wire but memoryviews once
# zero-copy filters have run; every consumer accepts either.
Buffer = Union[bytes, bytearray, memoryview]

FRAME_MAGIC = b"PQZ1"

SHUFFLE_SUFFIX = "+shuffle"


class UnknownCodecError(KeyError):
    """Raised for a compression spec naming a codec this process lacks."""


@dataclass(frozen=True)
class Compressor:
    """One registered blob codec: a name and its (de)compress callables.

    ``compress_level`` (optional) compresses at an explicit effort level —
    codecs without it reject ``"<codec>:<level>"`` specs at parse time.
    ``levels`` is the inclusive ``(lo, hi)`` range ``compress_level``
    accepts. Levels only affect *encode* effort; ``decompress`` reads any
    level's output, which is what keeps ``recompress`` idempotent across
    levels of the same codec.
    """

    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]
    compress_level: Optional[Callable[[bytes, int], bytes]] = None
    levels: Optional[Tuple[int, int]] = None


_COMPRESSORS: Dict[str, Compressor] = {}


def register_compressor(name: str, compress: Callable[[bytes], bytes],
                        decompress: Callable[[bytes], bytes], *,
                        compress_level: Optional[
                            Callable[[bytes, int], bytes]] = None,
                        levels: Optional[Tuple[int, int]] = None) -> Compressor:
    """Register a blob codec under ``name`` (later wins; returns it).

    Codecs must be bijective on bytes: ``decompress(compress(b)) == b``
    for every input (at every supported level). Registration is
    process-wide.
    """
    comp = Compressor(name=name, compress=compress, decompress=decompress,
                      compress_level=compress_level, levels=levels)
    _COMPRESSORS[name] = comp
    return comp


def get_compressor(name: str) -> Compressor:
    """The registered codec for ``name``; raises :class:`UnknownCodecError`.

    The error message lists what IS available, so a table compressed with
    an optional codec (e.g. zstd) read by a process without that package
    fails with an actionable message instead of a bare KeyError.
    """
    try:
        return _COMPRESSORS[name]
    except KeyError:
        raise UnknownCodecError(
            f"unknown compression codec {name!r}; this process has "
            f"{sorted(_COMPRESSORS)}") from None


def available_codecs() -> List[str]:
    """Sorted codec names usable in this process (optional deps included
    only when importable)."""
    return sorted(_COMPRESSORS)


# -- builtin codecs ----------------------------------------------------------
# zlib level 3 is the measured sweet spot on shuffled float chunks (within
# ~3% of level 6's ratio at half the encode cost); lzma preset 1 trades
# ~4x slower encode for archival-grade ratios.

register_compressor("none", lambda b: b, lambda b: b)
register_compressor("zlib", lambda b: zlib.compress(b, 3), zlib.decompress,
                    compress_level=lambda b, lv: zlib.compress(b, lv),
                    levels=(0, 9))
register_compressor("lzma", lambda b: lzma.compress(b, preset=1),
                    lzma.decompress,
                    compress_level=lambda b, lv: lzma.compress(b, preset=lv),
                    levels=(0, 9))

try:  # optional: python-zstandard
    import zstandard as _zstd

    register_compressor(
        "zstd",
        lambda b: _zstd.ZstdCompressor(level=3).compress(b),
        lambda b: _zstd.ZstdDecompressor().decompress(b),
        compress_level=lambda b, lv: _zstd.ZstdCompressor(level=lv).compress(b),
        levels=(1, 22))
except ImportError:  # pragma: no cover - container lacks zstandard
    pass

try:  # optional: lz4
    import lz4.frame as _lz4f

    register_compressor(
        "lz4", _lz4f.compress, _lz4f.decompress,
        compress_level=lambda b, lv: _lz4f.compress(b, compression_level=lv),
        levels=(0, 16))
except ImportError:  # pragma: no cover - container lacks lz4
    pass


# -- byte shuffle ------------------------------------------------------------

# Optional accelerator for the unshuffle transpose (decode hot path). The
# hook takes the (itemsize, n) uint8 plane matrix and returns the
# (n, itemsize) item matrix as anything np.asarray accepts — installed from
# repro.kernels (Pallas) on TPU hosts, absent everywhere else so the lake
# never imports jax just to decode.
_UNSHUFFLE_KERNEL: Optional[Callable[[np.ndarray], np.ndarray]] = None


def set_unshuffle_kernel(fn: Optional[Callable[[np.ndarray], np.ndarray]]) -> None:
    """Install (or clear, with None) the unshuffle plane-transpose kernel."""
    global _UNSHUFFLE_KERNEL
    _UNSHUFFLE_KERNEL = fn


def get_unshuffle_kernel() -> Optional[Callable[[np.ndarray], np.ndarray]]:
    return _UNSHUFFLE_KERNEL


def byte_shuffle(raw: Buffer, itemsize: int) -> Buffer:
    """Transpose ``raw`` viewed as ``(n, itemsize)`` bytes to group the
    i-th byte of every item together (HDF5/Blosc shuffle filter).

    A trailing remainder shorter than ``itemsize`` is appended unshuffled,
    so any buffer length round-trips. ``itemsize <= 1`` is the identity.
    Returns a memoryview over a single freshly-written buffer — one copy
    total, no intermediate ``bytes`` materialization.
    """
    itemsize = int(itemsize)
    if itemsize <= 1 or len(raw) < 2 * itemsize:
        return raw
    a = np.frombuffer(raw, dtype=np.uint8)
    n = (len(a) // itemsize) * itemsize
    out = np.empty(len(a), dtype=np.uint8)
    out[:n].reshape(itemsize, -1)[...] = a[:n].reshape(-1, itemsize).T
    out[n:] = a[n:]
    return out.data


def byte_unshuffle(raw: Buffer, itemsize: int) -> Buffer:
    """Exact inverse of :func:`byte_shuffle` for the same ``itemsize``.

    Decode hot path: the plane transpose lands directly in one output
    buffer (returned as a memoryview — zero-copy for downstream
    ``np.frombuffer`` consumers). When an accelerator kernel is installed
    via :func:`set_unshuffle_kernel` the transpose runs there instead of
    numpy.
    """
    itemsize = int(itemsize)
    if itemsize <= 1 or len(raw) < 2 * itemsize:
        return raw
    a = np.frombuffer(raw, dtype=np.uint8)
    n = (len(a) // itemsize) * itemsize
    out = np.empty(len(a), dtype=np.uint8)
    planes = a[:n].reshape(itemsize, -1)
    kern = _UNSHUFFLE_KERNEL
    if kern is not None:
        out[:n] = np.asarray(kern(planes), dtype=np.uint8).reshape(-1)
    else:
        out[:n].reshape(-1, itemsize)[...] = planes.T
    out[n:] = a[n:]
    return out.data


# -- variant byte-delta ------------------------------------------------------


def byte_delta(new: Buffer, base: Buffer) -> Buffer:
    """XOR ``new`` against ``base`` byte-for-byte (TStore's variant trick).

    The output has ``len(new)`` exactly: the common prefix is XORed, any
    tail of ``new`` past ``len(base)`` is appended verbatim (written into
    the same single output buffer, returned as a memoryview). Because XOR
    is an involution, :func:`byte_undelta` is this same operation — and a
    variant that differs from its base in a few percent of values deltas
    to mostly zero bytes, which any codec then crushes.
    """
    n = min(len(new), len(base))
    if n == 0:
        return new
    a = np.frombuffer(new, dtype=np.uint8)
    b = np.frombuffer(base, dtype=np.uint8)
    out = np.empty(len(a), dtype=np.uint8)
    np.bitwise_xor(a[:n], b[:n], out=out[:n])
    out[n:] = a[n:]
    return out.data


def byte_undelta(delta: Buffer, base: Buffer) -> Buffer:
    """Exact inverse of :func:`byte_delta` given the same ``base``."""
    return byte_delta(delta, base)


@dataclass(frozen=True)
class DeltaBase:
    """The base object a delta frame diffs against.

    ``key`` is the base's *absolute* object-store key (self-describing:
    any reader of the frame can fetch it without catalog context);
    ``data`` its decoded bytes; ``content_hash`` the content address of
    those bytes (recorded so reconstruction can share the content cache
    and verify it got the right base).
    """

    key: str
    data: bytes
    content_hash: Optional[str] = None


# -- spec --------------------------------------------------------------------


@dataclass(frozen=True)
class CompressionSpec:
    """A parsed compression request: codec, optional level, shuffle flag.

    ``spec.id`` round-trips to the string form recorded in add-actions,
    store manifests, and frame headers (e.g. ``"zlib+shuffle"``,
    ``"zlib:9+shuffle"``). ``level=None`` means the codec's registered
    default effort.
    """

    codec: str = "none"
    shuffle: bool = False
    level: Optional[int] = None

    @property
    def id(self) -> str:
        """Canonical string form (``"<codec>[:<level>][+shuffle]"``)."""
        s = self.codec
        if self.level is not None:
            s += f":{self.level}"
        return s + (SHUFFLE_SUFFIX if self.shuffle else "")

    @property
    def active(self) -> bool:
        """Whether this spec asks for real encoding work.

        Requires a real codec: shuffle alone is never active — it cannot
        shrink anything by itself, while activating it would disable the
        legacy per-block compression and *grow* the store.
        """
        return self.codec != "none"


def _check_level(comp: Compressor, level: Optional[int]) -> None:
    """Validate an explicit level against the codec's registration."""
    if level is None:
        return
    if comp.name == "none" or comp.compress_level is None:
        raise ValueError(
            f"codec {comp.name!r} does not support compression levels")
    if comp.levels is not None and not (comp.levels[0] <= level
                                        <= comp.levels[1]):
        raise ValueError(
            f"level {level} outside {comp.name}'s supported range "
            f"{comp.levels[0]}..{comp.levels[1]}")


def parse_compression(
        spec: Union[None, str, CompressionSpec]) -> Optional[CompressionSpec]:
    """Normalize a user-facing ``compression=`` argument.

    Accepts ``None`` (no preference — caller falls back to its default),
    a :class:`CompressionSpec`, or a spec string like ``"zlib+shuffle"``
    or ``"zlib:9+shuffle"`` (``:<level>`` selects per-codec encode
    effort). Raises :class:`UnknownCodecError` for codecs this process
    lacks and ``ValueError`` for malformed strings or out-of-range
    levels.
    """
    if spec is None:
        return None
    if isinstance(spec, CompressionSpec):
        _check_level(get_compressor(spec.codec), spec.level)
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"bad compression spec {spec!r}")
    s = spec.strip().lower()
    shuffle = s.endswith(SHUFFLE_SUFFIX)
    if shuffle:
        s = s[: -len(SHUFFLE_SUFFIX)]
    if not s or "+" in s:
        raise ValueError(f"bad compression spec {spec!r} "
                         f"(want '<codec>[:<level>]' or "
                         f"'<codec>[:<level>]+shuffle')")
    level: Optional[int] = None
    if ":" in s:
        s, _, lv = s.partition(":")
        if not s or not lv:
            raise ValueError(f"bad compression spec {spec!r} "
                             f"(want '<codec>[:<level>]')")
        try:
            level = int(lv)
        except ValueError:
            raise ValueError(f"bad compression level {lv!r} in spec "
                             f"{spec!r}") from None
    if s == "none" and shuffle:
        # shuffle without a codec can never shrink anything, but would
        # switch off the legacy per-block compression — a silent space
        # REGRESSION; refuse loudly rather than store it as a default
        raise ValueError("shuffle requires a real codec "
                         "(\"none+shuffle\" would only grow the store)")
    comp = get_compressor(s)  # fail fast on unknown codecs
    _check_level(comp, level)
    return CompressionSpec(codec=s, shuffle=shuffle, level=level)


# -- frame format ------------------------------------------------------------


def is_framed(data: Buffer) -> bool:
    """True when ``data`` starts with the compression frame magic."""
    return data[:4] == FRAME_MAGIC


def frame_info(data: Buffer) -> Optional[Dict[str, Any]]:
    """The frame header dict (codec/shuffle/itemsize/raw_size) or None
    for unframed bytes — cheap introspection without decompressing."""
    if not is_framed(data):
        return None
    (hlen,) = struct.unpack_from("<I", data, 4)
    return json.loads(bytes(data[8:8 + hlen]))


def encode_frame(raw: bytes, spec: CompressionSpec, *, itemsize: int = 1,
                 delta_base: Optional[DeltaBase] = None) -> Tuple[bytes, str]:
    """Compress ``raw`` under ``spec`` into a self-describing frame.

    ``itemsize`` drives the shuffle filter (the stored tensor's dtype
    width; 1 disables shuffling regardless of the spec). ``delta_base``
    turns this into a delta frame: ``raw`` is XORed against the base's
    decoded bytes *before* shuffle + codec, and the header records the
    base's object key (+ content hash) so decode can reconstruct.

    Returns ``(stored_bytes, codec_id)`` where ``codec_id`` is what
    actually happened: when the codec fails to shrink the payload the raw
    bytes are returned **unframed** under ``"none"`` — zero storage
    overhead, exact accounting (decode is uniform either way, since
    unframed bytes pass straight through :func:`decode_frame`). Delta
    frames never take the unframed fallback — the XORed payload is
    meaningless without the header pointing at its base.
    """
    shuffle = spec.shuffle and itemsize > 1
    body = raw
    doc: Dict[str, Any] = {"codec": spec.codec, "shuffle": shuffle,
                           "itemsize": int(itemsize) if shuffle else 1,
                           "raw_size": len(raw)}
    if spec.level is not None:
        doc["level"] = int(spec.level)
    if delta_base is not None:
        body = byte_delta(body, delta_base.data)
        doc["delta_base"] = delta_base.key
        if delta_base.content_hash:
            doc["delta_base_hash"] = delta_base.content_hash
    if shuffle:
        body = byte_shuffle(body, itemsize)
    comp = get_compressor(spec.codec)
    if spec.level is not None and comp.compress_level is not None:
        payload = comp.compress_level(body, int(spec.level))
    else:
        payload = comp.compress(body)
    header = json.dumps(doc, separators=(",", ":")).encode("utf-8")
    if delta_base is None and 8 + len(header) + len(payload) >= len(raw):
        return raw, "none"  # incompressible: store raw, unframed
    frame = b"".join([FRAME_MAGIC, struct.pack("<I", len(header)), header,
                      payload])
    return frame, CompressionSpec(codec=spec.codec, shuffle=shuffle,
                                  level=spec.level).id


def decode_frame(data: bytes, *,
                 base_fetch: Optional[Callable[[str, Optional[str]],
                                               bytes]] = None) -> bytes:
    """Undo :func:`encode_frame`; unframed bytes pass through untouched.

    This passthrough IS the back-compat contract: every pre-compression
    file (parq-lite ``PQL1``, JSON logs, spilled indexes) flows through
    the same read path unchanged, byte for byte.

    Delta frames need ``base_fetch(base_key, base_hash) -> bytes``
    supplying the base object's *decoded* bytes; decoding a delta frame
    without one raises ``ValueError`` (the payload alone is an XOR
    residue, not data).
    """
    info = frame_info(data)
    if info is None:
        return data
    (hlen,) = struct.unpack_from("<I", data, 4)
    payload = data[8 + hlen:]
    body = get_compressor(info["codec"]).decompress(payload)
    if info.get("shuffle"):
        body = byte_unshuffle(body, int(info.get("itemsize", 1)))
    if len(body) != int(info["raw_size"]):
        raise ValueError(
            f"frame decode size mismatch: got {len(body)} bytes, header "
            f"says {info['raw_size']}")
    base_key = info.get("delta_base")
    if base_key is not None:
        if base_fetch is None:
            raise ValueError(
                f"delta frame references base {base_key!r}; decoding "
                f"requires a base_fetch callback")
        body = byte_undelta(body, base_fetch(base_key,
                                             info.get("delta_base_hash")))
    return body
