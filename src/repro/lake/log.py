"""Delta-style transaction log over an object store.

Faithful to the Delta Lake protocol shape (Armbrust et al., VLDB'20) at the
scale this framework needs:

* a table is a directory; its state is the ordered list of JSON commit files
  ``_delta_log/<version>.json``; each commit holds actions
  (``metaData`` / ``add`` / ``remove`` / ``commitInfo``), one JSON per line;
* a commit is atomic: put-if-absent of the next version file. Losers of the
  race retry on top of the new snapshot (optimistic concurrency). A writer
  that crashes after uploading data files but before the commit leaves no
  visible change — this is the checkpoint/restart safety story;
* every N commits a checkpoint file snapshots the live file list so readers
  replay O(N) recent commits, not the whole history;
* time travel = replay to an explicit version.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional

from .object_store import ObjectStore, ObjectNotFoundError, PutIfAbsentError

CHECKPOINT_INTERVAL = 10

# spilled catalog indexes live beside (not inside) the delta log: one JSON
# per spilled version, deterministic content, written after the commit —
# see repro.core.catalog.build_catalog_index
CATALOG_INDEX_DIR = "_catalog"


def _log_key(table: str, version: int) -> str:
    return f"{table}/_delta_log/{version:020d}.json"


def catalog_index_key(table: str, version: int) -> str:
    """Object key of the spilled catalog index for ``version``."""
    return f"{table}/{CATALOG_INDEX_DIR}/{version:020d}.index.json"


def catalog_index_version(table: str, key: str) -> Optional[int]:
    """Inverse of :func:`catalog_index_key`; None for foreign keys."""
    prefix = f"{table}/{CATALOG_INDEX_DIR}/"
    if not (key.startswith(prefix) and key.endswith(".index.json")):
        return None
    try:
        return int(key[len(prefix):-len(".index.json")])
    except ValueError:
        return None


def _ckpt_key(table: str, version: int) -> str:
    return f"{table}/_delta_log/{version:020d}.checkpoint.json"


def _last_ckpt_key(table: str) -> str:
    return f"{table}/_delta_log/_last_checkpoint"


@dataclass
class Snapshot:
    """Materialized table state at one version."""

    version: int
    metadata: Dict[str, Any]
    files: Dict[str, Dict[str, Any]]  # path -> add action payload

    def add_actions(self) -> List[Dict[str, Any]]:
        """Live add-actions (with ``path`` folded back in), path-sorted —
        the deterministic order every scan/catalog walk relies on."""
        return [dict(a, path=p) for p, a in sorted(self.files.items())]


class CommitConflict(Exception):
    """A fenced commit lost to a concurrent writer (or retries ran out).

    ``expected``/``found`` carry the version fence that failed so callers
    implementing rebase loops (e.g. ``WriteBatch``) can re-snapshot from
    ``found`` instead of re-probing the log.
    """

    def __init__(self, msg: str, *, expected: Optional[int] = None,
                 found: Optional[int] = None):
        super().__init__(msg)
        self.expected = expected
        self.found = found


class DeltaLog:
    """One table's ordered commit history (the ``_delta_log/`` tree)."""

    def __init__(self, store: ObjectStore, table_path: str):
        self.store = store
        self.table = table_path.rstrip("/")
        # log files are immutable: a version's snapshot never changes, so
        # replayed snapshots are cached for the life of the client
        self._snap_cache: Dict[int, Snapshot] = {}
        # highest version known to exist (None = never probed). Commit files
        # are append-only, so a cached floor only ever moves forward.
        self._latest: Optional[int] = None
        # commit timestamps are immutable once written — cached for the
        # TTL half of vacuum's retention policy
        self._commit_ts: Dict[int, float] = {}

    # -- write side ---------------------------------------------------------

    def commit(self, actions: List[Dict[str, Any]], *, expected_version: Optional[int] = None,
               op: str = "WRITE", max_retries: int = 20) -> int:
        """Atomically append one commit; returns the committed version.

        With ``expected_version`` the commit only succeeds against exactly
        that snapshot (serializable writers, e.g. checkpoint step fencing);
        otherwise losers rebase and retry (append-only commits commute).
        """
        attempt = 0
        while True:
            latest = self.latest_version()
            if expected_version is not None and latest != expected_version:
                raise CommitConflict(
                    f"expected v{expected_version}, found v{latest}",
                    expected=expected_version, found=latest)
            version = latest + 1
            payload = "\n".join(
                json.dumps(a, separators=(",", ":"))
                for a in actions + [{"commitInfo": {"op": op, "ts": time.time()}}])
            try:
                self.store.put(_log_key(self.table, version),
                               payload.encode("utf-8"), if_absent=True)
            except PutIfAbsentError:
                # somebody else owns this version; remember it so the retry
                # probes forward instead of re-listing the whole log dir
                self._latest = max(self._latest or -1, version)
                attempt += 1
                if expected_version is not None or attempt > max_retries:
                    raise CommitConflict(f"lost commit race at v{version}",
                                         expected=expected_version,
                                         found=version)
                continue
            self._latest = max(self._latest or -1, version)
            if version % CHECKPOINT_INTERVAL == 0:
                self._write_checkpoint(version)
            return version

    def _write_checkpoint(self, version: int) -> None:
        snap = self.snapshot(version)
        body = json.dumps({
            "version": version,
            "metadata": snap.metadata,
            "files": snap.files,
        }, separators=(",", ":")).encode("utf-8")
        self.store.put(_ckpt_key(self.table, version), body)
        self.store.put(_last_ckpt_key(self.table),
                       json.dumps({"version": version}).encode("utf-8"))

    # -- read side ----------------------------------------------------------

    def latest_version(self) -> int:
        """-1 when the table does not exist yet.

        A full ``_delta_log/`` prefix list happens at most once per client
        (cold start on a table with no checkpoint). Afterwards the cached
        latest — raised by ``_last_checkpoint`` when available — is extended
        by probing ``head(v+1)`` forward, which is O(new commits) instead of
        O(log length) and issues zero list requests on hot commit paths.
        """
        floor = self._latest
        if floor is None:
            floor = self._checkpoint_version()
        if floor is None:
            floor = self._list_latest()
        v = floor
        while self.store.exists(_log_key(self.table, v + 1)):
            v += 1
        self._latest = v
        return v

    def refresh_latest(self) -> int:
        """Authoritative re-resolution of the latest version.

        The probe-forward cache in :meth:`latest_version` trusts a single
        ``head(v + 1)`` miss to mean "no newer commit" — under an external
        writer on an eventually-consistent store (HEAD-after-PUT lag), that
        probe can miss a commit that a full listing already shows. This
        drops the cached floor and re-resolves from the log listing plus
        the checkpoint pointer, then probes forward from whichever is
        higher. Called by :meth:`snapshot` before declaring a version
        "future" (invalidate-on-miss); operators can call it directly to
        force a freshness check.
        """
        ckpt = self._checkpoint_version()
        v = max(self._list_latest(), ckpt if ckpt is not None else -1)
        while self.store.exists(_log_key(self.table, v + 1)):
            v += 1
        # the cached floor only ever moves forward: a stale LIST on the
        # same eventually-consistent store must not un-learn a version
        # this client has already observed
        self._latest = max(self._latest or -1, v)
        return self._latest

    def _checkpoint_version(self) -> Optional[int]:
        """Version recorded in ``_last_checkpoint`` (a known-to-exist floor)."""
        try:
            ptr = json.loads(self.store.get(_last_ckpt_key(self.table)))
            return int(ptr["version"])
        except (ObjectNotFoundError, KeyError, ValueError, json.JSONDecodeError):
            return None

    def _list_latest(self) -> int:
        latest = -1
        prefix = f"{self.table}/_delta_log/"
        for key in self.store.list(prefix):
            name = key[len(prefix):]
            if name.endswith(".json") and not name.endswith(".checkpoint.json"):
                try:
                    latest = max(latest, int(name[:-5]))
                except ValueError:
                    continue
        return latest

    def _checkpoint_at_or_before(self, version: int) -> Optional[Dict[str, Any]]:
        try:
            ptr = json.loads(self.store.get(_last_ckpt_key(self.table)))
            v = ptr["version"]
            if v <= version:
                return json.loads(self.store.get(_ckpt_key(self.table, v)))
        except (ObjectNotFoundError, KeyError, json.JSONDecodeError):
            pass
        # fall back: scan for any usable checkpoint
        best = None
        prefix = f"{self.table}/_delta_log/"
        for key in self.store.list(prefix):
            if key.endswith(".checkpoint.json"):
                v = int(key[len(prefix):-len(".checkpoint.json")])
                if v <= version and (best is None or v > best):
                    best = v
        if best is not None:
            return json.loads(self.store.get(_ckpt_key(self.table, best)))
        return None

    def cached_snapshot(self, version: int) -> Optional[Snapshot]:
        """Peek the snapshot cache — no I/O, None when never replayed."""
        return self._snap_cache.get(version)

    def commit_ts(self, version: int) -> Optional[float]:
        """The ``commitInfo.ts`` of one version (None if unreadable).

        One log-file get per uncached version; timestamps are immutable so
        the answer is cached for the life of the client. Used by vacuum's
        TTL retention ("keep every version younger than N seconds").
        """
        ts = self._commit_ts.get(version)
        if ts is not None:
            return ts
        try:
            body = self.store.get(_log_key(self.table, version)).decode("utf-8")
        except ObjectNotFoundError:
            return None
        for line in body.splitlines():
            if not line:
                continue
            action = json.loads(line)
            info = action.get("commitInfo")
            if info and "ts" in info:
                self._commit_ts[version] = float(info["ts"])
                return self._commit_ts[version]
        return None

    def snapshot(self, version: Optional[int] = None) -> Snapshot:
        """Materialize table state at ``version`` (latest if None).

        Replays checkpoint + trailing commits once, then serves from the
        immutable snapshot cache. Raises :class:`ObjectNotFoundError` for
        a missing table and ``ValueError`` for future versions."""
        if version is not None:
            # pinned reads on a cached snapshot are fully local: log files
            # are immutable, so no freshness probe is needed
            cached = self._snap_cache.get(version)
            if cached is not None:
                return cached
        latest = self.latest_version()
        if version is not None and version > latest:
            # invalidate-on-miss: an external writer may have landed a
            # commit the forward probe missed (see refresh_latest) — only
            # re-list before concluding the caller asked for the future
            latest = self.refresh_latest()
        if latest < 0:
            raise ObjectNotFoundError(f"no delta table at {self.table}")
        version = latest if version is None else version
        if version > latest:
            raise ValueError(f"time travel to v{version} but latest is v{latest}")
        cached = self._snap_cache.get(version)
        if cached is not None:
            return cached

        metadata: Dict[str, Any] = {}
        files: Dict[str, Dict[str, Any]] = {}
        start = 0
        ckpt = self._checkpoint_at_or_before(version)
        if ckpt:
            metadata = ckpt["metadata"]
            files = dict(ckpt["files"])
            start = ckpt["version"] + 1

        for v in range(start, version + 1):
            try:
                body = self.store.get(_log_key(self.table, v)).decode("utf-8")
            except ObjectNotFoundError:
                continue  # gaps cannot happen via commit(); tolerate anyway
            for line in body.splitlines():
                if not line:
                    continue
                action = json.loads(line)
                if "metaData" in action:
                    metadata = action["metaData"]
                elif "add" in action:
                    a = dict(action["add"])
                    files[a.pop("path")] = a
                elif "remove" in action:
                    files.pop(action["remove"]["path"], None)
        snap = Snapshot(version=version, metadata=metadata, files=files)
        self._snap_cache[version] = snap
        if len(self._snap_cache) > 64:
            self._snap_cache.pop(next(iter(self._snap_cache)))
        return snap

    def history(self) -> Iterator[Dict[str, Any]]:
        """Yield each version's ``commitInfo`` (op, timestamp, version)."""
        for v in range(self.latest_version() + 1):
            try:
                body = self.store.get(_log_key(self.table, v)).decode("utf-8")
            except ObjectNotFoundError:
                continue
            for line in body.splitlines():
                action = json.loads(line)
                if "commitInfo" in action:
                    yield dict(action["commitInfo"], version=v)
