from .object_store import (FaultInjectingObjectStore, FaultRule,
                           InjectedFault, InMemoryObjectStore, LatencyModel,
                           LocalFSObjectStore, ObjectNotFoundError,
                           ObjectStore, PutIfAbsentError)
from .log import (CommitConflict, DeltaLog, Snapshot, catalog_index_key,
                  catalog_index_version)
from .compression import (CompressionSpec, UnknownCodecError, available_codecs,
                          byte_shuffle, byte_unshuffle, decode_frame,
                          encode_frame, frame_info, parse_compression,
                          register_compressor, set_unshuffle_kernel)
from .io import (BlockCache, ReadExecutor, ReadStats, get_default_executor,
                 set_default_executor)
from .table import (CompactResult, DeltaTable, UploadGuard, VacuumResult,
                    file_overlaps)
from . import columnar
from . import device
from .device import ChunkAssembler, DeviceReadInfo, to_device

__all__ = [
    "InMemoryObjectStore", "LatencyModel", "LocalFSObjectStore", "ObjectStore",
    "FaultInjectingObjectStore", "FaultRule", "InjectedFault",
    "ObjectNotFoundError", "PutIfAbsentError", "CommitConflict", "DeltaLog",
    "Snapshot", "DeltaTable", "file_overlaps", "columnar", "device",
    "BlockCache", "ReadExecutor", "ReadStats", "get_default_executor",
    "set_default_executor", "CompactResult", "VacuumResult", "UploadGuard",
    "catalog_index_key", "catalog_index_version",
    "CompressionSpec", "UnknownCodecError", "available_codecs",
    "byte_shuffle", "byte_unshuffle", "decode_frame", "encode_frame",
    "frame_info", "parse_compression", "register_compressor",
    "set_unshuffle_kernel", "ChunkAssembler", "DeviceReadInfo", "to_device",
]
