from .object_store import (InMemoryObjectStore, LatencyModel, LocalFSObjectStore,
                           ObjectNotFoundError, ObjectStore, PutIfAbsentError)
from .log import CommitConflict, DeltaLog, Snapshot
from .io import (BlockCache, ReadExecutor, ReadStats, get_default_executor,
                 set_default_executor)
from .table import DeltaTable, file_overlaps
from . import columnar

__all__ = [
    "InMemoryObjectStore", "LatencyModel", "LocalFSObjectStore", "ObjectStore",
    "ObjectNotFoundError", "PutIfAbsentError", "CommitConflict", "DeltaLog",
    "Snapshot", "DeltaTable", "file_overlaps", "columnar",
    "BlockCache", "ReadExecutor", "ReadStats", "get_default_executor",
    "set_default_executor",
]
