from .object_store import (InMemoryObjectStore, LatencyModel, LocalFSObjectStore,
                           ObjectNotFoundError, ObjectStore, PutIfAbsentError)
from .log import CommitConflict, DeltaLog, Snapshot
from .table import DeltaTable
from . import columnar

__all__ = [
    "InMemoryObjectStore", "LatencyModel", "LocalFSObjectStore", "ObjectStore",
    "ObjectNotFoundError", "PutIfAbsentError", "CommitConflict", "DeltaLog",
    "Snapshot", "DeltaTable", "columnar",
]
