"""Device-side read assembly: staged host buffers -> accelerator arrays.

The last hop of the read pipeline (fetch -> staged decode -> **device**).
Codecs route decoded chunk payloads here instead of materializing a full
host tensor:

* :class:`ChunkAssembler` — a preallocated ``(n_slots, row_elems)`` staging
  buffer that chunk frames are written into via ``memoryview`` writes in
  **arrival order** (one copy off the decode path, no per-chunk
  intermediates); ``gather()`` then moves the buffer to the device once and
  reorders it there with the ``block_gather`` Pallas kernel. The only host
  copy is the staging write itself — never a second, ordered full-tensor
  copy.
* :func:`scatter_coo` — COO decode straight to a dense *device* buffer via
  the ``coo_scatter`` kernel: indices/values are the only host arrays; the
  dense tensor first exists on the device.
* :func:`to_device` / :func:`device_dtype_exact` — the jax boundary.
  ``jax.device_put`` silently downcasts 64-bit dtypes unless
  ``jax_enable_x64`` is set, so anything that cannot round-trip bit-exactly
  stays in numpy (the uniform fallback also covers hosts without jax).

This module deliberately imports nothing from ``repro.core`` (the codecs in
``core/encodings`` call down into it) and defers the jax import until a
device path actually runs, so ``import repro.lake`` stays cheap.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import numpy as np

_JAX: Any = None
_KOPS: Any = None
_PROBED = False


def _mods() -> Tuple[Any, Any]:
    """(jax, repro.kernels.ops) or (None, None) — probed once, lazily."""
    global _JAX, _KOPS, _PROBED
    if not _PROBED:
        _PROBED = True
        try:
            import jax as _j

            from ..kernels import ops as _k
            _JAX, _KOPS = _j, _k
        except Exception:  # jax absent: every entry point falls back to numpy
            _JAX, _KOPS = None, None
    return _JAX, _KOPS


def have_jax() -> bool:
    return _mods()[0] is not None


def is_device_array(x: Any) -> bool:
    """True when ``x`` lives on a jax device (vs. the numpy fallback)."""
    jx, _ = _mods()
    return jx is not None and isinstance(x, jx.Array)


def device_dtype_exact(dtype: Any) -> bool:
    """True when jax holds ``dtype`` bit-exactly under the current config.

    Without ``jax_enable_x64``, ``device_put`` canonicalizes f64 -> f32 /
    i64 -> i32 — a silent precision loss the read path must never commit.
    """
    jx, _ = _mods()
    if jx is None:
        return False
    dt = np.dtype(dtype)
    try:
        return np.dtype(jx.dtypes.canonicalize_dtype(dt)) == dt
    except TypeError:
        return False


def to_device(arr: np.ndarray) -> Any:
    """``jax.device_put`` when bit-exact; the numpy array itself otherwise."""
    jx, _ = _mods()
    if jx is not None and device_dtype_exact(arr.dtype):
        return jx.device_put(arr)
    return arr


@dataclass
class DeviceReadInfo:
    """Accounting for one device read, for stats and the zero-copy gate.

    ``path`` names how the tensor reached the device: ``"block_gather"``
    (chunk staging + device reorder), ``"coo_scatter"`` (sparse pairs
    scattered on device), or ``"host_fallback"`` (host decode then one
    transfer — layouts without a device kernel, or dtypes jax cannot hold).
    ``host_staged_bytes`` is every byte the read materialized on the host
    en route — the zero-full-tensor-copy claim is ``host_staged_bytes``
    not exceeding the payload actually read (never ordered-copy doubled,
    and for slice/sparse reads strictly less than the dense tensor).
    """

    path: str
    host_staged_bytes: int
    device_bytes: int
    on_device: bool


class ChunkAssembler:
    """Arrival-order chunk staging + on-device reorder.

    ``add(out_pos, blob)`` writes a chunk payload into the next free
    staging row via a ``memoryview`` write (chunks land in whatever order
    the pipeline delivers them); ``gather()`` device-puts the staging
    buffer once and permutes rows into output order with the
    ``block_gather`` kernel (one ``(1, row_elems)`` tile per row). Without
    jax — or for dtypes the device cannot hold bit-exactly — the reorder
    is a numpy fancy-index instead.
    """

    def __init__(self, n_slots: int, row_elems: int, dtype: Any):
        self.dtype = np.dtype(dtype)
        self.n_slots = int(n_slots)
        self.row_elems = max(1, int(row_elems))
        self._buf = np.empty((self.n_slots, self.row_elems), dtype=self.dtype)
        self._rows = self._buf.view(np.uint8).reshape(self.n_slots, -1)
        # output position -> staging row, steering the gather
        self._ids = np.empty(self.n_slots, dtype=np.int32)
        self.count = 0

    @property
    def staged_bytes(self) -> int:
        return self.count * self._rows.shape[1]

    def add(self, out_pos: int, blob: Any) -> None:
        """Stage one chunk payload destined for output row ``out_pos``."""
        row = self.count
        self._rows[row] = np.frombuffer(blob, dtype=np.uint8)
        self._ids[out_pos] = row
        self.count += 1

    def gather(self, *, use_pallas: Optional[bool] = None) -> Any:
        """The ``(n_slots, row_elems)`` array in output order (device when
        possible), transferring the staging buffer exactly once."""
        if self.count != self.n_slots:
            raise ValueError(
                f"assembled {self.count} of {self.n_slots} chunks")
        if self.n_slots == 0:
            return to_device(self._buf)
        _, kops = _mods()
        if kops is not None and device_dtype_exact(self.dtype):
            # complex is not a Pallas-supported element type (and the
            # interpreter cannot allocate complex outputs); the jnp
            # reference gather still runs on the device
            if np.issubdtype(self.dtype, np.complexfloating):
                use_pallas = False
            tiles = kops.block_gather_host(self._buf, self._ids,
                                           (1, self.row_elems),
                                           use_pallas=use_pallas)
            # the gather's zero-fill for padding ids promotes bool tiles to
            # int32 — every id here is valid, so casting back is exact
            if tiles.dtype != self.dtype:
                tiles = tiles.astype(self.dtype)
            return tiles.reshape(self.n_slots, self.row_elems)
        return self._buf[self._ids]

    def on_device(self) -> bool:
        """Whether :meth:`gather` will land on a jax device."""
        return _mods()[1] is not None and device_dtype_exact(self.dtype)


def scatter_coo(flat_idx: np.ndarray, values: np.ndarray, size: int, *,
                use_pallas: Optional[bool] = None) -> Any:
    """Dense flat ``(size,)`` buffer from COO pairs — on device when the
    kernels and dtype allow, else a numpy ``np.add.at`` scatter."""
    size = int(size)
    _, kops = _mods()
    if (kops is not None and size > 0 and size < 2**31
            and device_dtype_exact(values.dtype)):
        # complex is not a Pallas-supported element type; the jnp
        # reference scatter still runs on the device
        if np.issubdtype(np.dtype(values.dtype), np.complexfloating):
            use_pallas = False
        return kops.coo_scatter_host(flat_idx, values, size,
                                     use_pallas=use_pallas)
    out = np.zeros(size, dtype=values.dtype)
    if len(flat_idx):
        np.add.at(out, flat_idx, values)
    return out
