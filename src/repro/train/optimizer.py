"""AdamW with warmup-cosine schedule. Moments in f32, pytree-shaped like
params, shardable by ``dist.sharding.opt_state_shardings`` (ZeRO-1)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: OptState, params
           ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g,
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g,
                     state.v, grads)

    def upd(p, mm, vv):
        mhat = mm / b1c
        vhat = vv / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, OptState(m=m, v=v, count=count), {
        "lr": lr, "grad_norm": gnorm}
