"""Cross-pod gradient compression — the paper's BSGS applied to the wire.

The technique transplanted (DESIGN.md §2): BSGS keeps only the non-zero /
high-energy blocks of a tensor plus their coordinates. Top-k block
sparsification with error feedback (DGC/PowerSGD lineage) does exactly that
to gradients before the *slow* cross-pod reduction:

  e_p   = g_p + r_p                  (per-pod gradient + residual)
  ids,B = block_topk(e_p, k)         (BSGS encode, kernels.block_topk)
  r_p'  = e_p - decode(ids, B)       (error feedback)
  g_hat = mean_p decode_p            (cross-pod sum of *compressed* payloads)

Implementation is pure jit/GSPMD: per-pod values carry an explicit leading
``pod`` dim sharded over the pod mesh axis; a sharding constraint forces
the all-gather to happen on the **compressed** (ids, blocks) arrays, after
which decode+sum is local. The HLO therefore shows cross-pod collective
bytes equal to k·block_bytes — measurable by the roofline harness.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kref

DEFAULT_BLOCK = (8, 128)


class CompressState(NamedTuple):
    residual: Any          # pytree like grads, with leading pod dim


def _as2d(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    if x.ndim == 0:
        return x.reshape(1, 1), x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), x.shape
    return x.reshape(-1, x.shape[-1]), x.shape


def _leaf_geometry(shape, block=DEFAULT_BLOCK):
    rows = 1 if len(shape) <= 1 else int(math.prod(shape[:-1]))
    cols = shape[-1] if shape else 1
    bh = min(block[0], rows)
    bw = min(block[1], cols)
    gh = -(-rows // bh)
    gw = -(-cols // bw)
    return (rows, cols), (bh, bw), (gh * bh, gw * bw), gh * gw


def _compress_leaf(e: jax.Array, ratio: float, block=DEFAULT_BLOCK):
    """e: (pods, ...) -> vmapped (ids, blocks) + static geometry."""
    x2_shape, bs, padded, n_blocks = _leaf_geometry(e.shape[1:], block)
    k = max(1, int(n_blocks * ratio))

    def one(ep):
        x2 = ep.reshape(x2_shape)
        x2 = jnp.pad(x2, ((0, padded[0] - x2_shape[0]),
                          (0, padded[1] - x2_shape[1])))
        return kref.block_topk(x2, bs, k)

    ids, blocks = jax.vmap(one)(e)
    return ids, blocks, padded, x2_shape, bs


def compressed_grad_mean(grads_podwise: Any, residuals: Any, *,
                         ratio: float = 0.05, block=DEFAULT_BLOCK,
                         replicate_spec=None) -> Tuple[Any, Any, Dict[str, Any]]:
    """grads_podwise: pytree, each leaf (n_pods, ...) sharded P('pod', ...).

    Returns (mean_decoded_grads (no pod dim), new_residuals, stats).
    replicate_spec: a NamedSharding that replicates — forces the all-gather
    onto the compressed payload. None (single-device tests) skips it.
    """
    stats = {"sent_bytes": 0, "dense_bytes": 0}

    def leaf(g, r):
        e = g.astype(jnp.float32) + r
        pods = e.shape[0]
        ids, blocks, padded, x2_shape, bs = _compress_leaf(e, ratio, block)
        # force the cross-pod exchange to happen on the compressed payload
        ids_all = jax.lax.with_sharding_constraint(ids, replicate_spec) \
            if replicate_spec is not None else ids
        blocks_all = jax.lax.with_sharding_constraint(blocks, replicate_spec) \
            if replicate_spec is not None else blocks

        def decode(i, b):
            z = jnp.zeros(padded, jnp.float32)
            return kref.block_scatter(z, i, b)[:x2_shape[0], :x2_shape[1]]

        decoded_own = jax.vmap(decode)(ids, blocks)          # (pods, rows, cols)
        mean = jnp.mean(jax.vmap(decode)(ids_all, blocks_all), axis=0)
        new_r = (e.reshape(pods, *x2_shape) - decoded_own).reshape(e.shape)
        stats["sent_bytes"] += int(ids.size * 4 + blocks.size * 4)
        stats["dense_bytes"] += int(e.size * 4)
        return mean.reshape(g.shape[1:]), new_r

    flat_g, treedef = jax.tree.flatten(grads_podwise)
    flat_r = treedef.flatten_up_to(residuals)
    means, new_rs = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = leaf(g, r)
        means.append(m)
        new_rs.append(nr)
    return (jax.tree.unflatten(treedef, means),
            jax.tree.unflatten(treedef, new_rs), stats)


def init_residuals(grads_podwise: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_podwise)


def compression_ratio_bytes(stats: Dict[str, int]) -> float:
    return stats["sent_bytes"] / max(stats["dense_bytes"], 1)
