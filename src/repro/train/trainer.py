"""train_step factory: loss/grad/AdamW under jit with GSPMD shardings.

Two variants:
* ``make_train_step`` — the production path. Params/opt-state shardings
  come from the rule engine; gradients reduce automatically over the batch
  axes (reduce-scatter under ZeRO shardings); donation keeps params/opt
  in-place.
* ``make_compressed_train_step`` — the paper-technique path for the
  cross-pod axis: params carry an explicit leading pod-replica dim, per-pod
  gradients are BSGS-top-k compressed with error feedback, and only the
  compressed payload crosses pods (see grad_compress.py).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as shd
from ..models import transformer
from ..models.config import ArchConfig
from . import grad_compress, optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState
    step: jax.Array


def init_state(cfg: ArchConfig, key) -> TrainState:
    params = transformer.init_params(cfg, key)
    return TrainState(params=params, opt=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def state_shardings(state: TrainState, cfg: ArchConfig, mesh: Mesh,
                    profile: Optional[str] = None) -> TrainState:
    p_sh = shd.params_shardings(state.params, cfg, mesh, profile)
    o_sh = opt.OptState(
        m=shd.opt_state_shardings(state.opt.m, cfg, mesh, profile),
        v=shd.opt_state_shardings(state.opt.v, cfg, mesh, profile),
        count=NamedSharding(mesh, P()))
    return TrainState(params=p_sh, opt=o_sh,
                      step=NamedSharding(mesh, P()))


def _constrain_batch(batch: Dict[str, jax.Array], mesh: Mesh):
    axes = shd.batch_axes(mesh)
    return {k: jax.lax.with_sharding_constraint(
        v, NamedSharding(mesh, P(axes, *([None] * (v.ndim - 1)))))
        for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, ocfg: opt.OptConfig, mesh: Optional[Mesh] = None):
    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if mesh is not None:
            batch = _constrain_batch(batch, mesh)

        def loss(p):
            return transformer.loss_fn(p, cfg, batch)

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            state.params)
        new_params, new_opt, om = opt.update(ocfg, grads, state.opt,
                                             state.params)
        metrics = dict(metrics, **om, total=total)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return train_step


def jit_train_step(cfg: ArchConfig, ocfg: opt.OptConfig, mesh: Mesh,
                   state: TrainState, batch_example: Dict[str, Any],
                   profile: Optional[str] = None):
    """jit with explicit in/out shardings + donated state."""
    st_sh = state_shardings(state, cfg, mesh, profile)
    b_sh = {k: NamedSharding(mesh, P(shd.batch_axes(mesh),
                                     *([None] * (len(v.shape) - 1))))
            for k, v in batch_example.items()}
    step = make_train_step(cfg, ocfg, mesh)
    return jax.jit(step,
                   in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,))


# ---------------------------------------------------------------------------
# cross-pod gradient compression variant (paper technique on the wire)
# ---------------------------------------------------------------------------


class CompressedTrainState(NamedTuple):
    params: Any          # leaves have leading (n_pods,) replica dim
    opt: opt.OptState    # moments with pod dim (per-pod identical updates)
    residual: Any        # error-feedback accumulators, per pod
    step: jax.Array


def init_compressed_state(cfg: ArchConfig, key, n_pods: int) -> CompressedTrainState:
    params = transformer.init_params(cfg, key)
    podded = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape),
                          params)
    return CompressedTrainState(
        params=podded,
        opt=opt.init(podded),
        residual=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), podded),
        step=jnp.zeros((), jnp.int32))


def make_compressed_train_step(cfg: ArchConfig, ocfg: opt.OptConfig,
                               ratio: float = 0.05,
                               mesh: Optional[Mesh] = None):
    replicate = NamedSharding(mesh, P()) if mesh is not None else None
    def train_step(state: CompressedTrainState, batch: Dict[str, jax.Array]):
        """batch leaves: (n_pods, local_batch, ...)."""

        def pod_loss(podded_params, batch):
            def one(p, b):
                return transformer.loss_fn(p, cfg, b)[0]
            losses = jax.vmap(one)(podded_params, batch)
            return jnp.mean(losses)

        loss, grads = jax.value_and_grad(pod_loss)(state.params, batch)
        # grads: per-pod (each pod's params only touched its own loss term)
        mean_g, new_res, stats = grad_compress.compressed_grad_mean(
            grads, state.residual, ratio=ratio, replicate_spec=replicate)
        n_pods = jax.tree.leaves(state.params)[0].shape[0]
        podded_g = jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (n_pods,) + g.shape), mean_g)
        new_params, new_opt, om = opt.update(ocfg, podded_g, state.opt,
                                             state.params)
        metrics = dict(om, loss=loss,
                       wire_ratio=jnp.asarray(
                           grad_compress.compression_ratio_bytes(stats)))
        return CompressedTrainState(params=new_params, opt=new_opt,
                                    residual=new_res,
                                    step=state.step + 1), metrics

    return train_step
