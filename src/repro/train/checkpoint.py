"""Distributed checkpointing on the Delta Tensor store.

Every train-state leaf is stored as FTSF chunk rows in one delta table;
a checkpoint step is ONE atomic :class:`~repro.core.batch.WriteBatch`
commit (two-phase: upload all part files, then commit), so a crash
mid-write leaves the previous checkpoint intact — the delta log's
put-if-absent commit is the recovery line. Restores pull the whole leaf
tree through ONE catalog snapshot and ONE merged
:meth:`~repro.core.catalog.Catalog.read_many` fetch plan (shared chunk
files fetch once; per-leaf decode overlaps in-flight fetches).

Features aimed at the 1000-node posture:
* **incremental**: per-leaf content hashes; unchanged leaves are not
  re-uploaded, the manifest re-points to the prior version's chunks (the
  frozen-backbone / adapter-training case, and optimizer count scalars);
* **elastic restore**: ``restore(..., shard_spec)`` issues slice reads for
  exactly the rows covering this host's shard under a *new* mesh shape —
  the paper's read-slice path doing resharded restarts;
* **async**: ``save_async`` snapshots to host memory and uploads on a
  background thread, overlapping the next train steps; ``wait()`` joins.
* **time travel / retention**: every checkpoint is a table version;
  ``restore(step=...)`` replays the manifest for that step.
  ``keep_checkpoints=K`` holds a snapshot **lease** on the last K saved
  versions, so ``store.vacuum()`` (run by anyone sharing the store) can
  reclaim older churn without ever breaking a restorable checkpoint;
  :meth:`prune` + :meth:`gc` actively delete checkpoints beyond the last K
  (respecting incremental chunk reuse) and vacuum the freed bytes.
"""

from __future__ import annotations

import hashlib
import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.leases import Lease
from ..core.store import DeltaTensorStore
from ..dist.sharding import _path_str
from ..lake import ObjectStore


def _leaf_hash(x: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=12)
    h.update(str(x.dtype).encode())
    h.update(str(x.shape).encode())
    h.update(np.ascontiguousarray(x).tobytes())
    return h.hexdigest()


class DeltaCheckpointer:
    def __init__(self, object_store: ObjectStore, root: str = "checkpoints", *,
                 chunk_dims: Optional[int] = None,
                 shards: Optional[int] = None,
                 keep_checkpoints: Optional[int] = None):
        # shards=N scales concurrent-writer commit throughput: param leaves
        # hash across N independent commit domains, so many hosts
        # checkpointing into one logical store stop racing a single delta
        # log. Manifest rows stay on shard 0 (the meta shard), so `steps`/
        # `restore` discovery below scans one table regardless of N.
        self.store = DeltaTensorStore(object_store, root, shards=shards)
        self.chunk_dims = chunk_dims
        # keep_checkpoints=K: lease the last K committed checkpoint versions
        # so concurrent store.vacuum() never deletes a restorable step —
        # retention by lease, not by "never vacuum the checkpoint store"
        self.keep_checkpoints = keep_checkpoints
        self._ckpt_leases: List[Tuple[int, Lease]] = []  # (step, lease), oldest first
        self._last_hashes: Dict[str, Tuple[str, str]] = {}  # leaf -> (hash, tid)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def _upload(self, step: int, leaves: List[Tuple[str, np.ndarray]]) -> None:
        manifest: Dict[str, str] = {}
        new_hashes: Dict[str, Tuple[str, str]] = {}
        # one WriteBatch = the whole checkpoint: part files upload invisibly
        # as they are staged, then land in a single atomic commit. The
        # leaf-hash skip above catches unchanged leaves in THIS process;
        # leaves changed-then-reverted (or written by another host) still
        # dedup at the chunk level — batch.put routes every upload through
        # the store's content-addressed chunk index, so a byte-identical
        # chunk commits as a reference to the existing object
        with self.store.batch(op=f"CHECKPOINT step={step}") as batch:
            for name, arr in leaves:
                digest = _leaf_hash(arr)
                prev = self._last_hashes.get(name)
                if prev is not None and prev[0] == digest:
                    manifest[name] = prev[1]       # unchanged: reuse chunks
                    continue
                tid = f"{name}@{step}"
                batch.put(arr, tensor_id=tid, layout="ftsf",
                          chunk_dims=self.chunk_dims)
                manifest[name] = tid
                new_hashes[name] = (digest, tid)
            batch.add_rows(
                {"step": np.asarray([step], np.int64),
                 "manifest": [json.dumps(manifest, sort_keys=True).encode()]},
                partition_values={"kind": "ckpt_manifest"})
        # only a committed checkpoint may update the incremental-skip state;
        # a failed batch must not make the next save skip an upload
        self._last_hashes.update(new_hashes)
        if self.keep_checkpoints is not None:
            # lease the committed version (vector) and slide the window
            self._ckpt_leases.append((step, self.store.lease(batch.version)))
            while len(self._ckpt_leases) > self.keep_checkpoints:
                _, old = self._ckpt_leases.pop(0)
                old.release()

    def save(self, step: int, state: Any) -> None:
        leaves = [( _path_str(p), np.asarray(x))
                  for p, x in jax.tree_util.tree_flatten_with_path(state)[0]]
        self._upload(step, leaves)

    def save_async(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot to host memory synchronously (device buffers may be donated)
        leaves = [(_path_str(p), np.asarray(x))
                  for p, x in jax.tree_util.tree_flatten_with_path(state)[0]]

        def run():
            try:
                self._upload(step, leaves)
            except BaseException as e:  # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for batch in self.store.table.scan(
                partition_filters={"kind": "ckpt_manifest"}):
            out.extend(int(s) for s in np.asarray(batch["step"]))
        return sorted(set(out))

    def _pinned_version(self, step: Optional[int]):
        """The version vector our retention lease pinned for ``step``
        (None when we hold no live lease for it)."""
        for s, lease in self._ckpt_leases:
            if s == step and not lease.released:
                return lease.version_vector
        return None

    def _manifest(self, step: Optional[int], *,
                  version: Optional[int] = None) -> Tuple[int, Dict[str, str]]:
        best: Tuple[int, Dict[str, str]] = (-1, {})
        for batch in self.store.table.scan(
                partition_filters={"kind": "ckpt_manifest"}, version=version):
            for s, blob in zip(np.asarray(batch["step"]), batch["manifest"]):
                s = int(s)
                if (step is None and s > best[0]) or (step is not None and s == step):
                    best = (s, json.loads(bytes(blob)))
        if best[0] < 0:
            raise KeyError(f"no checkpoint found (requested step={step})")
        return best

    def restore(self, template: Any, *, step: Optional[int] = None,
                shard_slices: Optional[Dict[str, Sequence]] = None) -> Tuple[int, Any]:
        """template: pytree of arrays/ShapeDtypeStructs giving the structure.

        shard_slices: optional {leaf_path: slice spec} — restore only this
        host's shard via slice reads (elastic restore on a new mesh).

        A step we hold a retention lease for restores against its *pinned*
        version vector: even if another maintenance actor pruned the step
        from the latest snapshot, the leased snapshot's manifest row and
        chunk files are vacuum-protected and the restore still succeeds.
        """
        pinned = self._pinned_version(step) if step is not None else None
        step_found, manifest = self._manifest(
            step, version=None if pinned is None else pinned[0])
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        # the whole tree restores through ONE catalog snapshot and ONE
        # merged fetch plan (consistent restore even under concurrent
        # writers): chunk files shared across leaves — incremental saves
        # re-point unchanged leaves at the same tids — fetch once, and
        # each leaf decodes as soon as its last file lands
        catalog = self.store.catalog(pinned)
        requests = []
        for path, _ in flat:
            name = _path_str(path)
            requests.append((manifest[name],
                             shard_slices[name] if shard_slices
                             and name in shard_slices else None))
        arrays = catalog.read_many(requests)
        out = [arr.astype(np.dtype(leaf.dtype), copy=False)
               for arr, (_, leaf) in zip(arrays, flat)]
        return step_found, jax.tree_util.tree_unflatten(
            treedef, out)

    def restore_available(self) -> bool:
        try:
            self._manifest(None)
            return True
        except KeyError:
            return False

    # -- retention / maintenance ----------------------------------------------

    def _manifest_files(self) -> List[Tuple[str, List[int], Dict[int, Dict[str, str]]]]:
        """Each manifest data file with the steps it holds and their
        manifests. One file per save normally; compact can merge several."""
        table = self.store.table
        adds = table.plan_scan(partition_filters={"kind": "ckpt_manifest"})
        out = []
        for add, batch in zip(adds, table.fetch_adds(adds)):
            steps = [int(s) for s in np.asarray(batch["step"])]
            manifests = {int(s): json.loads(bytes(blob))
                         for s, blob in zip(np.asarray(batch["step"]),
                                            batch["manifest"])}
            out.append((add["path"], steps, manifests))
        return out

    def prune(self, keep: Optional[int] = None) -> List[int]:
        """Delete checkpoints beyond the newest ``keep`` steps.

        Tensors still referenced by a kept step's manifest (incremental
        saves re-point unchanged leaves at older tids) are never deleted.
        Manifest files whose every step is pruned are removed from the log;
        files mixing kept and pruned steps are kept whole (conservative —
        only possible after a compact merged manifest rows). Leases held
        for pruned steps are released so vacuum can reclaim the bytes.
        Returns the pruned step numbers.
        """
        keep = self.keep_checkpoints if keep is None else int(keep)
        if keep is None or keep < 1:
            raise ValueError("prune needs keep >= 1 (or keep_checkpoints set)")
        files = self._manifest_files()
        all_steps = sorted({s for _, steps, _ in files for s in steps})
        if len(all_steps) <= keep:
            return []
        kept = set(all_steps[-keep:])
        referenced = {tid for _, _, m in files for s, man in m.items()
                      if s in kept for tid in man.values()}
        doomed_tids = sorted({tid for _, _, m in files for s, man in m.items()
                              if s not in kept for tid in man.values()}
                             - referenced)
        if doomed_tids:
            with self.store.batch(op=f"PRUNE CHECKPOINTS keep={keep}") as b:
                for tid in doomed_tids:
                    b.delete(tid, missing_ok=True)
        doomed_paths = [p for p, steps, _ in files
                        if steps and all(s not in kept for s in steps)]
        if doomed_paths:
            self.store.table.commit_adds([], removes=doomed_paths,
                                         op="PRUNE MANIFESTS")
        # re-pin surviving leases to the post-prune latest: the old pins
        # reference snapshots that still include the pruned steps' files,
        # which would keep vacuum from reclaiming anything. Every kept
        # step's manifest and tensors are live at latest, so the fresh pin
        # protects exactly what prune kept.
        survivors = []
        for s, lease in self._ckpt_leases:
            if s in kept:
                survivors.append((s, self.store.lease()))
            lease.release()
        self._ckpt_leases = survivors
        return [s for s in all_steps if s not in kept]

    def gc(self, keep: Optional[int] = None, *,
           dry_run: bool = False) -> Dict[str, Any]:
        """Prune + compact + vacuum the checkpoint store in one call.

        With ``dry_run`` nothing is committed or deleted; the vacuum half
        reports what a real run would reclaim *under current leases*.
        """
        keep = self.keep_checkpoints if keep is None else keep
        pruned: List[int] = []
        compact = []
        if not dry_run:
            if keep is not None:
                pruned = self.prune(keep)
            compact = self.store.compact()
        vacuum = self.store.vacuum(dry_run=dry_run)
        return {
            "pruned_steps": pruned,
            "files_compacted": sum(r.files_compacted for r in compact),
            "files_deleted": sum(r.files_deleted for r in vacuum),
            "bytes_reclaimed": sum(r.bytes_reclaimed for r in vacuum),
            "compact": compact,
            "vacuum": vacuum,
        }
