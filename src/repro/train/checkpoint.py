"""Distributed checkpointing on the Delta Tensor store.

Every train-state leaf is stored as FTSF chunk rows in one delta table;
a checkpoint step is ONE atomic :class:`~repro.core.batch.WriteBatch`
commit (two-phase: upload all part files, then commit), so a crash
mid-write leaves the previous checkpoint intact — the delta log's
put-if-absent commit is the recovery line. Restores open every leaf as a
:class:`~repro.core.catalog.TensorRef` from ONE catalog snapshot and
resolve the reads as parallel futures.

Features aimed at the 1000-node posture:
* **incremental**: per-leaf content hashes; unchanged leaves are not
  re-uploaded, the manifest re-points to the prior version's chunks (the
  frozen-backbone / adapter-training case, and optimizer count scalars);
* **elastic restore**: ``restore(..., shard_spec)`` issues slice reads for
  exactly the rows covering this host's shard under a *new* mesh shape —
  the paper's read-slice path doing resharded restarts;
* **async**: ``save_async`` snapshots to host memory and uploads on a
  background thread, overlapping the next train steps; ``wait()`` joins.
* **time travel / retention**: every checkpoint is a table version;
  ``restore(step=...)`` replays the manifest for that step.
"""

from __future__ import annotations

import hashlib
import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.store import DeltaTensorStore
from ..dist.sharding import _path_str
from ..lake import ObjectStore


def _leaf_hash(x: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=12)
    h.update(str(x.dtype).encode())
    h.update(str(x.shape).encode())
    h.update(np.ascontiguousarray(x).tobytes())
    return h.hexdigest()


class DeltaCheckpointer:
    def __init__(self, object_store: ObjectStore, root: str = "checkpoints", *,
                 chunk_dims: Optional[int] = None,
                 shards: Optional[int] = None):
        # shards=N scales concurrent-writer commit throughput: param leaves
        # hash across N independent commit domains, so many hosts
        # checkpointing into one logical store stop racing a single delta
        # log. Manifest rows stay on shard 0 (the meta shard), so `steps`/
        # `restore` discovery below scans one table regardless of N.
        self.store = DeltaTensorStore(object_store, root, shards=shards)
        self.chunk_dims = chunk_dims
        self._last_hashes: Dict[str, Tuple[str, str]] = {}  # leaf -> (hash, tid)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def _upload(self, step: int, leaves: List[Tuple[str, np.ndarray]]) -> None:
        manifest: Dict[str, str] = {}
        new_hashes: Dict[str, Tuple[str, str]] = {}
        # one WriteBatch = the whole checkpoint: part files upload invisibly
        # as they are staged, then land in a single atomic commit
        with self.store.batch(op=f"CHECKPOINT step={step}") as batch:
            for name, arr in leaves:
                digest = _leaf_hash(arr)
                prev = self._last_hashes.get(name)
                if prev is not None and prev[0] == digest:
                    manifest[name] = prev[1]       # unchanged: reuse chunks
                    continue
                tid = f"{name}@{step}"
                batch.put(arr, tensor_id=tid, layout="ftsf",
                          chunk_dims=self.chunk_dims)
                manifest[name] = tid
                new_hashes[name] = (digest, tid)
            batch.add_rows(
                {"step": np.asarray([step], np.int64),
                 "manifest": [json.dumps(manifest, sort_keys=True).encode()]},
                partition_values={"kind": "ckpt_manifest"})
        # only a committed checkpoint may update the incremental-skip state;
        # a failed batch must not make the next save skip an upload
        self._last_hashes.update(new_hashes)

    def save(self, step: int, state: Any) -> None:
        leaves = [( _path_str(p), np.asarray(x))
                  for p, x in jax.tree_util.tree_flatten_with_path(state)[0]]
        self._upload(step, leaves)

    def save_async(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot to host memory synchronously (device buffers may be donated)
        leaves = [(_path_str(p), np.asarray(x))
                  for p, x in jax.tree_util.tree_flatten_with_path(state)[0]]

        def run():
            try:
                self._upload(step, leaves)
            except BaseException as e:  # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for batch in self.store.table.scan(
                partition_filters={"kind": "ckpt_manifest"}):
            out.extend(int(s) for s in np.asarray(batch["step"]))
        return sorted(set(out))

    def _manifest(self, step: Optional[int]) -> Tuple[int, Dict[str, str]]:
        best: Tuple[int, Dict[str, str]] = (-1, {})
        for batch in self.store.table.scan(
                partition_filters={"kind": "ckpt_manifest"}):
            for s, blob in zip(np.asarray(batch["step"]), batch["manifest"]):
                s = int(s)
                if (step is None and s > best[0]) or (step is not None and s == step):
                    best = (s, json.loads(bytes(blob)))
        if best[0] < 0:
            raise KeyError(f"no checkpoint found (requested step={step})")
        return best

    def restore(self, template: Any, *, step: Optional[int] = None,
                shard_slices: Optional[Dict[str, Sequence]] = None) -> Tuple[int, Any]:
        """template: pytree of arrays/ShapeDtypeStructs giving the structure.

        shard_slices: optional {leaf_path: slice spec} — restore only this
        host's shard via slice reads (elastic restore on a new mesh).
        """
        step_found, manifest = self._manifest(step)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        # every leaf ref comes from ONE catalog snapshot (consistent restore
        # even under concurrent writers) and resolves as a parallel future
        catalog = self.store.catalog()
        futures = []
        for path, leaf in flat:
            name = _path_str(path)
            ref = catalog.open(manifest[name])
            futures.append(ref.read_async(
                shard_slices[name] if shard_slices and name in shard_slices
                else None))
        out = [f.result().astype(np.dtype(leaf.dtype), copy=False)
               for f, (_, leaf) in zip(futures, flat)]
        return step_found, jax.tree_util.tree_unflatten(
            treedef, out)

    def restore_available(self) -> bool:
        try:
            self._manifest(None)
            return True
        except KeyError:
            return False
