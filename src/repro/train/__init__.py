from . import checkpoint, grad_compress, optimizer, trainer
