"""Analytic FLOP/byte accounting per (arch × shape) — the MODEL_FLOPS side
of the roofline ratio (6·N·D for training, 2·N·D forward-only for serving,
N := active params for MoE). Attention's O(T·S) term is reported separately
so the ratio stays the assignment's definition."""

from __future__ import annotations

from typing import Dict

import jax

from ..models import transformer
from ..models.config import ArchConfig


def param_counts(cfg: ArchConfig) -> Dict[str, int]:
    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.key(0)))
    total = transformer.param_count(params)
    active = transformer.active_param_count(params, cfg)
    return {"total": int(total), "active": int(active)}


def attention_flops(cfg: ArchConfig, b: int, t: int, s: int) -> float:
    """Score+value matmuls: 2 · 2 · B · Hq · T · S · hd (fwd)."""
    if cfg.family in ("ssm",):
        return 0.0
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.shared_attn_every
    window = cfg.window
    eff_s = min(s, window) if window else s
    return 4.0 * b * cfg.n_heads * t * eff_s * cfg.hd * n_attn_layers


def model_flops(cfg: ArchConfig, kind: str, b: int, t: int,
                cache_len: int = 0) -> Dict[str, float]:
    counts = param_counts(cfg)
    n_act = counts["active"]
    if kind == "train":
        tokens = b * t
        core = 6.0 * n_act * tokens
        attn = 3.0 * attention_flops(cfg, b, t, t) / 2.0 * 2.0  # fwd+bwd ≈ 3×fwd
    elif kind == "prefill":
        tokens = b * t
        core = 2.0 * n_act * tokens
        attn = attention_flops(cfg, b, t, t) / 2.0   # causal halves the area
    else:  # decode
        tokens = b * 1
        core = 2.0 * n_act * tokens
        attn = attention_flops(cfg, b, 1, max(cache_len, 1))
    return {"model_flops": core, "attn_flops": attn,
            "tokens": float(tokens), **{f"params_{k}": v
                                        for k, v in counts.items()}}
