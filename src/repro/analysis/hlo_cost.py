"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` over 40 layers contributes its body a single time, so FLOPs /
bytes / collective bytes are undercounted by the trip count. This module
re-derives the three roofline inputs by walking the HLO call graph:

* per-op FLOPs: dot ops from operand shapes (resolved through a name→type
  map, since optimized HLO prints operands untyped) + dimension numbers:
  2 · prod(out_dims) · prod(lhs_contracting_dims);
* per-op HBM bytes: operands + result of top-level (post-fusion) ops —
  XLA's own memory model; fusion-internal ops contribute FLOPs only;
* collective bytes by kind (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute);
* ``while`` ops multiply body+condition costs by the trip count parsed
  from the condition computation's comparison constant.

Costs are for the SPMD per-device program — exactly what the per-chip
roofline terms need.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# out_type matched lazily: tuple types embed /*index=N*/ comments; the
# first ` opcode(` token after `=` is the real opcode (types never contain
# parentheses except the outer tuple wrapper).
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                    r"([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))")
_ARGNAME_RE = re.compile(r"%([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls|branch_computations)="
                       r"[{]?%?([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose "traffic" is bookkeeping, not HBM bytes
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "after-all", "iota", "partition-id", "replica-id", "domain",
             "opt-barrier"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0, with_bytes: bool = True) -> None:
        self.flops += other.flops * mult
        if with_bytes:
            self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


@dataclass
class _Op:
    name: str
    out_type: str
    opcode: str
    rest: str
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)


def _split_args_attrs(rest: str):
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


class HloProgram:
    def __init__(self, hlo: str):
        self.comps: Dict[str, _Computation] = {}
        self.types: Dict[str, str] = {}
        self.entry: Optional[str] = None
        cur: Optional[_Computation] = None
        for line in hlo.splitlines():
            if not line.strip():
                continue
            if not line.startswith(" "):
                m = _HEADER_RE.match(line)
                if m:
                    cur = _Computation(m.group(1))
                    self.comps[cur.name] = cur
                    if line.startswith("ENTRY"):
                        self.entry = cur.name
                    for pm in _PARAM_RE.finditer(m.group(2)):
                        self.types[pm.group(1)] = pm.group(2)
                continue
            m = _OP_RE.match(line)
            if m and cur is not None:
                op = _Op(m.group(1), m.group(2), m.group(3), m.group(4),
                         is_root=line.lstrip().startswith("ROOT"))
                cur.ops.append(op)
                self.types[op.name] = op.out_type

    # -- per-op costs ---------------------------------------------------------

    def _dot_flops(self, op: _Op) -> float:
        args, attrs = _split_args_attrs(op.rest)
        names = _ARGNAME_RE.findall(args)
        if not names:
            return 0.0
        lhs_type = self.types.get(names[0], "")
        ms = _SHAPE_RE.search(lhs_type)
        if not ms:
            return 0.0
        lhs_dims = [int(d) for d in ms.group(2).split(",") if d]
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
        contract = 1
        if mc:
            for idx in (int(i) for i in mc.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
        out = 1
        mo = _SHAPE_RE.search(op.out_type)
        if mo:
            for d in mo.group(2).split(","):
                if d:
                    out *= int(d)
        return 2.0 * out * contract

    def _trip_count(self, comp: _Computation) -> float:
        best = 1.0
        for op in comp.ops:
            if op.opcode == "constant":
                m = re.match(r"(\d+)\)", op.rest)
                if m:
                    best = max(best, float(m.group(1)))
        return best

    @staticmethod
    def _known_trip_count(rest: str) -> float:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
        return float(m.group(1)) if m else 0.0

    # slicing ops read only their output's worth of the operand — charging
    # the full operand would bill a 40-layer stacked param once per scan
    # iteration (the dominant overcount XLA's utilization model also fixes)
    _SLICING = {"dynamic-slice", "slice", "gather"}

    def _param_index(self, comp: _Computation) -> Dict[str, int]:
        out = {}
        for op in comp.ops:
            if op.opcode == "parameter":
                m = re.match(r"(\d+)\)", op.rest)
                if m:
                    out[op.name] = int(m.group(1))
        return out

    def _fusion_operand_util(self, callee: _Computation) -> Dict[int, float]:
        """Per-parameter bytes actually read inside a fusion: if a param is
        consumed only through slicing ops (or as the in-place target of a
        dynamic-update-slice), charge the touched bytes, not the buffer."""
        pidx = self._param_index(callee)
        util: Dict[int, float] = {}
        consumed_fully = set()
        for op in callee.ops:
            args, _ = _split_args_attrs(op.rest)
            names = _ARGNAME_RE.findall(args)
            nameset = set(names)
            for pname, idx in pidx.items():
                if pname not in nameset:
                    continue
                if op.opcode in self._SLICING:
                    util[idx] = util.get(idx, 0.0) + _shape_bytes(op.out_type)
                elif (op.opcode == "dynamic-update-slice"
                      and names and names[0] == pname):
                    # in-place accumulator target: charge the update only
                    upd = self.types.get(names[1], "") if len(names) > 1 else ""
                    util[idx] = util.get(idx, 0.0) + _shape_bytes(upd)
                elif op.opcode not in _FREE_OPS and op.opcode != "bitcast":
                    consumed_fully.add(idx)
        for idx in consumed_fully:
            util.pop(idx, None)
        return util

    def _fusion_output_bytes(self, callee: _Computation, out_b: float) -> float:
        """A fusion rooted in dynamic-update-slice writes only the update
        (XLA aliases the buffer); charge the touched bytes."""
        roots = [op for op in callee.ops if op.is_root]
        if not roots:
            return out_b
        root = roots[-1]
        def dus_bytes(op):
            args, _ = _split_args_attrs(op.rest)
            names = _ARGNAME_RE.findall(args)
            return (_shape_bytes(self.types.get(names[1], ""))
                    if len(names) > 1 else 0.0)
        if root.opcode == "dynamic-update-slice":
            return dus_bytes(root)
        if root.opcode == "tuple":
            args, _ = _split_args_attrs(root.rest)
            total, hit = 0.0, False
            for n in _ARGNAME_RE.findall(args):
                inner = next((o for o in callee.ops if o.name == n), None)
                if inner is not None and inner.opcode == "dynamic-update-slice":
                    total += dus_bytes(inner)
                    hit = True
                else:
                    total += _shape_bytes(self.types.get(n, ""))
            return total if hit else out_b
        return out_b

    def _op_bytes(self, op: _Op) -> float:
        if op.opcode in _FREE_OPS:
            return 0.0
        args, _ = _split_args_attrs(op.rest)
        names = _ARGNAME_RE.findall(args)
        out_b = float(_shape_bytes(op.out_type))
        if op.opcode in self._SLICING:
            return 2.0 * out_b
        if op.opcode in ("dynamic-update-slice", "scatter"):
            upd = _shape_bytes(self.types.get(names[1], "")) if len(names) > 1 else 0
            return out_b * 0.0 + 2.0 * upd + 64.0  # in-place: read+write update
        if op.opcode == "fusion":
            callees = _CALLS_RE.findall(op.rest)
            callee = (self.comps[callees[0]]
                      if callees and callees[0] in self.comps else None)
            util = self._fusion_operand_util(callee) if callee else {}
            total = (self._fusion_output_bytes(callee, out_b)
                     if callee else out_b)
            for i, name in enumerate(names):
                full = _shape_bytes(self.types.get(name, ""))
                total += min(full, util.get(i, full))
            return total
        total = out_b
        for name in names:
            total += _shape_bytes(self.types.get(name, ""))
        return total

    def _op_cost(self, op: _Op, memo) -> Cost:
        c = Cost()
        if op.opcode == "while":
            trip = self._known_trip_count(op.rest)  # XLA's own annotation
            if trip == 0.0:
                mc = _WHILE_COND_RE.search(op.rest)
                trip = (self._trip_count(self.comps[mc.group(1)])
                        if mc and mc.group(1) in self.comps else 1.0)
            mb = _WHILE_BODY_RE.search(op.rest)
            if mb and mb.group(1) in self.comps:
                c.add(self._comp_cost(self.comps[mb.group(1)], memo), mult=trip)
            return c
        for callee in _CALLS_RE.findall(op.rest):
            if callee in self.comps:
                # fusion-internal bytes are VMEM-local: flops/collectives only
                c.add(self._comp_cost(self.comps[callee], memo),
                      with_bytes=False)
        if op.opcode == "dot":
            c.flops += self._dot_flops(op)
        base = op.opcode.replace("-start", "")
        if base in COLLECTIVES and not op.opcode.endswith("-done"):
            nbytes = float(_shape_bytes(op.out_type))
            c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + nbytes
            c.coll_count[base] = c.coll_count.get(base, 0.0) + 1
        c.bytes += self._op_bytes(op)
        return c

    def _comp_cost(self, comp: _Computation, memo) -> Cost:
        if comp.name in memo:
            return memo[comp.name]
        memo[comp.name] = Cost()
        total = Cost()
        for op in comp.ops:
            total.add(self._op_cost(op, memo))
        memo[comp.name] = total
        return total

    def cost(self) -> Cost:
        if self.entry is None or self.entry not in self.comps:
            return Cost()
        return self._comp_cost(self.comps[self.entry], {})


def analyze(hlo: str) -> Cost:
    return HloProgram(hlo).cost()
