"""Sharding rule engine: GSPMD partition specs for params, opt state, batch.

One place decides how every tensor lays out over the mesh:

* ``batch_axes(mesh)`` — the data-parallel axes (``("pod", "data")`` on the
  multi-pod mesh, ``"data"`` otherwise); batches shard their leading dim
  over them.
* ``params_shardings`` / ``opt_state_shardings`` — per-leaf NamedShardings.
  Profile ``tp`` shards each weight's largest divisible dim over ``model``;
  ``fsdp_tp`` additionally shards a second dim over the data axes (ZeRO-3
  style). Optimizer moments always take the data axes too (ZeRO-1): they
  are touched once per step, so gathers are off the critical path.
* ``constrain(x, axes)`` — in-graph sharding hints for model code.
  ``axes`` entries are ``"batch"`` (data axes), ``"model"``, a literal mesh
  axis name, or ``None``. First-divisible-wins: when several dims name the
  same mesh axis, the first whose extent divides the axis size takes it and
  the rest stay replicated (a mesh axis can partition only one dim).
  Outside a mesh context (single-device tests) it is the identity.
* ``shard_map_batch(fn, *args)`` — run ``fn`` batch-locally via shard_map
  over the data axes (for ops GSPMD mispartitions, e.g. batched gathers in
  the MoE dispatch). Identity-wrapped when no mesh is active.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"
DATA = "data"
POD = "pod"


def _path_str(path) -> str:
    def part(k):
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)
    return "/".join(part(k) for k in path)


def current_mesh() -> Optional[Mesh]:
    """The ambient ``with mesh:`` context, or None."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes the batch dim shards over (pod-major on multi-pod meshes).

    Always a tuple: callers iterate it and splice it into PartitionSpecs
    (a tuple of names is a valid single-dim spec entry).
    """
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def _axes_size(mesh: Mesh, axes: Union[str, tuple, None]) -> int:
    if axes is None or axes == ():
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# ---------------------------------------------------------------------------
# in-graph constraints
# ---------------------------------------------------------------------------


def _resolve_spec(shape: Sequence[int], axes: Sequence[Any], mesh: Mesh) -> P:
    spec: List[Any] = [None] * len(shape)
    used: set = set()
    for d, want in enumerate(axes[: len(shape)]):
        if want is None:
            continue
        resolved = batch_axes(mesh) if want == "batch" else want
        if resolved is None or resolved == ():
            continue
        names = (resolved,) if isinstance(resolved, str) else tuple(resolved)
        if any(n not in mesh.axis_names or n in used for n in names):
            continue
        size = _axes_size(mesh, names)
        # first-divisible-wins: an indivisible dim stays replicated rather
        # than erroring out of GSPMD (e.g. kv heads % model on GQA archs)
        if size <= 1 or shape[d] % size != 0:
            continue
        spec[d] = resolved
        used.update(names)
    return P(*spec)


def constrain(x: jax.Array, axes: Sequence[Any]) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = _resolve_spec(x.shape, list(axes), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map_batch(fn, *args):
    """Run ``fn`` with each arg's leading (batch) dim split over the data
    axes; outputs are reassembled on the same layout. Batch-local compute
    only — ``fn`` must not reduce across the batch dim."""
    mesh = current_mesh()
    if mesh is None:
        return fn(*args)
    axes = batch_axes(mesh)
    dsize = _axes_size(mesh, axes)
    if dsize <= 1 or any(a.shape[0] % dsize != 0 for a in args):
        return fn(*args)
    from jax.experimental.shard_map import shard_map

    in_specs = tuple(P(axes, *([None] * (a.ndim - 1))) for a in args)
    out_shapes = jax.eval_shape(fn, *args)
    out_specs = jax.tree.map(
        lambda s: P(axes, *([None] * (len(s.shape) - 1))), out_shapes)
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)(*args)


# ---------------------------------------------------------------------------
# state shardings
# ---------------------------------------------------------------------------


def _leaf_sharding(shape: Sequence[int], mesh: Mesh, *,
                   fsdp: bool) -> NamedSharding:
    nd = len(shape)
    spec: List[Any] = [None] * nd
    msize = mesh.shape.get(MODEL, 1)
    # tensor-parallel dim: largest extent divisible by the model axis
    if msize > 1 and nd >= 1:
        for d in sorted(range(nd), key=lambda d: -shape[d]):
            if shape[d] >= msize and shape[d] % msize == 0:
                spec[d] = MODEL
                break
    if fsdp:
        daxes = batch_axes(mesh)
        dsize = _axes_size(mesh, daxes)
        if dsize > 1:
            for d in sorted(range(nd), key=lambda d: -shape[d]):
                if spec[d] is None and shape[d] >= dsize and shape[d] % dsize == 0:
                    spec[d] = daxes
                    break
    return NamedSharding(mesh, P(*spec))


def params_shardings(params: Any, cfg: Any, mesh: Mesh,
                     profile: Optional[str] = None) -> Any:
    """Pytree of NamedShardings matching ``params``.

    ``profile`` overrides ``cfg.sharding_profile`` (``tp`` | ``fsdp_tp``).
    """
    profile = profile or getattr(cfg, "sharding_profile", "tp")
    fsdp = profile == "fsdp_tp"

    def leaf(path, x):
        return _leaf_sharding(tuple(x.shape), mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_state_shardings(tree: Any, cfg: Any, mesh: Mesh,
                        profile: Optional[str] = None) -> Any:
    """Adam moments: ZeRO-1 — always take the data axes on top of TP.

    Moments are read/written once per step (not per layer per microbatch),
    so sharding them over data costs one reduce-scatter/all-gather pair off
    the forward/backward critical path and divides optimizer-state HBM by
    the data-parallel degree.
    """
    def leaf(path, x):
        return _leaf_sharding(tuple(x.shape), mesh, fsdp=True)

    return jax.tree_util.tree_map_with_path(leaf, tree)
