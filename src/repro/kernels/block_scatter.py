"""Pallas TPU kernel: scatter K (bh, bw) tiles into a dense 2-D output.

BSGS decode hot loop (paper Eq. 8, t_de). GPU scatters use atomics /
shared-memory banking; the TPU-native shape is the inverse: iterate the
*output* block grid sequentially (streaming, DMA-friendly) and let each
step pull in either its incoming block or the base tile. The inverse map
(output block -> source block or K=none) is computed once with one jnp
scatter outside the kernel and rides in scalar-prefetch SMEM.

This turns a random-scatter into a fully sequential HBM write pass —
bandwidth-optimal for a dense destination, no write hazards, no atomics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(inv_ref, blocks_ref, base_ref, o_ref, *, k_sel: int):
    g = pl.program_id(0)
    use_block = inv_ref[g] < k_sel
    o_ref[...] = jnp.where(use_block, blocks_ref[0].astype(o_ref.dtype), base_ref[...])


def block_scatter(base: jax.Array, ids: jax.Array, blocks: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """Write blocks[j] over base at block id ids[j]; ids >= n_blocks drop.

    base: (m, n); ids: (K,); blocks: (K, bh, bw). Returns updated (m, n).
    Duplicate ids are unsupported (BSGS ids are unique by construction).
    """
    k_sel, bh, bw = blocks.shape
    m, n = base.shape
    assert m % bh == 0 and n % bw == 0, (base.shape, blocks.shape)
    gh, gw = m // bh, n // bw
    n_blocks = gh * gw
    # inverse map: for each output block, which selected block lands there
    inv = jnp.full((n_blocks,), k_sel, dtype=jnp.int32)
    inv = inv.at[ids].set(jnp.arange(k_sel, dtype=jnp.int32), mode="drop")

    def out_map(g, inv_ref):
        return g // gw, g % gw

    def blocks_map(g, inv_ref):
        return jnp.minimum(inv_ref[g], k_sel - 1), 0, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, bh, bw), blocks_map),
                  pl.BlockSpec((bh, bw), out_map)],
        out_specs=pl.BlockSpec((bh, bw), out_map),
    )
    return pl.pallas_call(
        functools.partial(_scatter_kernel, k_sel=k_sel),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), base.dtype),
        interpret=interpret,
    )(inv, blocks, base)
