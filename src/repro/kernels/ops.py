"""Public jit'd entry points for the Pallas kernels.

Dispatch policy: compiled Pallas on TPU; on CPU the default is the ref.py
oracle (bit-identical semantics, fast under XLA:CPU), while
``use_pallas=True`` forces the kernel through the Pallas interpreter —
that is how the test suite validates the kernel bodies on this machine.

All wrappers pad operands to kernel alignment (tile multiples) and crop
the result, so callers never see the alignment constraints.
"""

from __future__ import annotations

import math
import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .block_gather import block_gather as _pl_block_gather
from .block_norms import block_norms as _pl_block_norms
from .block_scatter import block_scatter as _pl_block_scatter
from .coo_scatter import coo_scatter as _pl_coo_scatter
from .unshuffle import byte_unshuffle_planes as _pl_unshuffle


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _decide(use_pallas: Optional[bool]) -> Tuple[bool, bool]:
    """-> (use_pallas, interpret)

    REPRO_FORCE_PALLAS_INTERPRET=1 makes the default dispatch run every
    kernel body through the Pallas interpreter — the CI leg that exercises
    the kernels on CPU-only runners.
    """
    if use_pallas is None:
        use_pallas = _on_tpu() or bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"))
    return use_pallas, not _on_tpu()


def _pad2d(x: jax.Array, bh: int, bw: int) -> jax.Array:
    m, n = x.shape
    pm, pn = (-m) % bh, (-n) % bw
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@partial(jax.jit, static_argnames=("block_shape", "use_pallas"))
def block_gather(x: jax.Array, ids: jax.Array, block_shape: Tuple[int, int],
                 use_pallas: Optional[bool] = None) -> jax.Array:
    """Gather tiles listed in ``ids`` from (possibly ragged) 2-D ``x``."""
    pallas, interpret = _decide(use_pallas)
    xp = _pad2d(x, *block_shape)
    if pallas:
        return _pl_block_gather(xp, ids, block_shape, interpret=interpret)
    return ref.block_gather(xp, ids, block_shape)


@partial(jax.jit, static_argnames=("use_pallas",))
def block_scatter(base: jax.Array, ids: jax.Array, blocks: jax.Array,
                  use_pallas: Optional[bool] = None) -> jax.Array:
    pallas, interpret = _decide(use_pallas)
    bh, bw = blocks.shape[1:]
    m, n = base.shape
    bp = _pad2d(base, bh, bw)
    out = (_pl_block_scatter(bp, ids, blocks, interpret=interpret)
           if pallas else ref.block_scatter(bp, ids, blocks))
    return out[:m, :n]


@partial(jax.jit, static_argnames=("use_pallas",))
def block_norms(bv: jax.Array, use_pallas: Optional[bool] = None) -> jax.Array:
    pallas, interpret = _decide(use_pallas)
    g, b = bv.shape
    if pallas:
        tile_g = 8
        pg = (-g) % tile_g
        bvp = jnp.pad(bv, ((0, pg), (0, 0))) if pg else bv
        return _pl_block_norms(bvp, tile_g=tile_g, interpret=interpret)[:g]
    return ref.block_norms(bv)


@partial(jax.jit, static_argnames=("size", "use_pallas"))
def coo_scatter(flat_idx: jax.Array, values: jax.Array, size: int,
                use_pallas: Optional[bool] = None) -> jax.Array:
    pallas, interpret = _decide(use_pallas)
    if pallas:
        tile = 512 if size >= 512 else max(128, 1 << max(size - 1, 1).bit_length())
        padded = math.ceil(size / tile) * tile
        out = _pl_coo_scatter(flat_idx, values, padded, tile=tile,
                              interpret=interpret)
        return out[:size]
    return ref.coo_scatter(flat_idx, values, size)


@partial(jax.jit, static_argnames=("use_pallas",))
def unshuffle(planes: jax.Array, use_pallas: Optional[bool] = None) -> jax.Array:
    """Byte-plane transpose: (itemsize, n) uint8 planes -> (n, itemsize)."""
    pallas, interpret = _decide(use_pallas)
    if pallas:
        itemsize, n = planes.shape
        tile = 512
        pad = (-n) % tile
        pp = jnp.pad(planes, ((0, 0), (0, pad))) if pad else planes
        return _pl_unshuffle(pp, tile=tile, interpret=interpret)[:n]
    return ref.unshuffle(planes)


def unshuffle_host(planes: np.ndarray, *,
                   use_pallas: Optional[bool] = None) -> np.ndarray:
    """Host-buffer entry point with the ``compression.set_unshuffle_kernel``
    signature: numpy (itemsize, n) uint8 planes in, numpy (n, itemsize) out."""
    return np.asarray(unshuffle(jnp.asarray(planes), use_pallas=use_pallas))


def block_gather_host(x: np.ndarray, ids: np.ndarray,
                      block_shape: Tuple[int, int], *,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    """Host-buffer entry point: numpy operand/ids in, device tiles out.

    This is the lake's device-read doorway (``lake/device.py``): the staged
    chunk buffer never round-trips through a host-side gather.
    """
    return block_gather(jnp.asarray(x), jnp.asarray(ids, dtype=jnp.int32),
                        tuple(block_shape), use_pallas=use_pallas)


def coo_scatter_host(flat_idx: np.ndarray, values: np.ndarray, size: int, *,
                     use_pallas: Optional[bool] = None) -> jax.Array:
    """Host-buffer entry point: COO pairs in, dense device buffer out."""
    if len(flat_idx) == 0:
        return jnp.zeros((int(size),), dtype=values.dtype)
    return coo_scatter(jnp.asarray(flat_idx, dtype=jnp.int32),
                       jnp.asarray(values), int(size), use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("block_shape", "k", "use_pallas"))
def block_topk(x: jax.Array, block_shape: Tuple[int, int], k: int,
               use_pallas: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """(ids, blocks) of the k highest-energy tiles — gradient compression."""
    pallas, interpret = _decide(use_pallas)
    bh, bw = block_shape
    xp = _pad2d(x, bh, bw)
    m, n = xp.shape
    gh, gw = m // bh, n // bw
    bv = xp.reshape(gh, bh, gw, bw).transpose(0, 2, 1, 3).reshape(gh * gw, bh * bw)
    norms = block_norms(bv, use_pallas=use_pallas)
    _, ids = jax.lax.top_k(norms, k)
    ids = ids.astype(jnp.int32)
    blocks = (block_gather(xp, ids, block_shape, use_pallas=use_pallas)
              if pallas else ref.block_gather(xp, ids, block_shape))
    return ids, blocks
