"""Pallas TPU kernel: byte-unshuffle — the (itemsize, n) byte-plane transpose.

Frame decode hot loop: a shuffled chunk blob stores the i-th byte of every
item contiguously (``[b0 b0 ...][b1 b1 ...]``, the HDF5/Blosc filter that
makes float exponent bytes compressible); decode must transpose the planes
back to interleaved items. The numpy path in
``repro.lake.compression.byte_unshuffle`` pays a strided host transpose per
chunk; this kernel does the same transpose on-device, one column tile per
grid step, so decode bandwidth rides VMEM instead of the host memory bus.

Layout: input is the ``(itemsize, n_items)`` uint8 plane matrix, output the
``(n_items, itemsize)`` item matrix (flattening it row-major yields the raw
buffer). Each grid step moves one ``(itemsize, tile)`` slab of planes into
VMEM and writes it back transposed as ``(tile, itemsize)`` — itemsize is
tiny (2..16 for real dtypes), so a 512-column tile keeps the working set at
a few KiB while the lane dimension stays wide. Callers pad ``n_items`` to a
tile multiple and crop (see ``ops.unshuffle``).
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl


def _unshuffle_kernel(x_ref, o_ref):
    # (itemsize, tile) byte planes in, (tile, itemsize) items out
    o_ref[...] = x_ref[...].T


def byte_unshuffle_planes(planes: jax.Array, *, tile: int = 512,
                          interpret: bool = False) -> jax.Array:
    """planes: (itemsize, n) uint8 with n % tile == 0 -> (n, itemsize)."""
    itemsize, n = planes.shape
    assert n % tile == 0, (planes.shape, tile)
    return pl.pallas_call(
        _unshuffle_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((itemsize, tile), lambda t: (0, t))],
        out_specs=pl.BlockSpec((tile, itemsize), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, itemsize), planes.dtype),
        interpret=interpret,
    )(planes)
