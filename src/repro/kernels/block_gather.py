"""Pallas TPU kernel: gather K (bh, bw) tiles from a 2-D operand.

BSGS encode hot loop (paper Eq. 8, t_en): selected blocks are pulled from
HBM into VMEM one tile per grid step. The block ids ride in scalar-prefetch
SMEM so the BlockSpec index map can steer each step's DMA — the TPU version
of "only the necessary chunk is loaded into memory" (paper §II.A).

Tiling notes (v5e): pick bh a multiple of 8 and bw a multiple of 128 so a
tile is a whole (sublane × lane) vreg set; one (bh, bw) f32 tile of
8×128×4 B = 4 KiB keeps the double-buffered working set far under the
~16 MiB VMEM budget up to 512×512 blocks.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, x_ref, o_ref, *, n_blocks: int):
    k = pl.program_id(0)
    valid = ids_ref[k] < n_blocks
    tile = x_ref[...]
    o_ref[0] = jnp.where(valid, tile, jnp.zeros_like(tile))


def block_gather(x: jax.Array, ids: jax.Array, block_shape: Tuple[int, int],
                 *, interpret: bool = False) -> jax.Array:
    """x: (m, n) with m % bh == 0, n % bw == 0; ids: (K,) int32 block ids
    (row-major over the (m//bh, n//bw) grid; id == n_blocks marks padding).
    Returns (K, bh, bw)."""
    bh, bw = block_shape
    m, n = x.shape
    assert m % bh == 0 and n % bw == 0, (x.shape, block_shape)
    gh, gw = m // bh, n // bw
    n_blocks = gh * gw
    (k_sel,) = ids.shape

    def x_map(k, ids_ref):
        safe = jnp.minimum(ids_ref[k], n_blocks - 1)
        return safe // gw, safe % gw

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k_sel,),
        in_specs=[pl.BlockSpec((bh, bw), x_map)],
        out_specs=pl.BlockSpec((1, bh, bw), lambda k, ids_ref: (k, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, n_blocks=n_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_sel, bh, bw), x.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), x)
