"""Pallas TPU kernel: COO decode — scatter nnz values into a flat buffer.

GPU COO decode is an atomic scatter; TPUs have no scatter unit, but they
have an MXU. The TPU-native adaptation: iterate output tiles sequentially
and materialize each tile as a one-hot matmul,

    out[t*T : (t+1)*T] = values @ one_hot(idx - t*T, T)

i.e. a (K,) x (K, T) contraction on the MXU per tile. The full index/value
vectors stay resident in VMEM across grid steps (K is the device codec's
fixed capacity, <= ~128Ki f32 comfortably). Out-of-range indices — the
padding convention of ``repro.core.device.coo_encode`` — fall outside every
tile and drop naturally. Duplicate indices accumulate, matching
scatter-add semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coo_scatter_kernel(idx_ref, vals_ref, o_ref, *, tile: int):
    t = pl.program_id(0)
    start = t * tile
    local = idx_ref[...] - start                     # (K,)
    vals = vals_ref[...]
    k = local.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (k, tile), 1)
    onehot = (local[:, None] == cols).astype(vals.dtype)   # (K, T)
    o_ref[...] = jnp.dot(vals[None, :], onehot,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def coo_scatter(flat_idx: jax.Array, values: jax.Array, size: int,
                *, tile: int = 512, interpret: bool = False) -> jax.Array:
    """flat_idx: (K,) int32; values: (K,); returns (size,) dense.

    size % tile == 0 (callers pad; tile a multiple of 128 for the MXU).
    """
    assert size % tile == 0, (size, tile)
    (k,) = values.shape
    out = pl.pallas_call(
        functools.partial(_coo_scatter_kernel, tile=tile),
        grid=(size // tile,),
        in_specs=[pl.BlockSpec((k,), lambda t: (0,)),
                  pl.BlockSpec((k,), lambda t: (0,))],
        out_specs=pl.BlockSpec((1, tile), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, size), values.dtype),
        interpret=interpret,
    )(flat_idx.astype(jnp.int32), values)
    return out[0]
