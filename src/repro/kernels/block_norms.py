"""Pallas TPU kernel: per-block squared-L2 norms of a (G, B) blocked view.

The bandwidth-bound half of gradient compression (block top-k): one pass
over the gradient reading each element once, reducing every block row to a
scalar in f32. Arithmetic intensity ~0.25 FLOP/B, so the kernel's only job
is to keep the DMA pipeline saturated: (gt, B) input tiles stream through
VMEM; the (gt, 1) partial results live in VMEM and flush per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norms_kernel(bv_ref, o_ref):
    x = bv_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * x, axis=1, keepdims=True)


def block_norms(bv: jax.Array, *, tile_g: int = 8,
                interpret: bool = False) -> jax.Array:
    """bv: (G, B) blocked view (G % tile_g == 0). Returns (G,) f32 norms."""
    g, b = bv.shape
    assert g % tile_g == 0, (bv.shape, tile_g)
    out = pl.pallas_call(
        _norms_kernel,
        grid=(g // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_g, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 1), jnp.float32),
        interpret=interpret,
    )(bv)
    return out[:, 0]
