"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are asserted
against (tests sweep shapes/dtypes with assert_allclose). They are also
the CPU fallback used by ops.py when not running on TPU hardware.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def block_gather(x: jax.Array, ids: jax.Array, block_shape: Tuple[int, int]) -> jax.Array:
    """Gather K (bh, bw) tiles from a 2-D operand.

    ids are flattened block-grid indices (row-major over the grid); an id
    == n_blocks marks padding and yields a zero tile.
    """
    bh, bw = block_shape
    m, n = x.shape
    gh, gw = m // bh, n // bw
    n_blocks = gh * gw
    bv = x.reshape(gh, bh, gw, bw).transpose(0, 2, 1, 3).reshape(n_blocks, bh, bw)
    valid = ids < n_blocks
    safe = jnp.clip(ids, 0, n_blocks - 1)
    out = bv[safe]
    return jnp.where(valid[:, None, None], out, 0)


def block_scatter(base: jax.Array, ids: jax.Array, blocks: jax.Array) -> jax.Array:
    """Write K (bh, bw) tiles into ``base`` at flattened grid positions.

    Padding ids (>= n_blocks) are dropped. Duplicate ids are unsupported
    (BSGS block ids are unique by construction).
    """
    k, bh, bw = blocks.shape
    m, n = base.shape
    gh, gw = m // bh, n // bw
    n_blocks = gh * gw
    bv = base.reshape(gh, bh, gw, bw).transpose(0, 2, 1, 3).reshape(n_blocks, bh, bw)
    bv = bv.at[ids].set(blocks.astype(base.dtype), mode="drop")
    return bv.reshape(gh, gw, bh, bw).transpose(0, 2, 1, 3).reshape(m, n)


def block_norms(bv: jax.Array) -> jax.Array:
    """Squared-L2 per row of a (G, B) blocked view, accumulated in f32."""
    return jnp.sum(jnp.square(bv.astype(jnp.float32)), axis=-1)


def coo_scatter(flat_idx: jax.Array, values: jax.Array, size: int) -> jax.Array:
    """Scatter nnz values into a flat dense buffer (COO decode).

    Out-of-range indices (the fixed-capacity padding convention) drop.
    """
    out = jnp.zeros((size,), dtype=values.dtype)
    return out.at[flat_idx].add(values, mode="drop")


def unshuffle(planes: jax.Array) -> jax.Array:
    """Byte-plane transpose: (itemsize, n) uint8 planes -> (n, itemsize)."""
    return planes.T


def block_topk(x: jax.Array, block_shape: Tuple[int, int], k: int):
    """Top-k blocks by energy: (ids, blocks) — the gradient-compression path."""
    bh, bw = block_shape
    m, n = x.shape
    gh, gw = m // bh, n // bw
    bv = x.reshape(gh, bh, gw, bw).transpose(0, 2, 1, 3).reshape(gh * gw, bh * bw)
    norms = block_norms(bv)
    _, ids = jax.lax.top_k(norms, k)
    return ids.astype(jnp.int32), block_gather(x, ids.astype(jnp.int32), block_shape)
