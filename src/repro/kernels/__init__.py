"""Pallas TPU kernels for the paper's encode/decode hot loops.

Structure per kernel: <name>.py holds the pl.pallas_call + BlockSpec body,
ops.py the jit'd public wrappers (TPU: compiled; CPU: ref fallback or
interpret=True under test), ref.py the pure-jnp oracles.
"""
from . import ops, ref
from .ops import (block_gather, block_gather_host, block_norms, block_scatter,
                  block_topk, coo_scatter, coo_scatter_host, unshuffle,
                  unshuffle_host)


def install_unshuffle_kernel(force: bool = False) -> bool:
    """Route ``compression.byte_unshuffle``'s plane transpose through the
    Pallas kernel. Auto-installed on TPU hosts at import; ``force=True``
    installs on any backend (tests run it through the interpreter)."""
    from ..lake import compression
    if force or ops._on_tpu():
        compression.set_unshuffle_kernel(unshuffle_host)
        return True
    return False


install_unshuffle_kernel()

__all__ = ["ops", "ref", "block_gather", "block_gather_host", "block_norms",
           "block_scatter", "block_topk", "coo_scatter", "coo_scatter_host",
           "unshuffle", "unshuffle_host", "install_unshuffle_kernel"]
