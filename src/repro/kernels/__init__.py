"""Pallas TPU kernels for the paper's encode/decode hot loops.

Structure per kernel: <name>.py holds the pl.pallas_call + BlockSpec body,
ops.py the jit'd public wrappers (TPU: compiled; CPU: ref fallback or
interpret=True under test), ref.py the pure-jnp oracles.
"""
from . import ops, ref
from .ops import block_gather, block_norms, block_scatter, block_topk, coo_scatter

__all__ = ["ops", "ref", "block_gather", "block_norms", "block_scatter",
           "block_topk", "coo_scatter"]
