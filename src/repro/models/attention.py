"""Attention: GQA + RoPE + sliding-window + cross-attn + KV-cache decode.

Training/prefill use a chunked flash formulation in pure JAX: an outer
``lax.map`` over query tiles and an inner ``lax.scan`` over KV tiles with
running (max, denom, acc) in f32. Nothing O(T·S) is ever materialized, so
the 32k-prefill cells fit HBM and XLA fuses the tile body; tile sizes are
config knobs (``attn_chunk_q/kv``) aligned to MXU shapes.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, dense_init, rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, Hkv, Dh)
    v: jax.Array   # (B, S_max, Hkv, Dh)


def attn_init(key, cfg: ArchConfig, dtype, d_model: Optional[int] = None,
              kv_d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dkv = kv_d_model or d
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, dkv, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, dkv, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, d, dtype),
    }


def _project_qkv(p: Params, x: jax.Array, kv_x: jax.Array, cfg: ArchConfig):
    b, t, _ = x.shape
    s = kv_x.shape[1]
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, hd)
    k = (kv_x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (kv_x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, chunk_q: int = 512,
                    chunk_kv: int = 1024) -> jax.Array:
    """q: (B,T,Hq,Dh); k/v: (B,S,Hkv,Dh) with Hq % Hkv == 0. Returns (B,T,Hq,Dh)."""
    b, t, hq, dh = q.shape
    s0, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    cq = min(chunk_q, t)
    ck = min(chunk_kv, s0)
    t0 = t
    pad_t = (-t) % cq
    if pad_t:  # ragged prompt lengths: pad queries, slice the rows off below
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        t = t0 + pad_t
    pad_s = (-s0) % ck
    if pad_s:  # ragged cache length: pad keys, mask below by k_pos >= s0
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    s = s0 + pad_s
    nq, nk = t // cq, s // ck

    # full-head form: q keeps its Hq dim (shardable over `model` when
    # Hq % model == 0 — the GQA (Hkv, G) reshape broke that sharding and
    # made GSPMD all-gather a KV tile per scan step: 164k tile gathers /
    # 1.4 TB on the 32k-prefill cell before this change). KV tiles are
    # broadcast to Hq inside the tile body (free under sharding: each
    # shard expands only its local head group).
    from ..dist.sharding import constrain
    q = constrain(q, ["batch", None, "model", None])
    k = constrain(k, ["batch", None, "model", None])  # replicated if kv%16
    v = constrain(v, ["batch", None, "model", None])
    qc = jnp.moveaxis(q.reshape(b, nq, cq, hq, dh), 1, 0)   # (nq,B,cq,Hq,Dh)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, hkv, dh), 1, 0)

    def q_block(args):
        qi, q_i = args                                # q_i: (B, cq, Hq, Dh)
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_j, v_j = inp
            k_pos = kj * ck + jnp.arange(ck)
            k_rep = jnp.repeat(k_j, g, axis=2)        # (B, ck, Hq, Dh)
            v_rep = jnp.repeat(v_j, g, axis=2)
            sc = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_rep,
                            preferred_element_type=jnp.float32) * scale
            keep = jnp.broadcast_to((k_pos < s0)[None, :], (cq, ck))
            if causal:
                keep &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                keep &= q_pos[:, None] - k_pos[None, :] < window
            sc = jnp.where(keep, sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            # mask again after the subtraction: a fully-masked tile has
            # sc == m_new == NEG_INF and exp(0) would leak 1s
            p = jnp.exp(sc - m_new[..., None]) * keep
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_rep.dtype), v_rep,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, cq), jnp.float32)
        a0 = jnp.zeros((b, hq, cq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hq,cq,Dh)
        return jnp.moveaxis(out, 2, 1)                 # (B,cq,Hq,Dh)

    if nq == 1:
        out = q_block((jnp.asarray(0), qc[0]))[:, None]
    else:
        out = jax.lax.map(q_block, (jnp.arange(nq), qc))   # (nq,B,cq,Hq,Dh)
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(b, t, hq, dh)
    return out[:, :t0].astype(q.dtype)


def decode_attention(q: jax.Array, cache: KVCache, cache_len: jax.Array, *,
                     window: Optional[int] = None,
                     ring: bool = False) -> jax.Array:
    """One-token attention over a (possibly ring-buffered) KV cache.

    q: (B, 1, Hq, Dh); cache arrays (B, S, Hkv, Dh); cache_len = number of
    valid entries — a scalar or per-slot (B,) vector (the new token's k/v
    already written at cache_len-1).
    """
    b, _, hq, dh = q.shape
    s, hkv = cache.k.shape[1], cache.k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hkv, g, dh)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg, cache.k,
                    preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    clen = jnp.reshape(cache_len, (-1, 1)) if jnp.ndim(cache_len) else cache_len
    if ring:
        # ring buffer of width s (== window): slot i holds absolute position
        # p - ((p - i) mod s); early steps (abs < 0) are empty
        p_cur = clen - 1
        abs_pos = p_cur - jnp.mod(p_cur - pos[None, :], s)
        keep = jnp.broadcast_to(abs_pos >= 0, (b, s))
    else:
        keep = jnp.broadcast_to(pos[None, :] < clen, (b, s))
        if window is not None:
            keep &= pos[None, :] >= clen - window
    sc = jnp.where(keep[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def attn_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
               positions: jax.Array,
               kv_x: Optional[jax.Array] = None,
               causal: bool = True,
               window: Optional[int] = None,
               use_rope: bool = True,
               cache: Optional[KVCache] = None,
               cache_index: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Self- or cross-attention; prefill (cache returned filled) or decode.

    * training:      cache=None, cache_index=None
    * prefill:       cache=empty KVCache, cache_index=0 — fills [0, T)
    * decode:        cache=filled, cache_index=current length; x is (B,1,D)
    * cross-attn:    kv_x = encoder/image states; use_rope=False, causal=False
    """
    cross = kv_x is not None
    q, k, v = _project_qkv(p, x, kv_x if cross else x, cfg)
    if use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    elif use_rope and cross:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not cross:
        s_max = cache.k.shape[1]
        # ring mode: windowed attention serving with a window-sized cache
        ring = window is not None and s_max <= window
        widx = jnp.mod(cache_index, s_max) if ring else cache_index
        if jnp.ndim(cache_index) == 1 and x.shape[1] == 1:
            # per-slot decode write (continuous batching: ragged lengths)
            bidx = jnp.arange(x.shape[0])
            kc = cache.k.at[bidx, widx].set(k[:, 0].astype(cache.k.dtype))
            vc = cache.v.at[bidx, widx].set(v[:, 0].astype(cache.v.dtype))
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), widx, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), widx, axis=1)
        new_cache = KVCache(kc, vc)
        if x.shape[1] == 1:  # decode step
            out = decode_attention(q, new_cache, cache_index + 1,
                                   window=window, ring=ring)
            return (out.reshape(*x.shape[:2], -1) @ p["wo"]), new_cache
        k, v = kc, vc  # prefill: attend over the filled prefix (masked by causal)

    out = flash_attention(q, k, v, causal=causal and not cross, window=window,
                          q_offset=0, chunk_q=cfg.attn_chunk_q,
                          chunk_kv=cfg.attn_chunk_kv)
    return (out.reshape(*x.shape[:2], -1) @ p["wo"]), new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
