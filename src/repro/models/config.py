"""Architecture configuration — one frozen dataclass covers the whole zoo.

Every assigned architecture is expressed as an ``ArchConfig``; family-
specific structure (MoE, SSM, hybrid interleave, enc-dec, cross-attn) is
driven by fields rather than subclasses so the transformer assembly stays
one code path under ``jax.lax.scan``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention flavour
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # sliding-window attention width
    swa_every: int = 1                    # 1 = all layers windowed (if window)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: Optional[int] = None        # expert FFN width (defaults d_ff)

    # SSM (mamba2 / xlstm)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128

    # hybrid (zamba2): one shared attention block applied every k SSM layers
    shared_attn_every: int = 0

    # xLSTM: layers per super-block; last one is sLSTM, rest mLSTM
    xlstm_slstm_every: int = 0

    # vlm (llama-3.2-vision): cross-attn layer leading every super-block
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # audio (whisper): encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_divisor: int = 4          # stub frontend: frames = seq / divisor

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # distribution
    sharding_profile: str = "tp"          # tp | fsdp_tp
    remat_policy: str = "nothing_saveable"  # scan remat policy
    attn_chunk_q: int = 512               # flash attention tile sizes
    attn_chunk_kv: int = 1024

    # which shape cells apply (documented skips)
    supports_long_context: bool = False   # sub-quadratic path exists
    supports_decode: bool = True

    def kv_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def reduced(self) -> "ArchConfig":
        """CPU-smoke-test twin: same family/topology, tiny sizes."""
        def shrink(v, lo, hi):
            return max(lo, min(v, hi))
        kw: Dict = dict(
            n_layers=shrink(self.n_layers // 8, 2, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            n_image_tokens=16 if self.n_image_tokens else 0,
            window=min(self.window, 16) if self.window else None,
            attn_chunk_q=16, attn_chunk_kv=16,
            ssm_chunk=8,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.n_experts else None,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            dtype="float32",
        )
        # keep the interleave structure but make it fit the reduced depth
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
        if self.xlstm_slstm_every:
            kw["xlstm_slstm_every"] = 2
        if self.shared_attn_every or self.cross_attn_every or self.xlstm_slstm_every:
            kw["n_layers"] = 4
        return replace(self, **kw)


_REGISTRY: Dict[str, ArchConfig] = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # populate from the configs package lazily
        from .. import configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    from .. import configs  # noqa: F401
    return tuple(sorted(_REGISTRY))
