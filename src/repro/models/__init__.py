from .config import ArchConfig, get_arch, list_archs, register_arch
from . import attention, layers, moe, ssm, transformer

__all__ = ["ArchConfig", "get_arch", "list_archs", "register_arch",
           "attention", "layers", "moe", "ssm", "transformer"]
