"""Shared layers: norms, projections, RoPE, SwiGLU. Pure JAX, pytree params."""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, Dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, f, dtype),
            "w_up": dense_init(k2, d, f, dtype),
            "w_down": dense_init(k3, f, d, dtype)}


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def gelu_mlp_init(key, d: int, f: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, f, dtype),
            "w_out": dense_init(k2, f, d, dtype)}


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["w_in"], approximate=True) @ p["w_out"]


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array, *, tied: bool) -> jax.Array:
    w = table_or_head.T if tied else table_or_head
    # logits in f32 for stable loss
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      w.astype(jnp.float32))
