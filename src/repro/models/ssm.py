"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan has no TPU analogue,
so both Mamba2 and mLSTM run through one shared **chunked gated-linear-
attention core** — the SSD block-decomposition of Dao & Gu: intra-chunk
work is dense (cq × cq) matmuls on the MXU, inter-chunk state is a short
``lax.scan`` over T/chunk steps carrying the (N × P) matrix state. Decode
is the O(1) recurrent step on the same state.

mLSTM rides the same core with sigmoid forget/input gates and a learned
normalizer row (the ones-column trick appends the normalizer to the value
matrix so one scan carries both). sLSTM has true hidden-state feedback in
its gates, which is inherently sequential: it runs as a ``lax.scan`` over
time (documented; it is 1/8 of xLSTM's layers).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# shared chunked core:  h_t = a_t h_{t-1} + k_t v_t^T ;  y_t = h_t^T q_t
#   q,k: (B,T,H,N)  v: (B,T,H,P)  a: (B,T,H) in (0,1]
# ---------------------------------------------------------------------------


class GLAState(NamedTuple):
    s: jax.Array    # (B, H, N, P) matrix state


def gla_chunked(q: jax.Array, k: jax.Array, v: jax.Array, a: jax.Array,
                chunk: int, init_state: Optional[GLAState] = None
                ) -> Tuple[jax.Array, GLAState]:
    b, t, h, n = q.shape
    p = v.shape[-1]
    c = min(chunk, t)
    assert t % c == 0, (t, c)
    nc = t // c

    qc = jnp.moveaxis(q.reshape(b, nc, c, h, n), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nc, c, h, n), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, c, h, p), 1, 0)
    la = jnp.log(jnp.maximum(a, 1e-20)).astype(jnp.float32)
    lac = jnp.moveaxis(la.reshape(b, nc, c, h), 1, 0)

    s0 = (init_state.s if init_state is not None
          else jnp.zeros((b, h, n, p), jnp.float32))

    def step(s, inp):
        q_i, k_i, v_i, la_i = inp                     # (B,c,H,·)
        cum = jnp.cumsum(la_i, axis=1)                # (B,c,H) log decay from start
        # intra-chunk: M[i,j] = exp(cum_i - cum_j) for i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # (B,c,c,H)
        iota = jnp.arange(c)
        mask = iota[:, None] >= iota[None, :]
        m = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        att = jnp.einsum("bihn,bjhn->bijh", q_i.astype(jnp.float32),
                         k_i.astype(jnp.float32)) * m
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, v_i.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        dec_q = jnp.exp(cum)                                     # (B,c,H)
        y_inter = jnp.einsum("bihn,bhnp->bihp", q_i.astype(jnp.float32), s) \
            * dec_q[..., None]
        # new carried state
        dec_k = jnp.exp(cum[:, -1:, :] - cum)                    # decay j -> end
        s_local = jnp.einsum("bjhn,bjhp->bhnp",
                             (k_i.astype(jnp.float32) * dec_k[..., None]),
                             v_i.astype(jnp.float32))
        s_new = s * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_local
        return s_new, (y_intra + y_inter)

    s_fin, ys = jax.lax.scan(step, s0, (qc, kc, vc, lac))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, p)
    return y.astype(v.dtype), GLAState(s_fin)


def gla_step(q, k, v, a, state: GLAState) -> Tuple[jax.Array, GLAState]:
    """Single-token recurrent step. q,k: (B,1,H,N); v: (B,1,H,P); a: (B,1,H)."""
    s = state.s * a[:, 0, :, None, None].astype(jnp.float32)
    s = s + jnp.einsum("bhn,bhp->bhnp", k[:, 0].astype(jnp.float32),
                       v[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", q[:, 0].astype(jnp.float32), s)
    return y[:, None].astype(v.dtype), GLAState(s)


# ---------------------------------------------------------------------------
# causal depthwise conv (kernel 4), with decode state
# ---------------------------------------------------------------------------

CONV_K = 4


def conv_init(key, channels: int, dtype) -> Params:
    w = jax.random.normal(key, (CONV_K, channels), jnp.float32) / math.sqrt(CONV_K)
    return {"w": w.astype(dtype)}


def conv_apply(p: Params, x: jax.Array) -> jax.Array:
    """x: (B, T, C) causal depthwise conv along T."""
    w = p["w"].astype(jnp.float32)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(CONV_K))
    return jax.nn.silu(out).astype(x.dtype)


def conv_step(p: Params, x1: jax.Array, state: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x1: (B, 1, C); state: (B, K-1, C) previous inputs."""
    w = p["w"].astype(jnp.float32)
    window = jnp.concatenate([state, x1], axis=1).astype(jnp.float32)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w)[:, None]
    return jax.nn.silu(out).astype(x1.dtype), window[:, 1:].astype(state.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


class Mamba2Cache(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_channels)
    ssd: jax.Array    # (B, H, N, P)


def mamba2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n           # x, B, C go through the conv
    return d_inner, heads, n, conv_ch


def mamba2_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner, heads, n, conv_ch = mamba2_dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * n + heads, dtype),
        "conv": conv_init(ks[1], conv_ch, dtype),
        "a_log": jnp.zeros((heads,), jnp.float32) + jnp.log(jnp.e),  # A≈-e
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "w_out": dense_init(ks[2], d_inner, d, dtype),
    }


def _mamba2_split(p: Params, x: jax.Array, cfg: ArchConfig):
    d_inner, heads, n, conv_ch = mamba2_dims(cfg)
    zxbcdt = x @ p["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xbc, dt, (d_inner, heads, n)


def _mamba2_core(p, z, xbc, dt, dims, cfg, b, t):
    d_inner, heads, n = dims
    xv, bb, cc = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,T,H)
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt)                             # decay
    v = xv.reshape(b, t, heads, cfg.ssm_head_dim)
    v_in = v * dt[..., None].astype(v.dtype)
    q = jnp.repeat(cc[:, :, None, :], heads, axis=2)                   # C
    k = jnp.repeat(bb[:, :, None, :], heads, axis=2)                   # B
    return q, k, v, v_in, a


def mamba2_apply(p: Params, x: jax.Array, cfg: ArchConfig,
                 cache: Optional[Mamba2Cache] = None
                 ) -> Tuple[jax.Array, Optional[Mamba2Cache]]:
    b, t, _ = x.shape
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    z, xbc, dt, dims = _mamba2_split(p, xn, cfg)
    if cache is not None and t == 1:           # decode: O(1) recurrent step
        xbc1, conv_state = conv_step(p["conv"], xbc, cache.conv)
        q, k, v, v_in, a = _mamba2_core(p, z, xbc1, dt, dims, cfg, b, t)
        y, st = gla_step(q, k, v_in, a, GLAState(cache.ssd))
        new_cache = Mamba2Cache(conv=conv_state, ssd=st.s)
    else:                                       # train / prefill: chunked SSD
        xbc_raw = xbc
        xbc = conv_apply(p["conv"], xbc)
        q, k, v, v_in, a = _mamba2_core(p, z, xbc, dt, dims, cfg, b, t)
        init = GLAState(cache.ssd) if cache is not None else None
        y, st = gla_chunked(q, k, v_in, a, cfg.ssm_chunk, init_state=init)
        new_cache = None
        if cache is not None:
            tail = jnp.concatenate([cache.conv.astype(xbc_raw.dtype), xbc_raw],
                                   axis=1)[:, -(CONV_K - 1):]
            new_cache = Mamba2Cache(conv=tail.astype(cache.conv.dtype), ssd=st.s)
    y = y + v * p["d_skip"][None, None, :, None].astype(v.dtype)
    y = y.reshape(b, t, dims[0])
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return (y @ p["w_out"]).astype(x.dtype), new_cache


def mamba2_cache_init(cfg: ArchConfig, batch: int, dtype) -> Mamba2Cache:
    d_inner, heads, n, conv_ch = mamba2_dims(cfg)
    return Mamba2Cache(
        conv=jnp.zeros((batch, CONV_K - 1, conv_ch), dtype),
        ssd=jnp.zeros((batch, heads, n, cfg.ssm_head_dim), jnp.float32))


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — matrix memory via the shared core + normalizer row
# ---------------------------------------------------------------------------


class MLSTMCache(NamedTuple):
    s: jax.Array    # (B, H, N, P+1) state with normalizer column


def mlstm_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.n_heads
    p = d_inner // heads          # value head dim
    n = max(cfg.hd, 16)           # q/k head dim
    return d_inner, heads, n, p


def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner, heads, n, pdim = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype),
        "w_q": dense_init(ks[1], d_inner, heads * n, dtype),
        "w_k": dense_init(ks[2], d_inner, heads * n, dtype),
        "w_if": dense_init(ks[3], d_inner, 2 * heads, dtype),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "w_down": dense_init(ks[4], d_inner, d, dtype),
    }


def _mlstm_qkv(p, xi, cfg, b, t):
    d_inner, heads, n, pdim = mlstm_dims(cfg)
    q = (xi @ p["w_q"]).reshape(b, t, heads, n) / math.sqrt(n)
    k = (xi @ p["w_k"]).reshape(b, t, heads, n) / math.sqrt(n)
    v = xi.reshape(b, t, heads, pdim)
    gates = (xi @ p["w_if"]).astype(jnp.float32).reshape(b, t, heads, 2)
    i_g = jax.nn.sigmoid(gates[..., 0])
    f_g = jax.nn.sigmoid(gates[..., 1] + 2.0)   # bias toward remember
    ones = jnp.ones((b, t, heads, 1), v.dtype)
    v_aug = jnp.concatenate([v * i_g[..., None].astype(v.dtype), ones *
                             i_g[..., None].astype(v.dtype)], axis=-1)
    return q, k, v_aug, f_g, (d_inner, heads, n, pdim)


def _mlstm_out(y_aug, z, p, cfg, b, t, dims):
    d_inner, heads, n, pdim = dims
    y, norm = y_aug[..., :pdim], y_aug[..., pdim:]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    y = y.reshape(b, t, d_inner)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_down"]


def mlstm_apply(p: Params, x: jax.Array, cfg: ArchConfig,
                cache: Optional[MLSTMCache] = None
                ) -> Tuple[jax.Array, Optional[MLSTMCache]]:
    b, t, _ = x.shape
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    up = xn @ p["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)
    q, k, v_aug, f_g, dims = _mlstm_qkv(p, xi, cfg, b, t)
    if cache is not None and t == 1:           # decode
        y_aug, st = gla_step(q, k, v_aug, f_g, GLAState(cache.s))
        new_cache = MLSTMCache(st.s)
    else:                                       # train / prefill
        init = GLAState(cache.s) if cache is not None else None
        y_aug, st = gla_chunked(q, k, v_aug, f_g, cfg.ssm_chunk, init_state=init)
        new_cache = MLSTMCache(st.s) if cache is not None else None
    return _mlstm_out(y_aug, z, p, cfg, b, t, dims).astype(x.dtype), new_cache


def mlstm_cache_init(cfg: ArchConfig, batch: int) -> MLSTMCache:
    d_inner, heads, n, pdim = mlstm_dims(cfg)
    return MLSTMCache(jnp.zeros((batch, heads, n, pdim + 1), jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM block — sequential scan (hidden-state feedback in the gates)
# ---------------------------------------------------------------------------


class SLSTMCache(NamedTuple):
    c: jax.Array   # (B, d_inner)
    n: jax.Array   # (B, d_inner)
    h: jax.Array   # (B, d_inner)


def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.n_heads
    dh = d_inner // heads
    ks = jax.random.split(key, 4)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_in": dense_init(ks[0], d, 4 * d_inner, dtype),     # z,i,f,o pre-acts
        "r": (jax.random.normal(ks[1], (heads, dh, 4 * dh), jnp.float32)
              / math.sqrt(dh)).astype(dtype),                 # recurrent, per head
        "out_norm": rmsnorm_init(d_inner, dtype),
        "w_out": dense_init(ks[2], d_inner, d, dtype),
    }


def _slstm_cell(p, cfg, pre, state: SLSTMCache):
    """pre: (B, 4*d_inner) input pre-activations for one step."""
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.n_heads
    dh = d_inner // heads
    b = pre.shape[0]
    hh = state.h.reshape(b, heads, dh)
    rec = jnp.einsum("bhd,hdg->bhg", hh.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d_inner)
    zifo = pre.astype(jnp.float32) + rec
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.minimum(i, 10.0))
    f = jax.nn.sigmoid(f + 2.0)
    o = jax.nn.sigmoid(o)
    c = f * state.c + i * z
    n = f * state.n + i
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return h, SLSTMCache(c=c, n=n, h=h)


def slstm_apply(p: Params, x: jax.Array, cfg: ArchConfig,
                cache: Optional[SLSTMCache] = None
                ) -> Tuple[jax.Array, Optional[SLSTMCache]]:
    b, t, _ = x.shape
    d_inner = cfg.ssm_expand * cfg.d_model
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    pre = xn @ p["w_in"]                                   # (B,T,4*d_inner)
    state = cache if cache is not None else SLSTMCache(
        c=jnp.zeros((b, d_inner), jnp.float32),
        n=jnp.zeros((b, d_inner), jnp.float32),
        h=jnp.zeros((b, d_inner), jnp.float32))

    if t == 1:
        h, new_state = _slstm_cell(p, cfg, pre[:, 0], state)
        hs = h[:, None]
    else:
        def step(st, pre_t):
            h, st2 = _slstm_cell(p, cfg, pre_t, st)
            return st2, h
        new_state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)                        # (B,T,d_inner)
    y = rmsnorm(p["out_norm"], hs.astype(x.dtype), cfg.norm_eps)
    out = y @ p["w_out"]
    return out.astype(x.dtype), (new_state if cache is not None else None)


def slstm_cache_init(cfg: ArchConfig, batch: int) -> SLSTMCache:
    d_inner = cfg.ssm_expand * cfg.d_model
    z = jnp.zeros((batch, d_inner), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z)
