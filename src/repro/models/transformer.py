"""LM assembly for all ten architectures — one scan-based code path.

Depth is organized as *super-blocks* so heterogeneous stacks stay inside a
single ``jax.lax.scan`` with stacked params (compile time O(1) in depth):

  dense/moe     : n_layers super-blocks of 1 layer (attn + MLP/MoE)
  vlm           : 1 cross-attn layer + (every-1) self layers per super-block
  hybrid/zamba2 : 1 *shared* attention block (params hoisted out of the
                  scan, per-application KV caches scanned) + every Mamba2
  ssm/xlstm     : (every-1) mLSTM + 1 sLSTM per super-block
  audio/whisper : encoder scan (bidirectional) + decoder scan
                  (self-attn + cross-attn + MLP)

Each super-block body is wrapped in ``jax.checkpoint`` with the config's
remat policy. Caches are stacked pytrees scanned alongside params, so
prefill/decode run the same structure.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm
from .attention import KVCache, attn_apply, attn_init, init_kv_cache
from .config import ArchConfig
from .layers import (Params, dense_init, dtype_of, embed, embed_init, mlp,
                     mlp_init, rmsnorm, rmsnorm_init, unembed)
from .moe import moe_apply, moe_init

REMAT_POLICIES = {
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots_saveable": jax.checkpoint_policies.dots_saveable,
    "dots_with_no_batch_dims_saveable":
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything_saveable": jax.checkpoint_policies.everything_saveable,
}


def _stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# super-block geometry
# ---------------------------------------------------------------------------


def superblock_plan(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_super, layers_per_super) for the main stack."""
    if cfg.family == "vlm":
        assert cfg.n_layers % cfg.cross_attn_every == 0
        return cfg.n_layers // cfg.cross_attn_every, cfg.cross_attn_every
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.shared_attn_every == 0
        return cfg.n_layers // cfg.shared_attn_every, cfg.shared_attn_every
    if cfg.family == "ssm" and cfg.xlstm_slstm_every:
        assert cfg.n_layers % cfg.xlstm_slstm_every == 0
        return cfg.n_layers // cfg.xlstm_slstm_every, cfg.xlstm_slstm_every
    return cfg.n_layers, 1


# ---------------------------------------------------------------------------
# per-family layer init
# ---------------------------------------------------------------------------


def _attn_mlp_layer_init(key, cfg: ArchConfig, dtype, use_moe: bool,
                         cross: bool = False, kv_d: Optional[int] = None) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype),
                 "attn": attn_init(k1, cfg, dtype, kv_d_model=kv_d),
                 "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if use_moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)
    if cross:
        p["ln_cross"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn_init(k4, cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    n_super, per = superblock_plan(cfg)
    fam = cfg.family

    if fam in ("dense", "moe"):
        params["blocks"] = _stacked_init(
            lambda k: _attn_mlp_layer_init(k, cfg, dtype, use_moe=fam == "moe"),
            keys[2], n_super)
    elif fam == "vlm":
        params["cross_blocks"] = _stacked_init(
            lambda k: _attn_mlp_layer_init(k, cfg, dtype, use_moe=False, cross=True),
            keys[2], n_super)
        params["blocks"] = _stacked_init(
            lambda k: _stacked_init(
                lambda k2: _attn_mlp_layer_init(k2, cfg, dtype, use_moe=False),
                k, per - 1),
            keys[3], n_super)
    elif fam == "hybrid":
        params["shared_attn"] = _attn_mlp_layer_init(keys[2], cfg, dtype,
                                                     use_moe=False)
        params["blocks"] = _stacked_init(
            lambda k: _stacked_init(lambda k2: ssm.mamba2_init(k2, cfg, dtype),
                                    k, per),
            keys[3], n_super)
    elif fam == "ssm":
        if cfg.xlstm_slstm_every:
            params["blocks"] = _stacked_init(
                lambda k: _stacked_init(lambda k2: ssm.mlstm_init(k2, cfg, dtype),
                                        k, per - 1),
                keys[2], n_super)
            params["slstm_blocks"] = _stacked_init(
                lambda k: ssm.slstm_init(k, cfg, dtype), keys[3], n_super)
        else:
            params["blocks"] = _stacked_init(
                lambda k: ssm.mlstm_init(k, cfg, dtype), keys[2], n_super)
    elif fam == "audio":
        params["enc_blocks"] = _stacked_init(
            lambda k: _attn_mlp_layer_init(k, cfg, dtype, use_moe=False),
            keys[2], cfg.n_encoder_layers)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        params["blocks"] = _stacked_init(
            lambda k: _attn_mlp_layer_init(k, cfg, dtype, use_moe=False, cross=True),
            keys[3], n_super)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _apply_attn_mlp(pl: Params, x, cfg: ArchConfig, positions, *,
                    use_moe: bool, causal=True, window=None,
                    cache: Optional[KVCache] = None, cache_index=None,
                    cross_kv=None):
    h, new_cache = attn_apply(pl["attn"], rmsnorm(pl["ln1"], x, cfg.norm_eps),
                              cfg, positions=positions, causal=causal,
                              window=window, cache=cache,
                              cache_index=cache_index)
    x = x + h
    if cross_kv is not None:
        hc, _ = attn_apply(pl["cross"], rmsnorm(pl["ln_cross"], x, cfg.norm_eps),
                           cfg, positions=positions, kv_x=cross_kv,
                           causal=False, use_rope=False)
        x = x + hc
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        h2, aux = moe_apply(pl["moe"], rmsnorm(pl["ln2"], x, cfg.norm_eps), cfg)
    else:
        h2 = mlp(pl["mlp"], rmsnorm(pl["ln2"], x, cfg.norm_eps))
    return x + h2, new_cache, aux


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                enc_len: int = 1) -> Dict[str, Any]:
    dtype = dtype_of(cfg.dtype)
    n_super, per = superblock_plan(cfg)
    fam = cfg.family

    def stack(fn, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *([fn()] * n)) if n else None

    caches: Dict[str, Any] = {"index": jnp.zeros((batch,), jnp.int32)}
    if fam in ("dense", "moe"):
        caches["blocks"] = stack(lambda: init_kv_cache(cfg, batch, max_len, dtype),
                                 n_super)
    elif fam == "vlm":
        caches["cross_blocks"] = stack(
            lambda: init_kv_cache(cfg, batch, max_len, dtype), n_super)
        caches["blocks"] = stack(
            lambda: stack(lambda: init_kv_cache(cfg, batch, max_len, dtype),
                          per - 1), n_super)
    elif fam == "hybrid":
        caches["shared_attn"] = stack(
            lambda: init_kv_cache(cfg, batch, max_len, dtype), n_super)
        caches["blocks"] = stack(
            lambda: stack(lambda: ssm.mamba2_cache_init(cfg, batch, dtype), per),
            n_super)
    elif fam == "ssm":
        if cfg.xlstm_slstm_every:
            caches["blocks"] = stack(
                lambda: stack(lambda: ssm.mlstm_cache_init(cfg, batch), per - 1),
                n_super)
            caches["slstm_blocks"] = stack(lambda: ssm.slstm_cache_init(cfg, batch),
                                           n_super)
        else:
            caches["blocks"] = stack(lambda: ssm.mlstm_cache_init(cfg, batch),
                                     n_super)
    elif fam == "audio":
        caches["blocks"] = stack(lambda: init_kv_cache(cfg, batch, max_len, dtype),
                                 n_super)
        caches["enc_out"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
    return caches


# ---------------------------------------------------------------------------
# the stack runner
# ---------------------------------------------------------------------------


class StackOut(NamedTuple):
    x: jax.Array
    caches: Optional[Dict[str, Any]]
    aux: jax.Array


def _run_stack(params: Params, cfg: ArchConfig, x: jax.Array, positions, *,
               caches: Optional[Dict[str, Any]], cache_index,
               cross_kv: Optional[jax.Array]) -> StackOut:
    fam = cfg.family
    n_super, per = superblock_plan(cfg)
    policy = REMAT_POLICIES[cfg.remat_policy]
    use_cache = caches is not None
    window = cfg.window

    def super_body(carry, xs):
        x, aux = carry
        if fam in ("dense", "moe"):
            pl, cache = xs
            x, new_cache, a = _apply_attn_mlp(
                pl, x, cfg, positions, use_moe=fam == "moe", window=window,
                cache=cache, cache_index=cache_index)
            aux += a
            return (x, aux), new_cache
        if fam == "vlm":
            (pc, cc), (pb, cb) = xs
            x, nc, _ = _apply_attn_mlp(pc, x, cfg, positions, use_moe=False,
                                       window=None, cache=cc,
                                       cache_index=cache_index,
                                       cross_kv=cross_kv)
            new_b = []
            for j in range(per - 1):
                plj = jax.tree.map(lambda v: v[j], pb)
                cbj = jax.tree.map(lambda v: v[j], cb) if use_cache else None
                x, ncj, _ = _apply_attn_mlp(plj, x, cfg, positions,
                                            use_moe=False, cache=cbj,
                                            cache_index=cache_index)
                new_b.append(ncj)
            new_b = (jax.tree.map(lambda *vs: jnp.stack(vs), *new_b)
                     if use_cache else None)
            return (x, aux), (nc, new_b)
        if fam == "hybrid":
            (shared_cache,), (pb, cb) = xs[0], xs[1]
            x, nc, _ = _apply_attn_mlp(params["shared_attn"], x, cfg, positions,
                                       use_moe=False, cache=shared_cache,
                                       cache_index=cache_index)
            new_b = []
            for j in range(per):
                plj = jax.tree.map(lambda v: v[j], pb)
                cbj = jax.tree.map(lambda v: v[j], cb) if use_cache else None
                x_delta, ncj = ssm.mamba2_apply(plj, x, cfg, cache=cbj)
                x = x + x_delta
                new_b.append(ncj)
            new_b = (jax.tree.map(lambda *vs: jnp.stack(vs), *new_b)
                     if use_cache else None)
            return (x, aux), (nc, new_b)
        if fam == "ssm":
            if cfg.xlstm_slstm_every:
                (pb, cb), (ps, cs) = xs
                new_b = []
                for j in range(per - 1):
                    plj = jax.tree.map(lambda v: v[j], pb)
                    cbj = jax.tree.map(lambda v: v[j], cb) if use_cache else None
                    dx, ncj = ssm.mlstm_apply(plj, x, cfg, cache=cbj)
                    x = x + dx
                    new_b.append(ncj)
                dx, ncs = ssm.slstm_apply(ps, x, cfg, cache=cs)
                x = x + dx
                new_b = (jax.tree.map(lambda *vs: jnp.stack(vs), *new_b)
                         if use_cache else None)
                return (x, aux), (new_b, ncs)
            pl, cache = xs
            dx, nc = ssm.mlstm_apply(pl, x, cfg, cache=cache)
            return (x + dx, aux), nc
        if fam == "audio":
            pl, cache = xs
            x, nc, _ = _apply_attn_mlp(pl, x, cfg, positions, use_moe=False,
                                       cache=cache, cache_index=cache_index,
                                       cross_kv=cross_kv)
            return (x, aux), nc
        raise ValueError(fam)

    # assemble scan xs per family
    def none_like(tree):  # cache placeholder when not serving
        return None

    if fam in ("dense", "moe", "audio"):
        xs = (params["blocks"], caches["blocks"] if use_cache else None)
    elif fam == "vlm":
        xs = ((params["cross_blocks"],
               caches["cross_blocks"] if use_cache else None),
              (params["blocks"], caches["blocks"] if use_cache else None))
    elif fam == "hybrid":
        xs = ((caches["shared_attn"] if use_cache else None,),
              (params["blocks"], caches["blocks"] if use_cache else None))
    elif fam == "ssm" and cfg.xlstm_slstm_every:
        xs = ((params["blocks"], caches["blocks"] if use_cache else None),
              (params["slstm_blocks"],
               caches["slstm_blocks"] if use_cache else None))
    else:
        xs = (params["blocks"], caches["blocks"] if use_cache else None)

    body = jax.checkpoint(super_body, policy=policy, prevent_cse=False)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)

    out_caches = None
    if use_cache:
        out_caches = dict(caches)
        if fam == "vlm":
            out_caches["cross_blocks"], out_caches["blocks"] = new_caches
        elif fam == "hybrid":
            out_caches["shared_attn"], out_caches["blocks"] = new_caches
        elif fam == "ssm" and cfg.xlstm_slstm_every:
            out_caches["blocks"], out_caches["slstm_blocks"] = new_caches
        else:
            out_caches["blocks"] = new_caches
    return StackOut(x=x, caches=out_caches, aux=aux)


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def _encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, D) stub frontend embeddings -> encoder states."""
    x = frames
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    policy = REMAT_POLICIES[cfg.remat_policy]

    def body(carry, pl):
        x, = carry
        x, _, _ = _apply_attn_mlp(pl, x, cfg, positions, use_moe=False,
                                  causal=False)
        return (x,), None

    (x,), _ = jax.lax.scan(jax.checkpoint(body, policy=policy, prevent_cse=False),
                           (x,), params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _backbone(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
              image_embeds: Optional[jax.Array] = None,
              encoder_frames: Optional[jax.Array] = None,
              caches: Optional[Dict[str, Any]] = None,
              cache_index: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """tokens (B, T) -> (hidden (B, T, D) post final-norm, caches', aux)."""
    x = embed(params["embed"], tokens)
    if positions is None:
        base = cache_index if cache_index is not None else 0
        if jnp.ndim(base) == 1:     # per-slot decode positions
            positions = base[:, None] + jnp.arange(tokens.shape[1])[None, :]
        else:
            positions = base + jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                                tokens.shape)
    cross_kv = None
    if cfg.family == "vlm":
        assert image_embeds is not None, "vlm needs image_embeds (stub frontend)"
        cross_kv = image_embeds
    if cfg.family == "audio":
        if caches is not None and encoder_frames is None:
            cross_kv = caches["enc_out"]
        else:
            assert encoder_frames is not None, "audio needs encoder_frames (stub)"
            cross_kv = _encode(params, cfg, encoder_frames)
            if caches is not None:
                caches = dict(caches)
                caches["enc_out"] = cross_kv

    out = _run_stack(params, cfg, x, positions, caches=caches,
                     cache_index=cache_index, cross_kv=cross_kv)
    h = rmsnorm(params["final_norm"], out.x, cfg.norm_eps)
    new_caches = out.caches
    if new_caches is not None and cache_index is not None:
        new_caches = dict(new_caches)
        nxt = cache_index + tokens.shape[1]
        if jnp.ndim(nxt) == 0:
            nxt = jnp.full((tokens.shape[0],), nxt, jnp.int32)
        new_caches["index"] = nxt
    return h, new_caches, out.aux


def forward(params: Params, cfg: ArchConfig, tokens: jax.Array, *,
            last_logits_only: bool = False, **kw
            ) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """tokens (B, T) -> (logits, caches', aux_loss)."""
    h, new_caches, aux = _backbone(params, cfg, tokens, **kw)
    if last_logits_only:
        h = h[:, -1:]
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, h, tied=cfg.tie_embeddings)
    return logits, new_caches, aux


CE_CHUNK = 1024


def _chunked_ce(h: jax.Array, table: jax.Array, tied: bool,
                labels: jax.Array) -> jax.Array:
    """Cross-entropy without materializing (B, T, V) logits.

    The final projection dominates activation memory at large vocab
    (151k vocab × 4k seq would be GBs of f32 per device); scanning
    CE over sequence chunks keeps one (B, chunk, V) tile alive and the
    chunk body under jax.checkpoint recomputes it in the backward pass.
    """
    b, t, d = h.shape
    c = min(CE_CHUNK, t)
    if t % c:
        pad = c - t % c
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        t = t + pad
    nc = t // c
    hs = jnp.moveaxis(h.reshape(b, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    def body(carry, inp):
        h_c, l_c = inp
        logits = unembed(table, h_c, tied=tied)          # (B, c, V) f32
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(l_c, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (l_c >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum(nll * mask), carry[1] + jnp.sum(mask)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, _, aux = _backbone(params, cfg, batch["tokens"],
                          image_embeds=batch.get("image_embeds"),
                          encoder_frames=batch.get("encoder_frames"))
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    loss = _chunked_ce(h, table, cfg.tie_embeddings, batch["labels"])
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


def prefill(params, cfg, tokens, caches, *, last_logits_only: bool = False, **kw):
    return forward(params, cfg, tokens, caches=caches,
                   cache_index=jnp.zeros((), jnp.int32),
                   last_logits_only=last_logits_only, **kw)


def decode_step(params, cfg, token, caches, **kw):
    """token: (B, 1); caches carry their own index."""
    return forward(params, cfg, token, caches=caches,
                   cache_index=caches["index"], **kw)


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params: Params, cfg: ArchConfig) -> int:
    """MoE: only top_k/n_experts of expert params are active per token."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        if cfg.n_experts and ("w_gate" in keys or "w_up" in keys or
                              "w_down" in keys) and "moe" in keys:
            total += leaf.size * cfg.top_k // cfg.n_experts
        else:
            total += leaf.size
    return total
