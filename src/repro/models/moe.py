"""Mixture-of-Experts with gather-based dispatch (GShard-style, GSPMD-native).

Routing groups are sequences: each (batch-row, T tokens) group routes its
tokens independently, so the dispatch tables are (B, E, C) with
C = T·top_k/E·capacity_factor — every tensor keeps the batch dim leading
and shards over `data`, the expert dim shards over `model` (expert
parallelism). Dispatch/combine are gathers (token table → expert slots and
back), not one-hot matmuls: nothing O(T·E·C) is materialized, and under
GSPMD the expert-sharded compute + model-axis reduction for the combine
fall out of the shardings.

Dropping semantics: per-(group, expert) overflow beyond C drops (standard
capacity-factor behaviour); with the default cf=1.25 and load-balance loss
drops are rare. Top-k gate weights are renormalized over the kept experts.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, dense_init


def moe_capacity(cfg: ArchConfig, t: int) -> int:
    c = int(math.ceil(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(4, -(-c // 4) * 4) if t > 1 else max(1, c)


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    k0, k1, k2, k3 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "w_router": dense_init(k0, d, e, jnp.float32),
        "w_gate": (jax.random.normal(k1, (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(k2, (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(k3, (e, f, d), jnp.float32) / math.sqrt(f)).astype(dtype),
    }


def moe_apply(p: Params, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out (B, T, D), aux load-balance loss ())."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = moe_capacity(cfg, t)

    logits = (x.astype(jnp.float32) @ p["w_router"])            # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                  # (B,T,k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Shazeer load-balance aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(
        (jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # --- dispatch + gathers run batch-manually (shard_map over data axes):
    # GSPMD cannot partition batched gathers and would otherwise gather at
    # GLOBAL batch on every device (measured: 16-32x flops on this layer).
    from ..dist.sharding import constrain, shard_map_batch

    def build_tables(gate_idx_l):
        bl = gate_idx_l.shape[0]
        ef_l = gate_idx_l.reshape(bl, t * k)                    # (B, T*k)
        oh = jax.nn.one_hot(ef_l, e, dtype=jnp.int32)           # (B, T*k, E)
        pos_l = (jnp.cumsum(oh, axis=1) * oh).sum(-1) - 1       # 0-based
        keep_l = pos_l < c
        token_of_slot = jnp.tile(jnp.repeat(jnp.arange(t), k)[None], (bl, 1))
        flat_target = jnp.where(keep_l, ef_l * c + pos_l, e * c)
        sel_l = jnp.full((bl, e * c + 1), t, dtype=jnp.int32)   # sentinel tok
        sel_l = jax.vmap(lambda s, tgt, tok: s.at[tgt].set(tok, mode="drop"))(
            sel_l, flat_target, token_of_slot.astype(jnp.int32))
        return (sel_l[:, : e * c].reshape(bl, e, c), pos_l,
                keep_l.astype(jnp.int8))

    sel, pos, keep8 = shard_map_batch(build_tables, gate_idx)
    keep = keep8.astype(bool)
    ef = gate_idx.reshape(b, t * k)

    xp = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)  # pad row
    expert_in = shard_map_batch(
        lambda xp_l, sel_l: jax.vmap(lambda xb, sb: xb[sb])(xp_l, sel_l),
        xp, sel)                                                # (B,E,C,D)
    # expert tensors: batch over data AND experts over model (EP) — or, when
    # E doesn't divide the model axis (mixtral 8e/16), the expert FFN width
    # takes the model axis instead (first-divisible-wins in `constrain`)
    expert_in = constrain(expert_in, ["batch", "model", None, None])

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"])) * \
        jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    h = constrain(h, ["batch", "model", None, "model"])  # pins bwd d(h) too
    out_e = constrain(jnp.einsum("becf,efd->becd", h, p["w_down"]),
                      ["batch", "model", None, None])

    # --- combine: gather each token's k slots back, weight, sum ---
    slot_e = jnp.clip(ef, 0, e - 1)
    slot_c = jnp.clip(pos, 0, c - 1)
    per_slot = shard_map_batch(
        lambda oe, ee, cc: jax.vmap(lambda ob, eb, cb: ob[eb, cb])(oe, ee, cc),
        out_e, slot_e.astype(jnp.int32), slot_c.astype(jnp.int32))
    per_slot = per_slot * (keep[..., None] * gate_w.reshape(b, t * k)[..., None]
                           ).astype(per_slot.dtype)             # (B,T*k,D)
    out = per_slot.reshape(b, t, k, d).sum(axis=2)
    return out.astype(x.dtype), aux
