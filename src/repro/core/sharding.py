"""Sharded logical store: route tensors across N independent delta tables.

The paper's store commits every write through ONE delta log, so the
put-if-absent commit race on ``_delta_log/<version>.json`` is the
scalability wall under many concurrent writers: all of them serialize on a
single optimistic-append domain. Deep Lake scales its lakehouse by
partitioning tensor data across independent chunked objects; NeurStore
gives each tenant an isolated write domain. This module brings that model
here: one *logical* store is backed by ``N`` shard tables, each with its
own ``_delta_log`` — commits on different shards never race each other.

* :class:`ShardRouter` — a **stable** hash of ``tensor_id`` picks the shard.
  Stability matters twice: across processes (``hash()`` is salted per
  interpreter, so it would scatter a tensor's reads away from its writes)
  and across time (N is fixed at store-create time, recorded in the store
  manifest, and never changes — resharding would need a rewrite).
* :func:`load_or_init_manifest` — the tiny JSON manifest at
  ``<root>/_store_manifest.json`` records the shard count and router algo.
  A 1-shard store writes **no manifest** and keeps its table at ``<root>``
  itself, byte-for-byte the pre-sharding layout, so every existing table
  opens unchanged and old clients can read what a ``shards=1`` client
  writes. Manifest creation is put-if-absent: two clients racing to create
  the same sharded store converge on one manifest.

A logical snapshot of a sharded store is a **version vector** — one delta
version per shard, e.g. ``(3, 5, 4, 4)`` for 4 shards. Shard commits are
independent, so there is no single total order across shards; pinning a
vector is the cross-shard consistency primitive (see ``Catalog``).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

from ..lake.object_store import (ObjectNotFoundError, ObjectStore,
                                 PutIfAbsentError)

MANIFEST_NAME = "_store_manifest.json"
ROUTER_ALGO = "blake2b64"
MANIFEST_FORMAT = 1

# a store.version() / catalog.version for a sharded store: one entry per shard
VersionVector = Tuple[int, ...]


def manifest_key(root: str) -> str:
    """Object key of the store manifest under ``root``."""
    return f"{root.rstrip('/')}/{MANIFEST_NAME}"


def shard_table_path(root: str, shard: int) -> str:
    """Shard tables live under the logical root, one directory per shard."""
    return f"{root.rstrip('/')}/shard-{shard:05d}"


@dataclass(frozen=True)
class ShardRouter:
    """Stable ``tensor_id -> shard`` mapping, fixed at store-create time."""

    shards: int
    algo: str = ROUTER_ALGO

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.algo != ROUTER_ALGO:
            raise ValueError(f"unknown shard router algo {self.algo!r} "
                             f"(this client supports {ROUTER_ALGO!r})")

    def shard_of(self, tensor_id: str) -> int:
        """Shard index for ``tensor_id`` (stable across processes)."""
        if self.shards == 1:
            return 0
        digest = hashlib.blake2b(tensor_id.encode("utf-8"),
                                 digest_size=8).digest()
        return int.from_bytes(digest, "big") % self.shards


def load_manifest(store: ObjectStore, root: str) -> Optional[dict]:
    """The store manifest, or None for an unsharded (pre-existing) table."""
    try:
        return json.loads(store.get(manifest_key(root)))
    except ObjectNotFoundError:
        return None


def load_or_init_manifest(store: ObjectStore, root: str,
                          shards: Optional[int],
                          retention: Optional[dict] = None,
                          compression: Optional[str] = None) -> dict:
    """Resolve the store's shard layout, creating the manifest if needed.

    ``shards=None`` means "whatever the store already is" (1 when nothing
    exists yet). An explicit ``shards`` that contradicts an existing
    manifest is a hard error — N is immutable for the life of the store.

    ``retention`` (e.g. ``{"keep_versions": 3, "ttl_s": None}``) and
    ``compression`` (a chunk-blob codec spec like ``"zlib+shuffle"``) are
    recorded at create time so every client — including the
    ``repro.launch.gc`` maintenance CLI — agrees on the store's default
    vacuum policy and codec without out-of-band configuration.

    Unsharded stores normally write **no manifest** (byte-compat with
    pre-sharding tables) and keep their defaults client-side; creating a
    *fresh* unsharded store with an explicit ``compression`` is the one
    exception — the default is worth recording, and an extra
    ``_store_manifest.json`` beside a table changes no table bytes. An
    existing manifest is never rewritten: ctor arguments that differ from
    it act as client-side overrides, opening a store stays read-only.
    """
    existing = load_manifest(store, root)
    if existing is not None:
        found = int(existing["shards"])
        if shards is not None and int(shards) != found:
            raise ValueError(
                f"store at {root!r} has {found} shards; cannot open with "
                f"shards={shards} (shard count is fixed at create time)")
        return existing
    root = root.rstrip("/")
    if shards is None or int(shards) == 1:
        manifest = {"shards": 1, "router": ROUTER_ALGO,
                    "format": MANIFEST_FORMAT}
        if compression is None or compression == "none":
            # unsharded layout: table at <root>, no manifest written —
            # byte-compatible with pre-sharding tables
            return manifest
        if next(iter(store.list(f"{root}/_delta_log/")), None) is not None:
            # opening an existing table must not mutate it: the ctor's
            # compression acts as a client-side default only
            return manifest
        manifest["compression"] = compression
        if retention is not None:
            manifest["retention"] = dict(retention)
        return _put_manifest(store, root, manifest,
                             shards=1, retention=retention,
                             compression=compression)
    # creating a sharded store where an unsharded table already lives would
    # shadow its data forever (reads would resolve to empty shard tables)
    if next(iter(store.list(f"{root}/_delta_log/")), None) is not None:
        raise ValueError(
            f"an unsharded table already exists at {root!r}; cannot create "
            f"a {shards}-shard store over it (shard count is fixed at "
            f"create time)")
    manifest = {"shards": int(shards), "router": ROUTER_ALGO,
                "format": MANIFEST_FORMAT}
    if retention is not None:
        manifest["retention"] = dict(retention)
    if compression is not None and compression != "none":
        manifest["compression"] = compression
    return _put_manifest(store, root, manifest, shards=shards,
                         retention=retention, compression=compression)


def _put_manifest(store: ObjectStore, root: str, manifest: dict, *,
                  shards: Optional[int], retention: Optional[dict],
                  compression: Optional[str]) -> dict:
    """Create-once manifest write; a lost race defers to the winner."""
    body = json.dumps(manifest, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")
    try:
        store.put(manifest_key(root), body, if_absent=True)
    except PutIfAbsentError:
        # lost the create race: the winner's manifest is authoritative
        return load_or_init_manifest(store, root, shards,
                                     retention=retention,
                                     compression=compression)
    return manifest


def resolve_version_vector(shards: int,
                           version: Union[None, int, Sequence[int]],
                           ) -> Tuple[Optional[int], ...]:
    """Normalize a user-facing ``version=`` argument to one entry per shard.

    ``None`` entries mean "latest" for that shard. A bare int is accepted
    only on 1-shard stores (the pre-sharding API); sharded stores must pin
    a full vector — a single int is ambiguous across independent logs.
    """
    if version is None:
        return (None,) * shards
    if isinstance(version, (int,)) and not isinstance(version, bool):
        if shards != 1:
            raise TypeError(
                f"sharded store needs a {shards}-entry version vector, "
                f"got bare int {version}")
        return (int(version),)
    vv = tuple(None if v is None else int(v) for v in version)
    if len(vv) != shards:
        raise ValueError(
            f"version vector has {len(vv)} entries for {shards} shards")
    return vv
