"""The paper's primary contribution: tensor storage in a delta table.

Five codecs (FTSF, COO, CSR/CSC, CSF, BSGS), the 10% sparsity policy, the
DeltaTensorStore facade with its handle API (Catalog / TensorRef /
WriteBatch), and device-side (jit) encodings for in-training use.
"""
from .encodings.base import Codec, SparseCOO, get_codec, normalize_slices
from .encodings import ftsf, coo, csr, csf, bsgs  # noqa: F401 (register codecs)
from .sparsity import SPARSE_THRESHOLD, choose_layout, density
from .cas import ChunkEntry, ChunkIndex, chunk_hash, chunk_index_for
from .catalog import (Catalog, ShardSource, TensorEntry, TensorRef,
                      build_catalog_index)
from .batch import BatchClosedError, WriteBatch
from .leases import Lease, LeaseRegistry, RetentionPolicy, registry_for
from .sharding import ShardRouter, VersionVector, load_manifest
from .store import DeltaTensorStore

__all__ = ["Codec", "SparseCOO", "get_codec", "normalize_slices",
           "SPARSE_THRESHOLD", "choose_layout", "density", "DeltaTensorStore",
           "Catalog", "TensorEntry", "TensorRef", "WriteBatch",
           "BatchClosedError", "ShardRouter", "VersionVector",
           "load_manifest", "Lease", "LeaseRegistry", "RetentionPolicy",
           "registry_for", "ShardSource", "build_catalog_index",
           "ChunkEntry", "ChunkIndex", "chunk_hash", "chunk_index_for"]
