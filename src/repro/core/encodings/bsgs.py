"""BSGS — Block Sparse Generic Storage (paper §IV.F).

Mode-Generic/BCSR generalized: partition the tensor on a block grid, keep
only non-zero blocks as (block coordinates, flattened dense block). One
table row per non-zero block; per-dimension block-coordinate columns give
min/max stats for slice pruning ("partitioning before encoding" — the slice
can be served without decoding the whole tensor). Metadata columns
(dense_shape, block_shape, dtype) repeat per row and collapse under
columnar dictionary/RLE encoding, the paper's Fig. 9 "value, 4" notation.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from .base import (Codec, RowGroup, SliceSpec, SparseCOO, as_coo,
                   header_shape, make_header, normalize_slices, register,
                   slice_shape, split_groups)


def _norm_block_shape(shape: Tuple[int, ...], block_shape) -> Tuple[int, ...]:
    if block_shape is None:
        # heuristic default: cover trailing dims up to ~512 elements
        bs = [1] * len(shape)
        prod = 1
        for d in range(len(shape) - 1, -1, -1):
            take = min(shape[d], max(1, 512 // prod))
            bs[d] = take
            prod *= take
            if prod >= 512:
                break
        return tuple(bs)
    block_shape = tuple(int(b) for b in block_shape)
    if len(block_shape) < len(shape):  # pad leading 1s (paper's 1x2 on a 3x4x2)
        block_shape = (1,) * (len(shape) - len(block_shape)) + block_shape
    if len(block_shape) != len(shape):
        raise ValueError(f"block shape {block_shape} vs tensor rank {len(shape)}")
    return tuple(min(b, s) for b, s in zip(block_shape, shape))


class BSGSCodec(Codec):
    """Block-Sparse Grid Storage (paper §IV.E)."""

    layout = "bsgs"
    supports_slice = True
    supports_coo = False      # decode_coo here is a dense round-trip, not native

    def encode(self, tensor: Any, *, block_shape=None, **_) -> List[RowGroup]:
        """Tensor -> row groups (header + chunk rows)."""
        t = as_coo(tensor)
        shape = t.shape
        bs = _norm_block_shape(shape, block_shape)
        grid = tuple(-(-s // b) for s, b in zip(shape, bs))
        block_elems = int(np.prod(bs))
        ndim = t.ndim

        if t.nnz:
            bidx = t.indices // np.asarray(bs, dtype=t.indices.dtype)
            off = t.indices % np.asarray(bs, dtype=t.indices.dtype)
            bkey = np.ravel_multi_index([bidx[:, d] for d in range(ndim)], grid)
            okey = np.ravel_multi_index([off[:, d] for d in range(ndim)], bs)
            order = np.argsort(bkey, kind="stable")
            bkey, okey, vals = bkey[order], okey[order], t.values[order]
            ukeys, inverse = np.unique(bkey, return_inverse=True)
            buf = np.zeros((len(ukeys), block_elems), dtype=t.values.dtype)
            buf[inverse, okey] = vals
            ucoords = np.stack(np.unravel_index(ukeys, grid), axis=1)
        else:
            ukeys = np.zeros(0, np.int64)
            buf = np.zeros((0, block_elems), dtype=t.values.dtype)
            ucoords = np.zeros((0, ndim), np.int64)

        n_blocks = len(ukeys)
        cols: Dict[str, Any] = {
            "block_key": ukeys.astype(np.int64) if n_blocks else np.asarray([-1], np.int64),
            "values": (list(buf) if n_blocks
                       else [np.zeros(0, t.values.dtype)]),
            "dense_shape": [np.asarray(shape, np.int64)] * max(n_blocks, 1),
            "block_shape": [np.asarray(bs, np.int64)] * max(n_blocks, 1),
            "dtype": [str(t.values.dtype)] * max(n_blocks, 1),
        }
        for d in range(ndim):
            cols[f"bidx{d}"] = (ucoords[:, d].astype(np.int64)
                                if n_blocks else np.zeros(1, np.int64))
        skip = tuple(f"bidx{d}" for d in range(ndim))
        header = make_header(shape, t.values.dtype,
                             block_shape=np.asarray(bs, np.int64))
        return [header, RowGroup(kind="chunk", columns=cols, skip_columns=skip)]

    # -- decode -----------------------------------------------------------------

    @staticmethod
    def _meta(groups: List[Dict[str, Any]]):
        header, chunks = split_groups(groups)
        from .base import header_dtype
        shape = header_shape(header)
        bs = tuple(int(x) for x in header["block_shape"][0])
        return shape, bs, header_dtype(header), chunks

    def _scatter(self, groups: List[Dict[str, Any]], region: SliceSpec) -> np.ndarray:
        """Scatter blocks intersecting ``region`` into a padded buffer, crop.

        Vectorized: one row-scatter into a (n_env_blocks, block_elems)
        matrix, then a transpose back to the interleaved dense layout.
        """
        shape, bs, dtype, groups = self._meta(groups)
        ndim = len(shape)
        block_elems = int(np.prod(bs))
        # block-aligned envelope of the region
        blo = np.asarray([region[d][0] // bs[d] for d in range(ndim)])
        bhi = np.asarray([max(blo[d] + 1, -(-region[d][1] // bs[d]))
                          for d in range(ndim)])
        env_blocks = tuple(int(x) for x in (bhi - blo))
        n_env = int(np.prod(env_blocks))

        kept = []   # (coords, flat values) across batches
        for g in groups:
            keys = np.asarray(g["block_key"])
            coords = np.stack([np.asarray(g[f"bidx{d}"]) for d in range(ndim)],
                              axis=1)
            keep = (keys >= 0) & np.all((coords >= blo) & (coords < bhi), axis=1)
            for i in np.flatnonzero(keep):
                kept.append((coords[i], g["values"][i]))

        out_shape = tuple(region[d][1] - region[d][0] for d in range(ndim))
        if len(kept) < 4096 or len(kept) * block_elems > 4 * n_env:
            # few/large blocks (time-major layouts): place each block
            # directly — no padded intermediate, no giant transpose
            out = np.zeros(out_shape, dtype=dtype)
            r0 = [region[d][0] for d in range(ndim)]
            for c, v in kept:
                block = np.asarray(v).reshape(bs).astype(dtype, copy=False)
                src, dst = [], []
                ok = True
                for d in range(ndim):
                    lo_abs = int(c[d]) * bs[d]
                    a = max(lo_abs, region[d][0])
                    z = min(lo_abs + bs[d], region[d][1])
                    if z <= a:
                        ok = False
                        break
                    src.append(slice(a - lo_abs, z - lo_abs))
                    dst.append(slice(a - r0[d], z - r0[d]))
                if ok:
                    out[tuple(dst)] = block[tuple(src)]
            return out

        # many small blocks: one vectorized row scatter + layout transpose
        buf2 = np.zeros((n_env, block_elems), dtype=dtype)
        if kept:
            coords = np.stack([c for c, _ in kept])
            rows = np.ravel_multi_index((coords - blo).T, env_blocks)
            stacked = np.concatenate(
                [np.asarray(v).reshape(-1) for _, v in kept]).reshape(
                len(kept), block_elems)
            buf2[rows] = stacked.astype(dtype, copy=False)
        full = buf2.reshape(tuple(env_blocks) + tuple(bs))
        perm = [x for d in range(ndim) for x in (d, ndim + d)]
        buf = full.transpose(perm).reshape(
            tuple(env_blocks[d] * bs[d] for d in range(ndim)))
        crop = tuple(slice(region[d][0] - int(blo[d]) * bs[d],
                           region[d][1] - int(blo[d]) * bs[d])
                     for d in range(ndim))
        return buf[crop]

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        shape, _, _, _ = self._meta(groups)
        return self._scatter(groups, tuple((0, s) for s in shape))

    def decode_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        """Decoded row groups -> :class:`SparseCOO` (no densify)."""
        return SparseCOO.from_dense(self.decode(groups))

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec):
        """Pushdown predicate selecting chunk rows for ``spec``."""
        shape = header_shape(header)
        bs = tuple(int(x) for x in header["block_shape"][0])
        out = {}
        for d, (lo, hi) in enumerate(spec):
            if (lo, hi) != (0, shape[d]):
                out[f"bidx{d}"] = (lo // bs[d], (hi - 1) // bs[d])
        return out

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from pruned groups."""
        shape, _, _, _ = self._meta(groups)
        spec = normalize_slices(shape, spec)
        out = self._scatter(groups, spec)
        assert out.shape == slice_shape(spec)
        return out


register(BSGSCodec())
