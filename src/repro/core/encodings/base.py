"""Shared codec machinery for the five paper formats.

A codec maps a tensor to/from *row groups*: a list of ``(columns, meta)``
pairs, where ``columns`` is a parq-lite column dict and ``meta`` tags the
group kind ("header" / "chunk"). The store persists each group as one or
more delta-table files so data skipping works at file granularity.

Slice specs follow the paper's Eq. (2): fix ranges on a prefix of the
dimensions, take everything in the rest. We normalize to a full-rank tuple
of ``(start, stop)`` pairs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import ml_dtypes  # noqa: F401 — registers bfloat16 et al. with numpy
import numpy as np

SliceSpec = Tuple[Tuple[int, int], ...]


@dataclass
class SparseCOO:
    """COO carrier: what torch.sparse_coo_tensor is to the paper."""

    indices: np.ndarray  # (nnz, ndim) integer coordinates
    values: np.ndarray   # (nnz,)
    shape: Tuple[int, ...]

    @property
    def nnz(self) -> int:
        """Number of stored (non-zero) entries."""
        return len(self.values)

    @property
    def ndim(self) -> int:
        """Tensor rank."""
        return len(self.shape)

    @property
    def density(self) -> float:
        """nnz / total elements (0.0 for zero-size shapes)."""
        total = int(np.prod(self.shape))
        return self.nnz / total if total else 0.0

    @classmethod
    def from_dense(cls, x: np.ndarray) -> "SparseCOO":
        """Extract the non-zero pattern of a dense array."""
        idx = np.argwhere(x != 0)
        return cls(indices=idx.astype(np.int64),
                   values=x[tuple(idx.T)] if len(idx) else x.ravel()[:0],
                   shape=tuple(x.shape))

    def to_dense(self) -> np.ndarray:
        """Materialize the dense array (zeros where no entry)."""
        out = np.zeros(self.shape, dtype=self.values.dtype)
        if self.nnz:
            out[tuple(self.indices.T)] = self.values
        return out

    def sorted(self) -> "SparseCOO":
        """Entries re-ordered lexicographically, dim0 major."""
        if self.nnz == 0:
            return self
        order = np.lexsort(self.indices.T[::-1])  # dim0 major
        return SparseCOO(self.indices[order], self.values[order], self.shape)

    def slice(self, spec: SliceSpec) -> "SparseCOO":
        """Entries inside ``spec``, re-based to the slice's origin."""
        mask = np.ones(self.nnz, dtype=bool)
        for d, (lo, hi) in enumerate(spec):
            mask &= (self.indices[:, d] >= lo) & (self.indices[:, d] < hi)
        new_shape = tuple(hi - lo for lo, hi in spec)
        idx = self.indices[mask] - np.asarray([lo for lo, _ in spec], dtype=self.indices.dtype)
        return SparseCOO(idx, self.values[mask], new_shape)


def normalize_slices(shape: Sequence[int],
                     slices: Optional[Sequence[Optional[Tuple[int, int]]]]) -> SliceSpec:
    """Pad a leading-dims slice spec to full rank, clip to bounds."""
    shape = tuple(int(s) for s in shape)
    slices = list(slices or [])
    if len(slices) > len(shape):
        raise ValueError(f"slice rank {len(slices)} > tensor rank {len(shape)}")
    out: List[Tuple[int, int]] = []
    for d, dim in enumerate(shape):
        sl = slices[d] if d < len(slices) else None
        if sl is None:
            out.append((0, dim))
        else:
            lo, hi = sl
            lo = max(0, lo + dim if lo < 0 else lo)
            hi = min(dim, hi + dim if hi < 0 else hi)
            if hi < lo:
                hi = lo
            out.append((lo, hi))
    return tuple(out)


def slice_shape(spec: SliceSpec) -> Tuple[int, ...]:
    """Output shape of a normalized slice spec."""
    return tuple(hi - lo for lo, hi in spec)


@dataclass
class RowGroup:
    """One encoded unit a codec emits: a kind tag + parq-lite columns."""

    kind: str                 # "header" | "chunk"
    columns: Dict[str, Any]   # parq-lite column dict
    # numeric columns usable for file pruning on slice reads
    skip_columns: Tuple[str, ...] = ()


def make_header(shape: Sequence[int], dtype, **extra: Any) -> RowGroup:
    """Uniform 1-row header group each codec emits alongside its chunks.

    Tiny (one RTT to fetch), and it's what slice pushdown reads before any
    chunk file is touched. CSF extends it with fid0/fptr0/fid1/fptr1 per the
    paper's non-chunked data.
    """
    cols: Dict[str, Any] = {
        "__header__": np.asarray([1], dtype=np.int8),
        "dense_shape": [np.asarray(shape, dtype=np.int64)],
        "dtype": [str(np.dtype(dtype))],
    }
    for k, v in extra.items():
        if isinstance(v, np.ndarray):
            cols[k] = [v]
        elif isinstance(v, (list, tuple)):
            cols[k] = [np.asarray(v)]
        elif isinstance(v, str):
            cols[k] = [v]
        else:
            cols[k] = np.asarray([v])
    return RowGroup(kind="header", columns=cols)


def is_header(group: Dict[str, Any]) -> bool:
    """Whether a decoded row group is a tensor header."""
    return "__header__" in group


def split_groups(groups: List[Dict[str, Any]]):
    """(header, chunk_groups); raises ``ValueError`` with no header."""
    headers = [g for g in groups if is_header(g)]
    chunks = [g for g in groups if not is_header(g)]
    if not headers:
        raise ValueError("no header group present")
    return headers[0], chunks


def header_shape(header: Dict[str, Any]) -> Tuple[int, ...]:
    """Dense shape recorded in a header group."""
    return tuple(int(x) for x in header["dense_shape"][0])


def header_dtype(header: Dict[str, Any]) -> np.dtype:
    """Element dtype recorded in a header group."""
    return np.dtype(first_scalar(header["dtype"]))


class Codec:
    """Interface implemented by the five formats.

    Capability flags let callers (``TensorRef``) reject an unsupported
    operation before any chunk bytes are fetched, instead of failing deep
    inside decode:

    * ``supports_slice`` — the codec implements :meth:`decode_slice` (and
      usually :meth:`slice_filters` pushdown);
    * ``supports_coo`` — the codec decodes natively to :class:`SparseCOO`
      via ``decode_coo`` without materializing the dense tensor first.
    """

    layout: str = "?"
    supports_slice: bool = False
    supports_coo: bool = False

    def encode(self, tensor: Any, **params) -> List[RowGroup]:
        """Tensor -> row groups (header first, then chunk groups)."""
        raise NotImplementedError

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        raise NotImplementedError

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec) -> Dict[str, Tuple[int, int]]:
        """Pushdown predicate {column: (lo, hi)} selecting needed chunk rows."""
        return {}

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from (pruned) row groups."""
        raise NotImplementedError

    def decode_device(self, groups: List[Dict[str, Any]],
                      spec: Optional[SliceSpec] = None, *,
                      use_pallas: Optional[bool] = None):
        """Decode onto an accelerator device: ``(array, DeviceReadInfo)``.

        The base implementation is the documented fallback — host decode
        followed by one transfer (or no transfer at all when jax is absent
        or the dtype cannot be held bit-exactly; see
        :mod:`repro.lake.device`). FTSF and COO override this with true
        device assembly that never materializes an ordered full host
        tensor.
        """
        from ...lake import device as lake_device
        arr = self.decode(groups) if spec is None else self.decode_slice(
            groups, spec)
        out = lake_device.to_device(arr)
        info = lake_device.DeviceReadInfo(
            path="host_fallback", host_staged_bytes=int(arr.nbytes),
            device_bytes=int(arr.nbytes),
            on_device=lake_device.is_device_array(out))
        return out, info


def as_dense(tensor: Any) -> np.ndarray:
    """Coerce ndarray-or-SparseCOO to a dense ndarray."""
    return tensor.to_dense() if isinstance(tensor, SparseCOO) else np.asarray(tensor)


def as_coo(tensor: Any) -> SparseCOO:
    """Coerce ndarray-or-SparseCOO to :class:`SparseCOO`."""
    return tensor if isinstance(tensor, SparseCOO) else SparseCOO.from_dense(np.asarray(tensor))


def first_scalar(col: Any) -> Any:
    """First row of a column as a python scalar."""
    v = col[0]
    return v.item() if hasattr(v, "item") else v


_CODECS: Dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    """Register a layout codec under its ``layout`` name; returns it."""
    _CODECS[codec.layout] = codec
    return codec


def get_codec(layout: str) -> Codec:
    """The codec for ``layout``; raises ``KeyError`` listing known ones."""
    if layout not in _CODECS:
        raise KeyError(f"unknown layout {layout!r}; have {sorted(_CODECS)}")
    return _CODECS[layout]
