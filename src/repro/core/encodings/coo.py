"""COO — Coordinate encoding (paper §IV.C).

One logical row per non-zero: coordinates + value + (id, layout,
dense_shape) metadata. Deviation from the paper's Fig. 5, recorded in
DESIGN.md: instead of a single ``indices ARRAY<INT>`` column we emit one
integer column per dimension (``idx0``, ``idx1``, ...). The information is
identical, but per-dimension columns give the delta log min/max stats on
*every* coordinate, so slice reads prune files on any leading-dim range —
strictly better data skipping at zero cost (Parquet/parq-lite dictionary
encoding was already columnar).
"""

from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from .base import (Codec, RowGroup, SliceSpec, SparseCOO, as_coo,
                   header_dtype, header_shape, make_header, normalize_slices,
                   register, split_groups)


class COOCodec(Codec):
    """Per-element COO rows (paper's sparse baseline)."""

    layout = "coo"
    supports_slice = True
    supports_coo = True

    def encode(self, tensor: Any, **_) -> List[RowGroup]:
        """Tensor -> row groups (header + chunk rows)."""
        t = as_coo(tensor).sorted()
        cols: Dict[str, Any] = {
            "nnz_index": np.arange(t.nnz, dtype=np.int64),
            "value": np.asarray(t.values),
            "dense_shape": [np.asarray(t.shape, dtype=np.int64)] * t.nnz,
        }
        for d in range(t.ndim):
            cols[f"idx{d}"] = t.indices[:, d].astype(np.int64)
        if t.nnz == 0:  # keep schema discoverable for empty tensors
            cols["dense_shape"] = [np.asarray(t.shape, dtype=np.int64)]
            cols["nnz_index"] = np.asarray([-1], dtype=np.int64)
            cols["value"] = np.zeros(1, dtype=t.values.dtype)
            for d in range(t.ndim):
                cols[f"idx{d}"] = np.zeros(1, dtype=np.int64)
        skip = tuple(f"idx{d}" for d in range(t.ndim))
        header = make_header(t.shape, t.values.dtype, layout="COO")
        return [header, RowGroup(kind="chunk", columns=cols, skip_columns=skip)]

    @staticmethod
    def _coo(groups: List[Dict[str, Any]]) -> SparseCOO:
        header, groups = split_groups(groups)
        shape = header_shape(header)
        ndim = len(shape)
        idx_parts, val_parts = [], []
        for g in groups:
            keep = np.asarray(g["nnz_index"]) >= 0
            if not keep.any():
                continue
            idx = np.stack([np.asarray(g[f"idx{d}"])[keep] for d in range(ndim)], axis=1)
            idx_parts.append(idx)
            val_parts.append(np.asarray(g["value"])[keep])
        if not idx_parts:
            return SparseCOO(np.zeros((0, ndim), np.int64),
                             np.zeros(0, header_dtype(header)), shape)
        return SparseCOO(np.concatenate(idx_parts), np.concatenate(val_parts), shape)

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        return self._coo(groups).to_dense()

    def decode_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        """Decoded row groups -> :class:`SparseCOO` (no densify)."""
        return self._coo(groups)

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec):
        """Pushdown predicate selecting chunk rows for ``spec``."""
        shape = header_shape(header)
        out = {}
        for d, (lo, hi) in enumerate(spec):
            if (lo, hi) != (0, shape[d]):
                out[f"idx{d}"] = (lo, hi - 1)
        return out

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from pruned groups."""
        t = self._coo(groups)
        return t.slice(normalize_slices(t.shape, spec)).to_dense()

    def decode_device(self, groups: List[Dict[str, Any]],
                      spec: SliceSpec = None, *, use_pallas=None):
        """COO rows -> dense device tensor; the dense array never exists
        on the host. Only the (nnz, ndim) indices and (nnz,) values are
        staged; the ``coo_scatter`` kernel materializes the zeros-filled
        dense buffer directly on the device.
        """
        from ...lake import device as lake_device
        t = self._coo(groups)
        if spec is not None:
            t = t.slice(normalize_slices(t.shape, spec))
        size = int(np.prod(t.shape)) if t.ndim else 1
        if t.nnz and t.ndim:
            flat = np.ravel_multi_index(tuple(t.indices.T), t.shape)
        else:
            flat = np.zeros(0, dtype=np.int64)
        values = np.asarray(t.values)
        out = lake_device.scatter_coo(flat, values, size,
                                      use_pallas=use_pallas)
        out = out.reshape(t.shape)
        info = lake_device.DeviceReadInfo(
            path="coo_scatter",
            host_staged_bytes=int(t.indices.nbytes + values.nbytes),
            device_bytes=size * values.dtype.itemsize,
            on_device=lake_device.is_device_array(out))
        return out, info


register(COOCodec())
