"""CSF — Compressed Sparse Fiber (paper §IV.E).

The sorted non-zeros form a prefix tree: level *l* nodes are the unique
length-(l+1) coordinate prefixes. Per level we keep ``fid`` (the level-l
coordinate of each node) and ``fptr`` (offsets into level-(l+1) nodes).
Following the paper's storage layout, the first two levels are stored once
per tensor ("non-chunked": ``fid0/fptr0/fid1/fptr1``) and everything deeper
— plus the values — is chunked along level-1 fiber boundaries, each chunk
annotated with its level-1 node range ``[n1_start, n1_end)`` so a slice on
the leading dimension walks ``fid0``/``fptr0`` and fetches only overlapping
chunks.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from .base import (Codec, RowGroup, SliceSpec, SparseCOO, as_coo, header_dtype,
                   header_shape, is_header, normalize_slices, register)

TARGET_LEAVES_PER_CHUNK = 1 << 16


def _dedupe(t: SparseCOO) -> SparseCOO:
    """Sort lexicographically and sum duplicate coordinates."""
    t = t.sorted()
    if t.nnz == 0:
        return t
    same = np.all(t.indices[1:] == t.indices[:-1], axis=1)
    if not same.any():
        return t
    starts = np.flatnonzero(np.concatenate(([True], ~same)))
    seg = np.repeat(np.arange(len(starts)), np.diff(np.concatenate((starts, [t.nnz]))))
    vals = np.bincount(seg, weights=t.values.astype(np.float64)).astype(t.values.dtype)
    return SparseCOO(t.indices[starts], vals, t.shape)


def _build_tree(idx: np.ndarray, nnz: int, ndim: int):
    """node_starts[l]: nnz-positions where a new level-l node begins."""
    node_starts: List[np.ndarray] = []
    prev = None
    for l in range(ndim):
        ch = np.concatenate(([True], idx[1:, l] != idx[:-1, l])) if nnz else np.zeros(0, bool)
        if prev is not None:
            ch = ch | prev
        node_starts.append(np.flatnonzero(ch))
        prev = ch
    return node_starts


class CSFCodec(Codec):
    """Compressed Sparse Fiber trees (paper §IV.D)."""

    layout = "csf"
    supports_slice = True
    supports_coo = True

    def encode(self, tensor: Any, **_) -> List[RowGroup]:
        """Tensor -> row groups (header + chunk rows)."""
        t = _dedupe(as_coo(tensor))
        idx, vals, ndim, nnz = t.indices, t.values, t.ndim, t.nnz
        node_starts = _build_tree(idx, nnz, ndim)
        fids = [idx[node_starts[l], l].astype(np.int64) for l in range(ndim)]
        fptrs: List[np.ndarray] = []
        for l in range(ndim - 1):
            p = np.searchsorted(node_starts[l + 1], node_starts[l]).astype(np.int64)
            fptrs.append(np.concatenate((p, [len(node_starts[l + 1])])))

        hl = min(2, ndim)                     # header levels (paper: first two)
        unit = hl - 1                         # chunking level
        n_unit = len(node_starts[unit]) if nnz else 0

        header: Dict[str, Any] = {
            "__header__": np.asarray([1], dtype=np.int8),
            "dense_shape": [np.asarray(t.shape, dtype=np.int64)],
            "nnz": np.asarray([nnz], dtype=np.int64),
            "dtype": [str(vals.dtype)],
            "fid0": [fids[0] if nnz else np.zeros(0, np.int64)],
        }
        if ndim >= 2:
            header["fptr0"] = [fptrs[0] if nnz else np.zeros(1, np.int64)]
            header["fid1"] = [fids[1] if nnz else np.zeros(0, np.int64)]
        if ndim >= 3:
            header["fptr1"] = [fptrs[1] if nnz else np.zeros(1, np.int64)]
        groups = [RowGroup(kind="header", columns=header)]

        if nnz == 0:
            return groups

        # leaves spanned by each chunking-level node
        unit_starts = node_starts[unit]
        unit_leaf_bounds = np.concatenate((unit_starts, [nnz]))
        # greedy split: consecutive unit nodes until ~TARGET leaves
        cut_ids = [0]
        while cut_ids[-1] < n_unit:
            target = unit_leaf_bounds[cut_ids[-1]] + TARGET_LEAVES_PER_CHUNK
            nxt = int(np.searchsorted(unit_leaf_bounds, target, side="left"))
            cut_ids.append(max(min(nxt, n_unit), cut_ids[-1] + 1))

        cols: Dict[str, Any] = {k: [] for k in
                                ("n1_start", "n1_end", "leaf_start", "values")}
        deep_levels = list(range(2, ndim))
        for l in deep_levels:
            cols[f"fid{l}"] = []
            if l < ndim - 1:
                cols[f"fptr{l}"] = []
        for a, b in zip(cut_ids[:-1], cut_ids[1:]):
            leaf_s, leaf_e = int(unit_leaf_bounds[a]), int(unit_leaf_bounds[b])
            cols["n1_start"].append(a)
            cols["n1_end"].append(b)
            cols["leaf_start"].append(leaf_s)
            cols["values"].append(vals[leaf_s:leaf_e])
            # global node range per deeper level, by composing fptrs
            gs, ge = a, b
            for l in deep_levels:
                gs, ge = int(fptrs[l - 1][gs]), int(fptrs[l - 1][ge])
                cols[f"fid{l}"].append(fids[l][gs:ge])
                if l < ndim - 1:
                    loc = fptrs[l][gs:ge + 1]
                    cols[f"fptr{l}"].append(loc - loc[0])
        n_chunks = len(cols["n1_start"])
        chunk_cols: Dict[str, Any] = {
            "n1_start": np.asarray(cols["n1_start"], dtype=np.int64),
            "n1_end": np.asarray(cols["n1_end"], dtype=np.int64),
            "leaf_start": np.asarray(cols["leaf_start"], dtype=np.int64),
            "values": cols["values"],
        }
        for l in deep_levels:
            chunk_cols[f"fid{l}"] = cols[f"fid{l}"]
            if l < ndim - 1:
                chunk_cols[f"fptr{l}"] = cols[f"fptr{l}"]
        del n_chunks
        groups.append(RowGroup(kind="chunk", columns=chunk_cols,
                               skip_columns=("n1_start", "n1_end")))
        return groups

    # -- decode -----------------------------------------------------------------

    @staticmethod
    def _split(groups: List[Dict[str, Any]]):
        header = next(g for g in groups if is_header(g))
        chunks = [g for g in groups if not is_header(g)]
        return header, chunks

    def _chunk_coo(self, header: Dict[str, Any], g: Dict[str, Any], i: int,
                   ndim: int) -> Tuple[np.ndarray, np.ndarray]:
        """Rebuild (coords, values) for chunk row i of batch g."""
        s = int(np.asarray(g["n1_start"])[i])
        e = int(np.asarray(g["n1_end"])[i])
        vals = np.asarray(g["values"][i])
        L = len(vals)
        coords = np.empty((L, ndim), dtype=np.int64)
        fid0 = np.asarray(header["fid0"][0])
        if ndim == 1:
            coords[:, 0] = fid0[s:e]
            return coords, vals
        fptr0 = np.asarray(header["fptr0"][0])
        fid1 = np.asarray(header["fid1"][0])
        if ndim == 2:
            lc1 = np.ones(e - s, dtype=np.int64)  # level-1 nodes are leaves
        else:
            # bottom-up: fill deep coords and propagate per-node leaf counts
            deepest = np.asarray(g[f"fid{ndim - 1}"][i])
            coords[:, ndim - 1] = deepest
            lc_prev = np.ones(len(deepest), dtype=np.int64)
            for l in range(ndim - 2, 1, -1):
                fptr_l = np.asarray(g[f"fptr{l}"][i])
                cum = np.concatenate(([0], np.cumsum(lc_prev)))
                lc_l = cum[fptr_l[1:]] - cum[fptr_l[:-1]]
                coords[:, l] = np.repeat(np.asarray(g[f"fid{l}"][i]), lc_l)
                lc_prev = lc_l
            # lc_prev now holds leaf counts per local level-2 node
            fptr1 = np.asarray(header["fptr1"][0])
            ch1 = fptr1[s:e + 1] - fptr1[s]
            cum = np.concatenate(([0], np.cumsum(lc_prev)))
            lc1 = cum[ch1[1:]] - cum[ch1[:-1]]
        coords[:, 1] = np.repeat(fid1[s:e], lc1)
        i0 = np.searchsorted(fptr0, np.arange(s, e), side="right") - 1
        coords[:, 0] = np.repeat(fid0[i0], lc1)
        return coords, vals

    def _to_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        header, chunks = self._split(groups)
        shape = header_shape(header)
        dtype = header_dtype(header)
        ndim = len(shape)
        all_coords, all_vals = [], []
        for g in chunks:
            for i in range(len(np.asarray(g["n1_start"]))):
                c, v = self._chunk_coo(header, g, i, ndim)
                all_coords.append(c)
                all_vals.append(v)
        if not all_coords:
            return SparseCOO(np.zeros((0, ndim), np.int64), np.zeros(0, dtype), shape)
        return SparseCOO(np.concatenate(all_coords),
                         np.concatenate(all_vals).astype(dtype), shape)

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        return self._to_coo(groups).to_dense()

    def decode_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        """Decoded row groups -> :class:`SparseCOO` (no densify)."""
        return self._to_coo(groups)

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec):
        """Pushdown predicate selecting chunk rows for ``spec``."""
        shape = header_shape(header)
        lo, hi = spec[0]
        if (lo, hi) == (0, shape[0]) or len(shape) < 2:
            return {}
        fid0 = np.asarray(header["fid0"][0])
        fptr0 = np.asarray(header["fptr0"][0])
        p0s = int(np.searchsorted(fid0, lo, side="left"))
        p0e = int(np.searchsorted(fid0, hi - 1, side="right"))
        if p0s >= p0e:
            return {"n1_start": (0, -1)}  # empty: prunes everything
        n1s, n1e = int(fptr0[p0s]), int(fptr0[p0e])
        return {"n1_start": (None, n1e - 1), "n1_end": (n1s + 1, None)}

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from pruned groups."""
        t = self._to_coo(groups)
        return t.slice(normalize_slices(t.shape, spec)).to_dense()


register(CSFCodec())
