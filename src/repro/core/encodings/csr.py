"""CSR/CSC (paper §IV.D) — flatten to 2-D, compress row- or column-wise.

The tensor is reshaped to a 2-D matrix: the first ``split`` dims become
rows, the rest become columns (``flattened_shape``); CSR's three arrays
(``value``, ``col_indices``, ``crow_indices``) are then chunked into table
rows along matrix-row boundaries ("encoding before partitioning", as the
paper groups it). Each chunk row records its ``[row_start, row_end)`` so a
leading-dim slice prunes chunk files by range. CSC is CSR of the transpose.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from .base import (Codec, RowGroup, SliceSpec, SparseCOO, as_coo, first_scalar,
                   header_shape, make_header, normalize_slices, register,
                   split_groups)

TARGET_NNZ_PER_CHUNK = 1 << 18


def _flatten_coo(t: SparseCOO, split: int, transpose: bool) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Tuple[int, int]]:
    rows_shape = t.shape[:split] or (1,)
    cols_shape = t.shape[split:] or (1,)
    n_rows = int(np.prod(rows_shape))
    n_cols = int(np.prod(cols_shape))
    if t.nnz:
        r = np.ravel_multi_index([t.indices[:, d] for d in range(split)], rows_shape) \
            if split else np.zeros(t.nnz, dtype=np.int64)
        c = np.ravel_multi_index([t.indices[:, d] for d in range(split, t.ndim)], cols_shape) \
            if split < t.ndim else np.zeros(t.nnz, dtype=np.int64)
    else:
        r = c = np.zeros(0, dtype=np.int64)
    if transpose:
        r, c = c, r
        n_rows, n_cols = n_cols, n_rows
    return r.astype(np.int64), c.astype(np.int64), np.asarray(t.values), (n_rows, n_cols)


class CSRCodec(Codec):
    """Compressed sparse rows for matrices (paper §IV.C)."""

    layout = "csr"
    transpose = False
    supports_slice = True
    supports_coo = True

    def encode(self, tensor: Any, *, split: int = 1, **_) -> List[RowGroup]:
        """Tensor -> row groups (header + chunk rows)."""
        t = as_coo(tensor)
        r, c, v, (n_rows, n_cols) = _flatten_coo(t, split, self.transpose)
        order = np.lexsort((c, r))
        r, c, v = r[order], c[order], v[order]
        # chunk along row boundaries targeting ~TARGET_NNZ_PER_CHUNK nnz each
        groups: List[RowGroup] = []
        starts = [0]
        while starts[-1] < len(v):
            nxt = min(len(v), starts[-1] + TARGET_NNZ_PER_CHUNK)
            if nxt < len(v):  # align up to the end of the current matrix row
                row_at = r[nxt - 1]
                nxt = int(np.searchsorted(r, row_at, side="right"))
            starts.append(max(nxt, starts[-1] + 1))
        bounds = list(zip(starts[:-1], starts[1:])) or [(0, 0)]
        cols_rows: Dict[str, Any] = {k: [] for k in
                                     ("row_start", "row_end", "nnz_start", "value",
                                      "col_indices", "crow_local")}
        for s, e in bounds:
            rs = int(r[s]) if e > s else 0
            re_ = int(r[e - 1]) + 1 if e > s else 0
            local_rows = re_ - rs
            crow = np.zeros(local_rows + 1, dtype=np.int64)
            if e > s:
                counts = np.bincount(r[s:e] - rs, minlength=local_rows)
                crow[1:] = np.cumsum(counts)
            cols_rows["row_start"].append(rs)
            cols_rows["row_end"].append(re_)
            cols_rows["nnz_start"].append(s)
            cols_rows["value"].append(v[s:e])
            cols_rows["col_indices"].append(c[s:e])
            cols_rows["crow_local"].append(crow)
        n_chunks = len(bounds)
        chunk_cols: Dict[str, Any] = {
            "row_start": np.asarray(cols_rows["row_start"], dtype=np.int64),
            "row_end": np.asarray(cols_rows["row_end"], dtype=np.int64),
            "nnz_start": np.asarray(cols_rows["nnz_start"], dtype=np.int64),
            "value": cols_rows["value"],
            "col_indices": cols_rows["col_indices"],
            "crow_local": cols_rows["crow_local"],
            "dense_shape": [np.asarray(t.shape, dtype=np.int64)] * n_chunks,
            "flattened_shape": [np.asarray((n_rows, n_cols), dtype=np.int64)] * n_chunks,
            "split": np.full(n_chunks, split, dtype=np.int32),
        }
        header = make_header(t.shape, v.dtype, split=split,
                             flattened_shape=np.asarray((n_rows, n_cols), np.int64))
        return [header, RowGroup(kind="chunk", columns=chunk_cols,
                                 skip_columns=("row_start", "row_end"))]

    # -- decode ----------------------------------------------------------------

    def _gather(self, groups: List[Dict[str, Any]]):
        header, groups = split_groups(groups)
        shape = header_shape(header)
        flat = tuple(int(x) for x in header["flattened_shape"][0])
        split = int(first_scalar(header["split"]))
        rows, cols, vals = [], [], []
        for g in groups:
            for i in range(len(g["row_start"])):
                rs = int(np.asarray(g["row_start"])[i])
                crow = np.asarray(g["crow_local"][i])
                v = np.asarray(g["value"][i])
                c = np.asarray(g["col_indices"][i])
                local_rows = len(crow) - 1
                r = np.repeat(np.arange(rs, rs + local_rows), np.diff(crow))
                rows.append(r)
                cols.append(c)
                vals.append(v)
        if rows:
            r = np.concatenate(rows)
            c = np.concatenate(cols)
            v = np.concatenate(vals)
        else:
            from .base import header_dtype
            r = c = np.zeros(0, np.int64)
            v = np.zeros(0, header_dtype(header))
        return r, c, v, shape, flat, split

    def _to_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        r, c, v, shape, flat, split = self._gather(groups)
        if self.transpose:
            r, c = c, r
        ndim = len(shape)
        rows_shape = shape[:split] or (1,)
        cols_shape = shape[split:] or (1,)
        idx = np.empty((len(v), ndim), dtype=np.int64)
        if split:
            for d, coord in enumerate(np.unravel_index(r, rows_shape)):
                idx[:, d] = coord
        if split < ndim:
            for d, coord in enumerate(np.unravel_index(c, cols_shape)):
                idx[:, split + d] = coord
        return SparseCOO(idx, v, shape)

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        return self._to_coo(groups).to_dense()

    def decode_coo(self, groups: List[Dict[str, Any]]) -> SparseCOO:
        """Decoded row groups -> :class:`SparseCOO` (no densify)."""
        return self._to_coo(groups)

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec):
        """Pushdown predicate selecting chunk rows for ``spec``."""
        if self.transpose:
            return {}  # CSC indexes by columns; leading-dim pushdown unavailable
        shape = header_shape(header)
        split = int(first_scalar(header["split"]))
        rows_shape = shape[:split] or (1,)
        los = [spec[d][0] for d in range(split)]
        his = [spec[d][1] - 1 for d in range(split)]
        if not los:
            return {}
        lo = int(np.ravel_multi_index(los, rows_shape))
        hi = int(np.ravel_multi_index(his, rows_shape))
        # chunk [row_start,row_end) overlaps [lo,hi] iff start<=hi and end>lo
        return {"row_start": (None, hi), "row_end": (lo + 1, None)}

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from pruned groups."""
        t = self._to_coo(groups)
        return t.slice(normalize_slices(t.shape, spec)).to_dense()


class CSCCodec(CSRCodec):
    """CSR's column-major sibling (encodes the transpose walk)."""

    layout = "csc"
    transpose = True


register(CSRCodec())
register(CSCCodec())
