"""FTSF — Flattened Tensor Storage Format (paper §IV.A).

A rank-N tensor is split along its leading ``N - Dc`` dimensions into
rank-``Dc`` chunks; each chunk becomes one table row
``(chunk_index, chunk BINARY)`` plus the paper's metadata columns
(``dim_count``, ``dimensions``, ``chunk_dim_count``), which dictionary/RLE
encoding makes nearly free. ``chunk_index`` is the row-major flattening of
the leading indices, so a slice on the leading dims maps to a
``chunk_index`` interval and the delta log's min/max stats skip every file
outside it — that is the paper's −90 % read-slice result.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from .base import (Codec, RowGroup, SliceSpec, as_dense, first_scalar,
                   header_dtype, header_shape, make_header, normalize_slices,
                   register, slice_shape, split_groups)


class FTSFCodec(Codec):
    """Flattened Tensor Storage Format (paper §IV.A)."""

    layout = "ftsf"
    supports_slice = True
    supports_coo = False      # dense chunks: COO reads densify first

    def encode(self, tensor: Any, *, chunk_dims: int = None, **_) -> List[RowGroup]:
        """Tensor -> row groups (header + chunk rows)."""
        x = as_dense(tensor)
        n = x.ndim
        if chunk_dims is None:
            chunk_dims = max(n - 1, 0)
        if not 0 <= chunk_dims <= n:
            raise ValueError(f"chunk_dims {chunk_dims} out of range for rank {n}")
        lead = x.shape[: n - chunk_dims]
        n_chunks = int(np.prod(lead)) if lead else 1
        flat = np.ascontiguousarray(x).reshape(n_chunks, -1)
        chunk_nbytes = flat[0].nbytes if n_chunks else 0
        cols: Dict[str, Any] = {
            "chunk_index": np.arange(n_chunks, dtype=np.int64),
            "chunk": [flat[i].tobytes() for i in range(n_chunks)],
            "dim_count": np.full(n_chunks, n, dtype=np.int32),
            "dimensions": [np.asarray(x.shape, dtype=np.int64)] * n_chunks,
            "chunk_dim_count": np.full(n_chunks, chunk_dims, dtype=np.int32),
            "dtype": [str(x.dtype)] * n_chunks,
        }
        del chunk_nbytes
        header = make_header(x.shape, x.dtype, chunk_dim_count=chunk_dims,
                             dimensions=np.asarray(x.shape, dtype=np.int64))
        return [header,
                RowGroup(kind="chunk", columns=cols, skip_columns=("chunk_index",))]

    # -- helpers -------------------------------------------------------------

    @staticmethod
    def _meta(groups: List[Dict[str, Any]]) -> Tuple[Tuple[int, ...], int, np.dtype, List[Dict[str, Any]]]:
        header, chunks = split_groups(groups)
        shape = header_shape(header)
        chunk_dims = int(first_scalar(header["chunk_dim_count"]))
        return shape, chunk_dims, header_dtype(header), chunks

    def decode(self, groups: List[Dict[str, Any]]) -> np.ndarray:
        """Decoded row groups -> the dense tensor."""
        shape, chunk_dims, dtype, groups = self._meta(groups)
        lead = shape[: len(shape) - chunk_dims]
        n_chunks = int(np.prod(lead)) if lead else 1
        chunk_elems = int(np.prod(shape[len(shape) - chunk_dims:])) if chunk_dims else 1
        out = np.empty((n_chunks, chunk_elems), dtype=dtype)
        seen = 0
        for g in groups:
            for i, blob in zip(np.asarray(g["chunk_index"]), g["chunk"]):
                out[int(i)] = np.frombuffer(blob, dtype=dtype)
                seen += 1
        if seen != n_chunks:
            raise ValueError(f"decode: got {seen}/{n_chunks} chunks")
        return out.reshape(shape)

    def slice_filters(self, header: Dict[str, Any], spec: SliceSpec):
        """Pushdown predicate selecting chunk rows for ``spec``."""
        shape = header_shape(header)
        chunk_dims = int(first_scalar(header["chunk_dim_count"]))
        lead = shape[: len(shape) - chunk_dims]
        if not lead:
            return {}
        # envelope of row-major flattened leading indices
        los = [spec[d][0] for d in range(len(lead))]
        his = [spec[d][1] - 1 for d in range(len(lead))]
        lo = int(np.ravel_multi_index(los, lead))
        hi = int(np.ravel_multi_index(his, lead))
        return {"chunk_index": (lo, hi)}

    def decode_slice(self, groups: List[Dict[str, Any]], spec: SliceSpec) -> np.ndarray:
        """Decode only the ``spec`` window from pruned groups."""
        shape, chunk_dims, dtype, groups = self._meta(groups)
        spec = normalize_slices(shape, spec)
        n = len(shape)
        lead = shape[: n - chunk_dims]
        if chunk_dims and any(spec[d] != (0, shape[d]) for d in range(n - chunk_dims, n)):
            # sub-chunk slicing: fetch covering chunks, crop locally
            pass
        n_lead = len(lead)
        lead_spec = spec[:n_lead]
        out_lead = slice_shape(lead_spec)
        chunk_shape = shape[n - chunk_dims:]
        out = np.empty(tuple(out_lead) + tuple(chunk_shape), dtype=dtype)
        out2d = out.reshape(int(np.prod(out_lead)) if out_lead else 1, -1)
        wanted: Dict[int, int] = {}
        if n_lead:
            grids = np.meshgrid(*[np.arange(lo, hi) for lo, hi in lead_spec], indexing="ij")
            flat_idx = np.ravel_multi_index([g.ravel() for g in grids], lead)
            wanted = {int(ci): pos for pos, ci in enumerate(flat_idx)}
        else:
            wanted = {0: 0}
        found = 0
        for g in groups:
            for i, blob in zip(np.asarray(g["chunk_index"]), g["chunk"]):
                pos = wanted.get(int(i))
                if pos is None:
                    continue
                out2d[pos] = np.frombuffer(blob, dtype=dtype)
                found += 1
        if found != len(wanted):
            raise ValueError(f"decode_slice: got {found}/{len(wanted)} chunks")
        # crop trailing (in-chunk) dims if the slice narrows them
        trailing = tuple(slice(lo, hi) for lo, hi in spec[n_lead:])
        return out[(Ellipsis,) + trailing] if trailing else out

    def decode_device(self, groups: List[Dict[str, Any]],
                      spec: SliceSpec = None, *, use_pallas=None):
        """Chunk rows -> device tensor without an ordered host copy.

        Chunk payloads are staged into a preallocated buffer in **arrival
        order** (one memoryview write per chunk — the only host copy),
        then the whole buffer moves to the device once and the
        ``block_gather`` kernel permutes rows into ``chunk_index`` order
        there. Sub-chunk (trailing-dim) crops happen on the device view.
        """
        from ...lake import device as lake_device
        shape, chunk_dims, dtype, groups = self._meta(groups)
        n = len(shape)
        spec = normalize_slices(shape, spec)
        lead = shape[: n - chunk_dims]
        n_lead = len(lead)
        lead_spec = spec[:n_lead]
        out_lead = slice_shape(lead_spec)
        chunk_shape = shape[n - chunk_dims:]
        chunk_elems = int(np.prod(chunk_shape)) if chunk_dims else 1
        wanted: Dict[int, int] = {0: 0}
        if n_lead:
            grids = np.meshgrid(*[np.arange(lo, hi) for lo, hi in lead_spec],
                                indexing="ij")
            flat_idx = np.ravel_multi_index([g.ravel() for g in grids], lead)
            wanted = {int(ci): pos for pos, ci in enumerate(flat_idx)}
        asm = lake_device.ChunkAssembler(len(wanted), chunk_elems, dtype)
        for g in groups:
            for i, blob in zip(np.asarray(g["chunk_index"]), g["chunk"]):
                pos = wanted.get(int(i))
                if pos is not None:
                    asm.add(pos, blob)
        if asm.count != len(wanted):
            raise ValueError(
                f"decode_device: got {asm.count}/{len(wanted)} chunks")
        rows = asm.gather(use_pallas=use_pallas)
        out = rows.reshape(tuple(out_lead) + tuple(chunk_shape))
        trailing = tuple(slice(lo, hi) for lo, hi in spec[n_lead:])
        if any(sp != (0, d) for sp, d in zip(spec[n_lead:], chunk_shape)):
            out = out[(Ellipsis,) + trailing]
        on_dev = lake_device.is_device_array(out)
        info = lake_device.DeviceReadInfo(
            path="block_gather" if on_dev else "host_fallback",
            host_staged_bytes=asm.staged_bytes,
            device_bytes=int(np.prod(out.shape)) * np.dtype(dtype).itemsize,
            on_device=on_dev)
        return out, info


register(FTSFCodec())
