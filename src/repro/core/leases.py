"""Snapshot leases: refcounted pins that make maintenance safe for readers.

Since the handle API every read is snapshot-pinned: a
:class:`~repro.core.catalog.TensorRef` keeps returning the same bytes no
matter what writers do afterwards. That guarantee only holds while the
pinned version's data files still exist — which ``vacuum`` knows nothing
about unless someone tells it. This module is the telling:

* every ref **acquires a lease** on its catalog's version vector at open
  and releases it on ``close()`` / context-manager exit / garbage
  collection (a ``weakref.finalize`` backstop, so even leaked refs cannot
  pin a snapshot forever);
* the :class:`LeaseRegistry` refcounts leases per version vector.
  Registries are shared **per (object store, root)** within the process,
  so several ``DeltaTensorStore`` clients over the same physical store see
  each other's pins (Deep Lake ties dataset version retention to active
  reader views the same way);
* maintenance (``store.vacuum``) folds ``leased_versions(shard)`` into its
  retention horizon: files referenced by any leased snapshot are never
  deleted, so a pinned ref reads identical bytes before, during, and after
  concurrent compact+vacuum.

Leases are a **per-process** mechanism: two processes vacuum-ing the same
bucket do not see each other's refs. Cross-process retention is what the
``keep_versions`` / TTL half of :class:`RetentionPolicy` is for — leases
protect live readers, the policy protects everyone else.
"""

from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

from ..lake.io import store_scope as lease_scope  # noqa: F401 (re-export)

VersionVector = Tuple[int, ...]

# process-wide registries keyed by (object-store scope, store root): every
# client of one physical store shares one registry, so leases taken through
# any client are visible to maintenance run through any other. Weak values:
# each DeltaTensorStore (and every live Lease) holds its registry strongly,
# so a registry lives exactly as long as anything that could use it —
# transient stores don't accumulate dead registries for the process life.
_registries: "weakref.WeakValueDictionary[tuple, LeaseRegistry]" = \
    weakref.WeakValueDictionary()
_registries_lock = threading.Lock()


def registry_for(scope: tuple, root: str) -> "LeaseRegistry":
    """The shared registry for one physical (object store, root) pair."""
    key = (scope, root.rstrip("/"))
    with _registries_lock:
        reg = _registries.get(key)
        if reg is None:
            reg = LeaseRegistry()
            _registries[key] = reg
        return reg


@dataclass(frozen=True)
class RetentionPolicy:
    """How many non-leased historical versions maintenance must keep.

    ``keep_versions=K`` retains the newest K versions of every shard table
    (K=1 keeps only the latest snapshot — the classic vacuum). ``ttl_s``
    additionally retains every version whose commit is younger than the
    TTL, whatever K says. Leased versions are always retained on top of
    this policy; they are pins, not policy.
    """

    keep_versions: int = 1
    ttl_s: Optional[float] = None

    def __post_init__(self):
        if self.keep_versions < 1:
            raise ValueError(
                f"keep_versions must be >= 1, got {self.keep_versions}")


class Lease:
    """One refcount held on a version vector; release is idempotent."""

    __slots__ = ("_registry", "version_vector", "_released")

    def __init__(self, registry: "LeaseRegistry", vector: VersionVector):
        self._registry = registry
        self.version_vector = vector
        self._released = False

    def release(self) -> None:
        """Drop this pin (idempotent); maintenance may then reclaim."""
        if not self._released:
            self._released = True
            self._registry._release(self.version_vector)

    @property
    def released(self) -> bool:
        """Whether :meth:`release` already ran."""
        return self._released

    def __enter__(self) -> "Lease":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    def __repr__(self) -> str:
        state = "released" if self._released else "live"
        return f"Lease({self.version_vector}, {state})"


class LeaseRegistry:
    """Thread-safe refcounts of live snapshot pins, per version vector."""

    def __init__(self):
        self._counts: Dict[VersionVector, int] = {}
        self._lock = threading.Lock()

    def acquire(self, vector: VersionVector) -> Lease:
        """Take one refcounted pin on ``vector``; pair with ``release``."""
        vv = tuple(int(v) for v in vector)
        with self._lock:
            self._counts[vv] = self._counts.get(vv, 0) + 1
        return Lease(self, vv)

    def _release(self, vector: VersionVector) -> None:
        with self._lock:
            n = self._counts.get(vector, 0) - 1
            if n > 0:
                self._counts[vector] = n
            else:
                self._counts.pop(vector, None)

    # -- introspection (what vacuum consumes) --------------------------------

    def leased_vectors(self) -> Dict[VersionVector, int]:
        """Live vectors -> refcount (a snapshot; safe to iterate)."""
        with self._lock:
            return dict(self._counts)

    def leased_versions(self, shard: int) -> Set[int]:
        """Versions of ``shard`` pinned by any live lease.

        Vectors shorter than ``shard+1`` (from clients that opened the
        store before it was sharded — cannot happen today, defensive) are
        ignored rather than crashing maintenance.
        """
        with self._lock:
            return {vv[shard] for vv in self._counts if len(vv) > shard}

    @property
    def active(self) -> int:
        """Number of distinct leased vectors."""
        with self._lock:
            return len(self._counts)

    def __len__(self) -> int:
        return self.active
