"""WriteBatch — stage many tensor writes/deletes, land atomic commits.

Replaces the ad-hoc two-phase code that each writer (checkpointer, serve
weight saver) used to hand-roll over ``put_deferred`` + ``commit_adds``:

    with store.batch(op="CHECKPOINT step=7") as b:
        for name, arr in leaves:
            b.put(arr, tensor_id=f"{name}@7", layout="ftsf")
    print(b.version)          # the committed version (vector if sharded)

Part files are uploaded as they are staged (invisible until the commit);
``__exit__`` commits everything — puts, overwrites, deletes, raw rows — as
one delta-log action list **per shard**, so readers observe either all of a
shard's slice of the batch or none of it. On an unsharded store that is
exactly one atomic commit, as before. An exception inside the ``with``
block abandons the batch: uploaded files stay invisible to every snapshot
(vacuum reclaims them) and **no header is cached**, which is the fix for
the old put_deferred staleness bug where a failed batched commit left a
poisoned header cache.

**Commit-retry/rebase** (the ROADMAP follow-on): every per-shard commit is
fenced with ``expected_version`` = the batch's base snapshot for that
shard. When a concurrent writer lands first, the fence raises
:class:`~repro.lake.log.CommitConflict`; the batch then *rebases* — it
re-snapshots the conflicted shard, re-validates that no tensor staged here
was concurrently modified (same staged files present/absent as at the
base), and re-commits against the new version — up to ``commit_retries``
times. Disjoint-tensor writers therefore all succeed; a genuine
same-tensor overlap is non-rebasable and raises ``CommitConflict``
immediately (retrying cannot make two overwrites of one tensor commute).
"""

from __future__ import annotations

import uuid
from typing import Any, Dict, List, Optional, Tuple, Union, TYPE_CHECKING

from ..lake.log import CommitConflict, Snapshot
from ..lake.table import DeltaTable

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .store import DeltaTensorStore

DEFAULT_COMMIT_RETRIES = 10


class BatchClosedError(RuntimeError):
    """Raised when staging into an already committed/abandoned batch."""


def _tensor_paths(snapshot: Snapshot) -> Dict[str, List[str]]:
    """tid -> sorted live file paths in one shard snapshot."""
    out: Dict[str, List[str]] = {}
    for add in snapshot.add_actions():
        tid = (add.get("partitionValues") or {}).get("tensor")
        if tid is not None:
            out.setdefault(tid, []).append(add["path"])
    return {tid: sorted(paths) for tid, paths in out.items()}


class WriteBatch:
    """Stages puts/deletes against per-shard base snapshots; commits per shard.

    A shard's base snapshot is pinned the first time the batch touches that
    shard: every existence/overwrite/delete lookup resolves against it, so
    what a batch removes does not shift under a concurrent writer. Shards
    the batch never touches are never probed — on a sharded store a
    single-tensor put costs one shard's snapshot, not N. At commit time
    each touched shard lands one atomic commit fenced against its base,
    with the bounded rebase loop above resolving append-only races.

    Cross-shard note: per-shard commits are each atomic, but a batch that
    spans several shards is not a single cross-shard transaction — a reader
    sampling mid-commit can see some shards' slices landed and others not.
    Pin a version vector (``store.catalog()``) for a consistent view.
    """

    def __init__(self, store: "DeltaTensorStore", *, op: str = "WRITE BATCH",
                 commit_retries: Optional[int] = None):
        self._store = store
        self.op = op
        self.commit_retries = (DEFAULT_COMMIT_RETRIES if commit_retries is None
                               else max(0, int(commit_retries)))
        # staged operations, in order: each is a dict with
        #   kind: "put" | "delete" | "rows"
        #   shard: destination shard index
        #   tid:   tensor id ("put"/"delete" only; None for raw rows)
        #   adds:  add-actions uploaded for this op
        #   removes: file paths this op removes (resolved at the base)
        self._ops: List[Dict[str, Any]] = []
        # per-shard upload guards: staged part files register as in-flight
        # so a concurrent vacuum cannot delete them as orphans before the
        # commit lands; closed on commit/abandon (see UploadGuard)
        self._guards: Dict[int, Any] = {}
        # header seeds applied to the store's by-path cache ONLY on a
        # successful commit (never for an abandoned batch)
        self._header_seeds: List[tuple] = []
        self._staged_tids: List[str] = []
        # per-shard base pins: shard -> (base version, tid -> live paths)
        self._base_versions: Dict[int, int] = {}
        self._base_paths: Dict[int, Dict[str, List[str]]] = {}
        self._closed = False
        # committed version: int on 1-shard stores, version vector tuple on
        # sharded stores (resolved lazily); detail in `shard_versions`
        self._version: Union[None, int, Tuple[int, ...]] = None
        self.shard_versions: Dict[int, int] = {}  # shard -> committed version
        self.conflicts = 0  # CommitConflicts this batch hit (and rebased)

    # -- staging ---------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise BatchClosedError("WriteBatch already committed or abandoned")

    def _guard(self, shard: int):
        g = self._guards.get(shard)
        if g is None:
            g = self._guards[shard] = self._store.tables[shard].guard_uploads()
        return g

    def _close_guards(self) -> None:
        for g in self._guards.values():
            g.close()

    def put(self, tensor: Any, *, layout: str = "auto",
            tensor_id: Optional[str] = None, overwrite: bool = False,
            target_file_bytes: Optional[int] = None,
            compression: Optional[str] = None, **codec_params) -> str:
        """Stage one tensor; returns its id. Files upload now, commit later.

        ``compression`` overrides the store's default chunk-blob codec for
        this tensor (a spec like ``"zlib+shuffle"``; ``None`` = default).
        Raises ``ValueError`` on duplicate staging or an existing id
        without ``overwrite`` — checked before any byte is uploaded.
        """
        self._check_open()
        layout, tid = self._store._resolve_tid(tensor, layout, tensor_id)
        # all checks run BEFORE any byte uploads: a rejected put must not
        # cost encode+upload bandwidth or leave orphaned invisible files
        if tid in self._staged_tids:
            raise ValueError(f"tensor {tid!r} staged twice in one batch")
        existing = self._existing_paths(tid)
        if existing and not overwrite:
            raise ValueError(
                f"tensor {tid!r} already exists (use overwrite=True)")
        shard, adds, header_seed = self._store._encode_and_upload(
            tensor, layout=layout, tensor_id=tid,
            target_file_bytes=target_file_bytes,
            guard=self._guard(self._store.router.shard_of(tid)),
            compression=compression,
            **codec_params)
        self._ops.append({"kind": "put", "shard": shard, "tid": tid,
                          "adds": adds, "removes": sorted(existing)})
        if header_seed is not None:
            self._header_seeds.append(header_seed)
        self._staged_tids.append(tid)
        return tid

    def put_variant(self, tensor: Any, *, base_tid: str,
                    tensor_id: Optional[str] = None, overwrite: bool = False,
                    target_file_bytes: Optional[int] = None,
                    compression: Optional[str] = None) -> str:
        """Stage ``tensor`` as a delta-encoded variant of ``base_tid``.

        Chunks identical to the base dedup into pure references via the
        chunk index; differing chunks upload as XOR deltas against the
        base's objects. The staged tensor is an ordinary tensor in every
        other way (same commit/rebase semantics as :meth:`put`). The
        default id is ``"<base_tid>~<hex>"``; pass ``tensor_id`` to
        choose one. Raises ``KeyError`` if ``base_tid`` does not exist.
        """
        self._check_open()
        tid = tensor_id if tensor_id is not None else \
            f"{base_tid}~{uuid.uuid4().hex[:8]}"
        if tid in self._staged_tids:
            raise ValueError(f"tensor {tid!r} staged twice in one batch")
        existing = self._existing_paths(tid)
        if existing and not overwrite:
            raise ValueError(
                f"tensor {tid!r} already exists (use overwrite=True)")
        shard, adds, header_seed = self._store._encode_and_upload_variant(
            tensor, base_tid=base_tid, tensor_id=tid,
            target_file_bytes=target_file_bytes, compression=compression,
            guard_for=self._guard)
        self._ops.append({"kind": "put", "shard": shard, "tid": tid,
                          "adds": adds, "removes": sorted(existing)})
        if header_seed is not None:
            self._header_seeds.append(header_seed)
        self._staged_tids.append(tid)
        return tid

    def delete(self, tid: str, *, missing_ok: bool = False) -> None:
        """Stage removal of every file of ``tid`` (header + chunks)."""
        self._check_open()
        paths = self._existing_paths(tid)
        if not paths and not missing_ok:
            raise KeyError(f"tensor {tid!r} not found")
        if paths:
            self._ops.append({"kind": "delete",
                              "shard": self._store.router.shard_of(tid),
                              "tid": tid, "adds": [],
                              "removes": sorted(paths)})

    def add_rows(self, columns: Dict[str, Any], *,
                 partition_values: Optional[Dict[str, str]] = None) -> None:
        """Stage one raw table file (e.g. a checkpoint manifest row).

        Raw rows have no tensor id, so they always land on shard 0 (the
        meta shard) and are pure adds — a conflict on them is always
        rebasable by re-committing as-is.
        """
        self._check_open()
        add = self._store.tables[0].append(
            columns, commit=False, partition_values=partition_values or {},
            guard=self._guard(0))
        self._ops.append({"kind": "rows", "shard": 0, "tid": None,
                          "adds": [add], "removes": []})

    def _pin_shard(self, shard: int) -> None:
        """Pin this shard's base snapshot on first touch (then reuse it)."""
        if shard not in self._base_versions:
            snap = self._store.tables[shard].snapshot()
            self._base_versions[shard] = snap.version
            self._base_paths[shard] = _tensor_paths(snap)

    def _existing_paths(self, tid: str) -> List[str]:
        shard = self._store.router.shard_of(tid)
        self._pin_shard(shard)
        return self._base_paths[shard].get(tid, [])

    # -- terminal states -------------------------------------------------------

    @property
    def staged(self) -> List[str]:
        """Tensor ids staged by :meth:`put` so far, in staging order."""
        return list(self._staged_tids)

    @property
    def version(self) -> Union[None, int, Tuple[int, ...]]:
        """Committed version: int (1-shard) or a version vector (sharded).

        On a sharded store the vector covers ALL shards — committed shards
        at their new versions, untouched shards probed lazily on first
        access (so batches that never read ``version`` never pay for it).
        The vector is a valid logical pin observed just after the commit.
        """
        if self._version is None and self._closed and self.shard_versions \
                and self._store.shards > 1:
            vv = list(self._store.version_vector())
            for s, v in self.shard_versions.items():
                vv[s] = max(vv[s], v)
            self._version = tuple(vv)
        return self._version

    def _rebase(self, table: DeltaTable, ops: List[Dict[str, Any]]) -> int:
        """Re-snapshot one conflicted shard and re-validate the staged ops.

        Rebasable = every tensor this batch touches is byte-identical to
        the base in the fresh snapshot (same live files for overwrites and
        deletes, still absent for fresh puts) — then the staged add/remove
        actions still mean the same thing and can simply re-commit on top.
        Anything else is a genuine same-tensor overlap: raise.
        """
        snap = table.snapshot()
        live = _tensor_paths(snap)
        for op in ops:
            tid = op["tid"]
            if tid is None:
                continue  # raw rows: pure adds, nothing to re-validate
            if live.get(tid, []) != op["removes"]:
                raise CommitConflict(
                    f"tensor {tid!r} was concurrently modified; batch "
                    f"cannot be rebased", found=snap.version)
        return snap.version

    def commit(self) -> Union[None, int, Tuple[int, ...]]:
        """Land every staged action, one fenced atomic commit per shard.

        Returns the committed version on 1-shard stores. On sharded stores
        it returns None — read ``batch.version`` (lazy) or
        ``batch.shard_versions`` (free) instead; resolving the full vector
        eagerly here would probe every shard log on every commit.
        """
        self._check_open()
        self._closed = True
        if not self._ops:
            self._version = self._store.version()
            return self._version
        try:
            return self._commit_shards()
        finally:
            # committed files are live in snapshots, failed ones are
            # vacuumable orphans — either way the in-flight guard is done
            self._close_guards()

    def _commit_shards(self) -> Union[None, int, Tuple[int, ...]]:
        per_shard: Dict[int, List[Dict[str, Any]]] = {}
        for op in self._ops:
            per_shard.setdefault(op["shard"], []).append(op)

        stats = self._store.commit_stats
        for shard in sorted(per_shard):
            ops = per_shard[shard]
            adds = [a for op in ops for a in op["adds"]]
            removes = [p for op in ops for p in op["removes"]]
            table = self._store.tables[shard]
            self._pin_shard(shard)       # rows-only shards pin here
            expected = self._base_versions[shard]
            attempts = 0
            while True:
                try:
                    v = table.commit_adds(adds, removes=removes, op=self.op,
                                          expected_version=expected)
                    stats["commits"] += 1
                    self.shard_versions[shard] = v
                    break
                except CommitConflict:
                    stats["conflicts"] += 1
                    self.conflicts += 1
                    attempts += 1
                    if attempts > self.commit_retries:
                        raise
                    # rebase: raises CommitConflict itself on real overlap
                    expected = self._rebase(table, ops)
                    stats["retries"] += 1
            # spill-to-index hook: once a shard snapshot crosses the
            # store's threshold, write the catalog index beside the log so
            # cold readers skip the O(files) walk (cheap-guarded no-op on
            # small shards)
            self._store._maybe_spill(shard, v, adds_hint=len(adds))

        if self._store.shards == 1:
            self._version = self.shard_versions[0]
        # sharded: the full vector resolves lazily in the `version` property
        # headers become cacheable only now: the data is visible and the
        # header file path is immutable, so this can never go stale
        for path, cols in self._header_seeds:
            self._store._seed_header(path, cols)
        return self._version

    def abandon(self) -> None:
        """Drop the batch; uploaded part files remain invisible (and,
        with the upload guards closed, vacuumable as orphans)."""
        self._closed = True
        self._close_guards()

    def __enter__(self) -> "WriteBatch":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abandon()
        elif not self._closed:
            self.commit()
