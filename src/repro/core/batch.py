"""WriteBatch — stage many tensor writes/deletes, land ONE atomic commit.

Replaces the ad-hoc two-phase code that each writer (checkpointer, serve
weight saver) used to hand-roll over ``put_deferred`` + ``commit_adds``:

    with store.batch(op="CHECKPOINT step=7") as b:
        for name, arr in leaves:
            b.put(arr, tensor_id=f"{name}@7", layout="ftsf")
    print(b.version)          # the one committed table version

Part files are uploaded as they are staged (invisible until the commit);
``__exit__`` commits everything — puts, overwrites, deletes, raw rows — as
one delta-log action list, so readers observe either all of the batch or
none of it. An exception inside the ``with`` block abandons the batch:
uploaded files stay invisible to every snapshot (vacuum reclaims them) and
**no header is cached**, which is the fix for the old put_deferred
staleness bug where a failed batched commit left a poisoned header cache.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .store import DeltaTensorStore


class BatchClosedError(RuntimeError):
    pass


class WriteBatch:
    """Stages puts/deletes against one base snapshot; commits atomically.

    The base catalog is pinned at the first staging call: every
    existence/overwrite/delete lookup in this batch resolves against that
    one snapshot, so what a batch removes does not shift under a
    concurrent writer. (The final commit itself is the delta log's
    optimistic append — a racing commit between pin and land can still
    interleave; serializable writers should fence with
    ``table.commit_adds(..., expected_version=...)`` semantics instead.)
    """

    def __init__(self, store: "DeltaTensorStore", *, op: str = "WRITE BATCH"):
        self._store = store
        self.op = op
        self._adds: List[Dict[str, Any]] = []
        self._removes: List[str] = []
        # header seeds applied to the store's by-path cache ONLY on a
        # successful commit (never for an abandoned batch)
        self._header_seeds: List[tuple] = []
        self._staged_tids: List[str] = []
        self._base = None  # catalog pinned at first staging call
        self._closed = False
        self.version: Optional[int] = None  # set by commit()

    # -- staging ---------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise BatchClosedError("WriteBatch already committed or abandoned")

    def put(self, tensor: Any, *, layout: str = "auto",
            tensor_id: Optional[str] = None, overwrite: bool = False,
            target_file_bytes: Optional[int] = None, **codec_params) -> str:
        """Stage one tensor; returns its id. Files upload now, commit later."""
        self._check_open()
        layout, tid = self._store._resolve_tid(tensor, layout, tensor_id)
        # all checks run BEFORE any byte uploads: a rejected put must not
        # cost encode+upload bandwidth or leave orphaned invisible files
        if tid in self._staged_tids:
            raise ValueError(f"tensor {tid!r} staged twice in one batch")
        existing = self._existing_paths(tid)
        if existing and not overwrite:
            raise ValueError(
                f"tensor {tid!r} already exists (use overwrite=True)")
        adds, header_seed = self._store._encode_and_upload(
            tensor, layout=layout, tensor_id=tid,
            target_file_bytes=target_file_bytes, **codec_params)
        self._removes.extend(existing)
        self._adds.extend(adds)
        if header_seed is not None:
            self._header_seeds.append(header_seed)
        self._staged_tids.append(tid)
        return tid

    def delete(self, tid: str, *, missing_ok: bool = False) -> None:
        """Stage removal of every file of ``tid`` (header + chunks)."""
        self._check_open()
        paths = self._existing_paths(tid)
        if not paths and not missing_ok:
            raise KeyError(f"tensor {tid!r} not found")
        self._removes.extend(paths)

    def add_rows(self, columns: Dict[str, Any], *,
                 partition_values: Optional[Dict[str, str]] = None) -> None:
        """Stage one raw table file (e.g. a checkpoint manifest row)."""
        self._check_open()
        self._adds.append(self._store.table.append(
            columns, commit=False, partition_values=partition_values or {}))

    def _existing_paths(self, tid: str) -> List[str]:
        if self._base is None:
            self._base = self._store.catalog()   # pin the base snapshot
        return self._base.entry(tid).paths if tid in self._base else []

    # -- terminal states -------------------------------------------------------

    @property
    def staged(self) -> List[str]:
        return list(self._staged_tids)

    def commit(self) -> int:
        """Land every staged action in one atomic delta commit."""
        self._check_open()
        self._closed = True
        if not self._adds and not self._removes:
            self.version = self._store.table.version()
            return self.version
        self.version = self._store.table.commit_adds(
            self._adds, removes=self._removes, op=self.op)
        # headers become cacheable only now: the data is visible and the
        # header file path is immutable, so this can never go stale
        for path, cols in self._header_seeds:
            self._store._seed_header(path, cols)
        return self.version

    def abandon(self) -> None:
        """Drop the batch; uploaded part files remain invisible."""
        self._closed = True

    def __enter__(self) -> "WriteBatch":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abandon()
        elif not self._closed:
            self.commit()
