"""Sparsity policy (paper §IV.B): the 10 % rule of thumb.

Tensors whose non-zero fraction is below ``SPARSE_THRESHOLD`` get a sparse
encoding; everything else goes to FTSF (plain chunked). The threshold is a
config knob because the paper frames it as an application-specific
time/space trade-off.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np

from .encodings.base import SparseCOO

SPARSE_THRESHOLD = 0.10
# FROSTT-style heavy sparsity where per-element COO beats block formats
VERY_SPARSE_THRESHOLD = 1e-4


def density(tensor: Any) -> float:
    """Non-zero fraction in [0, 1] (0.0 for empty tensors)."""
    if isinstance(tensor, SparseCOO):
        return tensor.density
    x = np.asarray(tensor)
    total = x.size
    return (np.count_nonzero(x) / total) if total else 0.0


def choose_layout(tensor: Any, *, threshold: float = SPARSE_THRESHOLD,
                  prefer: Optional[str] = None) -> str:
    """Paper default policy: FTSF for general tensors, BSGS for sparse.

    BSGS is the paper's recommendation for sparse read paths (best Cr and
    read times, Figs. 13/15/16); callers that are write-bound can pass
    ``prefer='csf'`` (fastest writes, Fig. 14).
    """
    if prefer is not None:
        return prefer
    d = density(tensor)
    if d > threshold:
        return "ftsf"
    return "bsgs"
