"""Snapshot-pinned tensor catalog + lazy TensorRef handles.

The eager ``DeltaTensorStore.get/get_slice`` paths used to re-walk the full
``table.files()`` list on every access: O(files) metadata work per read, and
two reads in one burst could observe different table versions. The
:class:`Catalog` fixes both: it is built **once per snapshot** by a single
pass over the add-actions and indexes tensor-id -> (layout, header
add-action, chunk add-actions), so every subsequent read is an O(1) dict
lookup against one immutable table version.

:class:`TensorRef` is the lazy handle the redesigned public API returns
(``store.open(tid)``): metadata properties (``shape``/``dtype``/``layout``/
``nbytes``) touch at most the 1-row header file, numpy-style
``__getitem__`` maps int/slice/Ellipsis onto the paper's read-slice
operation, and ``read_async`` fans the chunk fetches out on the shared
:class:`~repro.lake.io.ReadExecutor` work pool. Refs opened from one
catalog are snapshot-consistent with each other by construction — the Deep
Lake / NeurStore "view over a pinned commit" model.

On a **sharded** store the catalog is the merged cross-shard index: it is
built from one snapshot per shard table and pinned to the resulting
*version vector* (``catalog.version == (v0, v1, ...)``). Each entry
remembers its shard, so refs route fetches to the right shard table while
consumers see one flat tensor namespace. One logical snapshot = one tuple
of shard versions; there is no single total order across shards.

**Spilled indexes** (the NeurStore move: keep the index beside the data):
past a file-count threshold the store writes the per-tensor grouping of a
committed shard snapshot to ``<table>/_catalog/<version>.index.json``. A
catalog built for a spilled version is then ONE object get + a dict load
(:class:`ShardSource` with ``index`` set) instead of a full snapshot walk
(log replay + O(files) classification); absent indexes fall back to the
walk transparently. :func:`build_catalog_index` defines the format.

**Leases**: every :class:`TensorRef` acquires a
:class:`~repro.core.leases.Lease` on its catalog's version vector at
construction and releases it on ``close()`` / context-manager exit / GC,
so ``store.vacuum()`` never deletes files a live ref still needs.
"""

from __future__ import annotations

import weakref
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Any, Dict, Iterator, List, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from ..lake import columnar
from ..lake.io import ReadExecutor, content_cache_key
from ..lake.log import Snapshot
from ..lake.table import Filters, file_overlaps, filter_rows, physical_path
from .encodings.base import (SparseCOO, get_codec, header_dtype,
                             header_shape, normalize_slices)

if TYPE_CHECKING:  # pragma: no cover - import cycle is typing-only
    from .store import DeltaTensorStore

CATALOG_INDEX_FORMAT = 1


def build_catalog_index(snapshot: Snapshot) -> Dict[str, Any]:
    """The spilled form of one shard snapshot's tensor grouping.

    Deterministic for a given snapshot (add-actions walk in sorted path
    order), so re-spilling a version is idempotent and an index-built
    catalog is bit-for-bit identical to a walk-built one.
    """
    tensors: Dict[str, Dict[str, Any]] = {}
    for add in snapshot.add_actions():
        pv = add.get("partitionValues") or {}
        tid = pv.get("tensor")
        if tid is None:
            continue  # non-tensor rows (e.g. checkpoint manifests)
        rec = tensors.setdefault(
            tid, {"layout": pv.get("layout", "?"), "header": [], "chunks": []})
        key = "header" if pv.get("kind") == "header" else "chunks"
        rec[key].append(add)
    return {"format": CATALOG_INDEX_FORMAT, "version": snapshot.version,
            "files": len(snapshot.files), "tensors": tensors}


@dataclass(frozen=True)
class ShardSource:
    """One shard's contribution to a catalog: a walked snapshot OR a
    loaded spilled index (exactly one of the two is set)."""

    version: int
    snapshot: Optional[Snapshot] = None
    index: Optional[Dict[str, Any]] = None


@dataclass
class TensorEntry:
    """One tensor's add-actions inside a single (shard) snapshot."""

    tensor_id: str
    layout: str
    shard: int = 0
    header_adds: List[Dict[str, Any]] = field(default_factory=list)
    chunk_adds: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def nbytes(self) -> int:
        """Stored bytes across this tensor's files (compressed size for
        frame-compressed files — what the object store actually holds)."""
        return (sum(a["size"] for a in self.header_adds) +
                sum(a["size"] for a in self.chunk_adds))

    @property
    def paths(self) -> List[str]:
        """Relative file paths of every header + chunk add-action."""
        return [a["path"] for a in self.header_adds + self.chunk_adds]


class Catalog:
    """Immutable tensor index over one logical snapshot (1+ shard snapshots).

    Built in one O(files) pass per shard; every lookup afterwards is O(1).
    The store caches catalogs per version vector (snapshots never change),
    so a read burst pays the walk once, not once per read. On a sharded
    store the per-shard indexes merge into one flat namespace — the stable
    router guarantees a tensor lives in exactly one shard, so the merge is
    collision-free by construction.
    """

    def __init__(self, store: "DeltaTensorStore",
                 sources: Union[Snapshot, ShardSource,
                                Sequence[Union[Snapshot, ShardSource]]]):
        self._store = store
        if isinstance(sources, (Snapshot, ShardSource)):
            sources = [sources]
        self._sources: Tuple[ShardSource, ...] = tuple(
            s if isinstance(s, ShardSource)
            else ShardSource(version=s.version, snapshot=s)
            for s in sources)
        self._versions: Tuple[int, ...] = tuple(s.version for s in self._sources)
        self._entries: Dict[str, TensorEntry] = {}
        self._headers: Dict[str, Dict[str, Any]] = {}  # tid -> parsed header
        for shard, source in enumerate(self._sources):
            if source.index is not None:
                # spilled path: the grouping work was done at write time
                for tid, rec in source.index["tensors"].items():
                    self._entries[tid] = TensorEntry(
                        tensor_id=tid, layout=rec["layout"], shard=shard,
                        header_adds=list(rec["header"]),
                        chunk_adds=list(rec["chunks"]))
                continue
            for add in source.snapshot.add_actions():
                pv = add.get("partitionValues", {}) or {}
                tid = pv.get("tensor")
                if tid is None:
                    continue  # non-tensor rows (e.g. checkpoint manifests)
                entry = self._entries.get(tid)
                if entry is None:
                    entry = self._entries[tid] = TensorEntry(
                        tensor_id=tid, layout=pv.get("layout", "?"),
                        shard=shard)
                if pv.get("kind") == "header":
                    entry.header_adds.append(add)
                else:
                    entry.chunk_adds.append(add)

    # -- inventory -----------------------------------------------------------

    @property
    def version(self) -> Union[int, Tuple[int, ...]]:
        """Pinned version: an int on 1-shard stores (the pre-sharding API),
        a per-shard version vector tuple on sharded stores."""
        if len(self._versions) == 1:
            return self._versions[0]
        return self.version_vector

    @property
    def version_vector(self) -> Tuple[int, ...]:
        """Per-shard pinned versions (1-tuple on unsharded stores)."""
        return self._versions

    @property
    def n_shards(self) -> int:
        """How many shard snapshots this catalog merges (1 if unsharded)."""
        return len(self._versions)

    def table_for(self, shard: int):
        """The shard's :class:`~repro.lake.table.DeltaTable`."""
        return self._store.tables[shard]

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, tid: str) -> bool:
        return tid in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def tensors(self) -> List[Tuple[str, str]]:
        """Sorted ``(tensor_id, layout)`` pairs — the old list_tensors."""
        return sorted((t, e.layout) for t, e in self._entries.items())

    def entry(self, tid: str) -> TensorEntry:
        """The tensor's add-action grouping; raises ``KeyError`` with the
        pinned version in the message when ``tid`` is absent."""
        try:
            return self._entries[tid]
        except KeyError:
            raise KeyError(f"tensor {tid!r} not found at v{self.version}") from None

    # -- header access ---------------------------------------------------------

    def header(self, tid: str) -> Dict[str, Any]:
        """Parsed 1-row header columns; fetched once per (snapshot, tensor).

        Header files are immutable and content-named, so the store-level
        by-path cache (seeded by committed writes) and the executor block
        cache both apply; a warm ref never touches the object store.
        """
        cols = self._headers.get(tid)
        if cols is not None:
            return cols
        entry = self.entry(tid)
        if not entry.header_adds:
            raise KeyError(f"tensor {tid!r}: no header at v{self.version}")
        add = entry.header_adds[0]
        cols = self._store._header_for_path(add["path"], shard=entry.shard)
        self._headers[tid] = cols
        return cols

    # -- handles ---------------------------------------------------------------

    def open(self, tid: str) -> "TensorRef":
        """A lazy :class:`TensorRef` pinned to this catalog's snapshot."""
        return TensorRef(self, self.entry(tid))

    # -- cross-tensor fetch scheduling ----------------------------------------

    def plan_many(self, requests: Sequence[Tuple[str, Optional[Sequence]]],
                  *, io: Optional["ReadExecutor"] = None) -> "FetchPlan":
        """Build ONE merged fetch plan for many ``(tid, slices)`` requests.

        Each request is a tensor id plus an optional per-axis slice list
        (``None`` = full read, same spec :meth:`TensorRef.read_slice`
        takes). Per request the codec's pushdown prunes chunk files
        exactly as a single read would; then the surviving object keys
        across ALL requests merge into one deduplicated fetch list in
        first-occurrence order — a chunk file shared by several requests
        (two slices of one tensor, or a batch's worth of adjacent rows)
        is fetched and decoded exactly once per plan. This is the paper's
        read-slice pruning lifted from one tensor to a whole batch /
        param-tree load.

        Keys resolve through :func:`~repro.lake.table.physical_path`, so
        deduplicated add-actions (several logical files aliasing one
        content-addressed object) merge into a single fetch, and the
        block-cache names carry each object's content hash. Delta-stored
        files additionally contribute their **base object keys** to the
        plan: bases are prepended to ``unique_keys`` so they land in the
        block cache before any delta frame that reconstructs against
        them — the executor's inline base fetch then hits cache instead
        of issuing a nested get per delta file.
        """
        # headers drive spec normalization and every decode; warm the
        # uncached ones concurrently rather than one RTT at a time. The
        # warm-up goes through the I/O pool (fetch_ordered into the block
        # cache, then header() parses from cache), NOT the work pool —
        # plan_many may itself be running inside a work-pool job (a
        # stream-loader batch fetch) and a work-on-work wait could
        # deadlock a saturated pool.
        io = io or self._store.io
        if io.cache.capacity:
            keys = []
            for tid in dict.fromkeys(t for t, _ in requests):
                if tid in self._headers:
                    continue
                entry = self.entry(tid)
                if not entry.header_adds:
                    continue
                path = entry.header_adds[0]["path"]
                if path in self._store._headers_by_path:
                    continue
                keys.append(f"{self.table_for(entry.shard).path}/{path}")
            if len(keys) > 1:
                for _ in io.fetch_ordered(self.table_for(0).store, keys):
                    pass
        reqs: List[PlanRequest] = []
        names: Dict[str, Optional[str]] = {}      # key -> block-cache name
        base_keys: Dict[str, Optional[str]] = {}  # delta base key -> name
        for tid, slices in requests:
            entry = self.entry(tid)
            codec = get_codec(entry.layout)
            header = self.header(tid)
            spec = filters = None
            adds = entry.chunk_adds
            if slices is not None:
                if not codec.supports_slice:
                    raise NotImplementedError(
                        f"layout {entry.layout!r} does not support slice reads")
                spec = normalize_slices(header_shape(header),
                                        [_as_spec_item(s) for s in slices])
                filters = codec.slice_filters(header, spec) or None
                adds = [a for a in adds if file_overlaps(a, filters)]
            table = self.table_for(entry.shard)
            keys: List[str] = []
            for a in adds:
                k = f"{table.path}/{physical_path(a)}"
                if k not in names:
                    keys.append(k)
                    ch = a.get("contentHash")
                    names[k] = content_cache_key(ch) if ch else None
                elif k not in keys:
                    keys.append(k)  # cross-request alias, new to this request
                db = a.get("deltaBase")
                if db:
                    bh = a.get("deltaBaseHash")
                    base_keys.setdefault(
                        db, content_cache_key(bh) if bh else None)
            reqs.append(PlanRequest(tid=tid, codec=codec, spec=spec,
                                    filters=filters, keys=keys))
        seen: Dict[str, None] = {}
        total = 0
        for r in reqs:
            total += len(r.keys)
            for k in r.keys:
                seen[k] = None
        deduped = total - len(seen)
        # bases FIRST: by the time a delta frame decodes, its base bytes
        # are already block-cached (windowed fetch_ordered preserves order)
        merged: Dict[str, None] = dict.fromkeys(base_keys)
        merged.update(seen)
        unique = list(merged)
        cache_names = [names.get(k) or base_keys.get(k) for k in unique]
        return FetchPlan(requests=reqs, unique_keys=unique,
                         keys_deduped=deduped, cache_names=cache_names)

    def read_many(self, requests: Sequence[Tuple[str, Optional[Sequence]]],
                  *, window: Optional[int] = None,
                  io: Optional["ReadExecutor"] = None,
                  cache_partition: Optional[str] = None,
                  device: bool = False) -> List[np.ndarray]:
        """Read many tensors/slices through one merged fetch plan.

        The plan's unique keys stream through the shared executor's
        windowed :meth:`~repro.lake.io.ReadExecutor.fetch_ordered`, so
        decode of file *k* overlaps the wire fetch of files > *k*; each
        arriving file is decoded ONCE and handed to every request that
        wanted it (with that request's own row filters), and a request's
        final codec decode runs as soon as its last file lands — not
        after the whole plan drains. Results come back in request order.

        The read holds a **lease** on this catalog's version vector for
        its duration (no :class:`TensorRef` is constructed here), so a
        concurrent vacuum cannot delete planned files mid-plan.

        ``window`` bounds outstanding gets (the stream loader's
        backpressure); None uses the executor default. ``io`` overrides
        the store's shared executor (width sweeps, a caller-owned pool);
        ``cache_partition`` routes fetched blocks into that block-cache
        priority class (the gateway pins hot base-model weights this way).
        ``device=True`` finishes each request through the codec's
        ``decode_device`` path (see :meth:`TensorRef.read_device`), so
        results are jax device buffers assembled without an ordered
        full-tensor host copy.
        """
        io = io or self._store.io
        plan = self.plan_many(requests, io=io)
        io.stats.bump(plans=1, plan_requests=len(plan.requests),
                      plan_keys_fetched=len(plan.unique_keys),
                      plan_keys_deduped=plan.keys_deduped)
        results: List[Optional[np.ndarray]] = [None] * len(plan.requests)
        received: List[Dict[str, Dict[str, Any]]] = [{} for _ in plan.requests]
        waiting: Dict[str, List[int]] = {}
        for i, r in enumerate(plan.requests):
            for k in r.keys:
                waiting.setdefault(k, []).append(i)

        def finish(i: int) -> None:
            r = plan.requests[i]
            groups = [self.header(r.tid)]
            groups.extend(received[i][k] for k in r.keys)  # request's order
            if device:
                out, info = r.codec.decode_device(groups, r.spec)
                if info.on_device:
                    io.stats.bump(bytes_to_device=info.device_bytes)
                results[i] = out
            else:
                results[i] = (r.codec.decode(groups) if r.spec is None
                              else r.codec.decode_slice(groups, r.spec))
            received[i].clear()

        lease = self._store.leases.acquire(self.version_vector)
        try:
            for i, r in enumerate(plan.requests):
                if not r.keys:
                    finish(i)  # fully pruned (or chunkless) request
            store = self.table_for(0).store
            fetched = io.fetch_ordered(store, plan.unique_keys, window=window,
                                       cache_names=plan.cache_names or None,
                                       cache_partition=cache_partition)
            for key, data in zip(plan.unique_keys, fetched):
                waiters = waiting.get(key, ())
                if not waiters:
                    continue  # base-object prefetch: block-cached for deltas
                batch = columnar.read_table(data)
                for i in waiters:
                    r = plan.requests[i]
                    received[i][key] = filter_rows(batch, r.filters)
                    if len(received[i]) == len(r.keys):
                        finish(i)
        finally:
            lease.release()
        return results  # type: ignore[return-value]


@dataclass
class PlanRequest:
    """One request's slot in a :class:`FetchPlan`."""

    tid: str
    codec: Any
    spec: Optional[List[Tuple[int, int]]]     # normalized; None = full read
    filters: Optional[Filters]                # row-level pushdown predicate
    keys: List[str]                           # full object keys, add order

    @property
    def n_keys(self) -> int:
        """Chunk files this request needs (post-pruning)."""
        return len(self.keys)


@dataclass
class FetchPlan:
    """A merged cross-tensor fetch plan (see :meth:`Catalog.plan_many`)."""

    requests: List[PlanRequest]
    unique_keys: List[str]                    # bases first, then deduped keys
    keys_deduped: int                         # references merged away
    # per-key block-cache names (content-hash based where known), aligned
    # with unique_keys; empty on plans built before the CAS subsystem
    cache_names: List[Optional[str]] = field(default_factory=list)

    @property
    def n_fetches(self) -> int:
        """Object gets this plan will issue."""
        return len(self.unique_keys)


def _as_spec_item(x: Any) -> Optional[Tuple[int, int]]:
    """Accept the legacy per-axis form: None or an (lo, hi) pair."""
    if x is None:
        return None
    lo, hi = x
    return int(lo), int(hi)


class TensorRef:
    """Lazy, snapshot-pinned handle to one stored tensor.

    Nothing is fetched at construction. Metadata properties read (and cache)
    only the tiny header file; ``read``/``read_slice``/``read_coo`` run the
    paper's read-tensor / read-slice operations against the pinned snapshot,
    pruning chunk files via codec pushdown before fanning fetches out on the
    shared executor. ``__getitem__`` gives the numpy view of the same thing.

    Construction acquires a **lease** on the pinned version vector, which
    ``store.vacuum()`` honors: the snapshot's files cannot be deleted under
    a live ref. ``close()`` (or context-manager exit, or garbage collection
    via a weakref finalizer) releases it; reads after close still work but
    are no longer protected from maintenance.
    """

    def __init__(self, catalog: Catalog, entry: TensorEntry):
        self._catalog = catalog
        self._entry = entry
        self._lease = catalog._store.leases.acquire(catalog.version_vector)
        # GC backstop: a dropped ref must not pin its snapshot forever
        self._finalizer = weakref.finalize(self, self._lease.release)

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        """Release this ref's snapshot lease (idempotent)."""
        self._finalizer()

    @property
    def closed(self) -> bool:
        """Whether the snapshot lease has been released."""
        return not self._finalizer.alive

    def __enter__(self) -> "TensorRef":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- metadata (header-only) ------------------------------------------------

    @property
    def tensor_id(self) -> str:
        """The stored tensor's id."""
        return self._entry.tensor_id

    @property
    def layout(self) -> str:
        """Storage codec name (ftsf/coo/csr/csf/bsgs)."""
        return self._entry.layout

    @property
    def shard(self) -> int:
        """Shard table this tensor's files live in (0 on unsharded stores)."""
        return self._entry.shard

    @property
    def version(self) -> Union[int, Tuple[int, ...]]:
        """Pinned version: table version, or the version vector if sharded."""
        return self._catalog.version

    @property
    def header(self) -> Dict[str, Any]:
        """Parsed 1-row header columns (cached per snapshot)."""
        return self._catalog.header(self.tensor_id)

    @property
    def shape(self) -> Tuple[int, ...]:
        """Dense shape, from the header only (no chunk fetches)."""
        return header_shape(self.header)

    @property
    def dtype(self) -> np.dtype:
        """Element dtype, from the header only (no chunk fetches)."""
        return header_dtype(self.header)

    @property
    def ndim(self) -> int:
        """Tensor rank."""
        return len(self.shape)

    @property
    def nbytes(self) -> int:
        """Stored bytes across this tensor's files (encoded size)."""
        return self._entry.nbytes

    @property
    def n_chunk_files(self) -> int:
        """How many chunk data files back this tensor at this snapshot."""
        return len(self._entry.chunk_adds)

    @property
    def codec(self):
        """The layout's :class:`~repro.core.encodings.base.Codec`."""
        return get_codec(self.layout)

    def __repr__(self) -> str:
        return (f"TensorRef({self.tensor_id!r}, layout={self.layout!r}, "
                f"version={self.version})")

    # -- reads -----------------------------------------------------------------

    def _groups(self, filters: Optional[Filters] = None) -> List[Dict[str, Any]]:
        """Header + surviving chunk batches, fetched concurrently."""
        table = self._catalog.table_for(self._entry.shard)
        adds = [a for a in self._entry.chunk_adds if file_overlaps(a, filters)]
        groups: List[Dict[str, Any]] = [self.header]
        groups.extend(table.fetch_adds(adds, filters=filters))
        return groups

    def read(self) -> np.ndarray:
        """Full dense read (the paper's read-tensor)."""
        return self.codec.decode(self._groups())

    def read_coo(self) -> SparseCOO:
        """Sparse COO read; native when the codec supports it."""
        if self.codec.supports_coo:
            return self.codec.decode_coo(self._groups())
        return SparseCOO.from_dense(self.read())

    def read_slice(self, slices: Sequence[Optional[Tuple[int, int]]]) -> np.ndarray:
        """The paper's read-slice: codec pushdown prunes chunk files first."""
        codec = self.codec
        if not codec.supports_slice:
            raise NotImplementedError(
                f"layout {self.layout!r} does not support slice reads")
        spec = normalize_slices(self.shape, [_as_spec_item(s) for s in slices])
        filters = codec.slice_filters(self.header, spec)
        return codec.decode_slice(self._groups(filters or None), spec)

    def read_device(self, slices: Optional[Sequence] = None, *,
                    with_info: bool = False,
                    use_pallas: Optional[bool] = None):
        """Read straight into a jax device buffer (numpy when jax can't).

        FTSF reads stage chunk payloads once and reorder on the device via
        ``block_gather``; COO reads scatter sparse pairs on the device via
        ``coo_scatter`` — neither materializes an ordered full tensor on
        the host. Other layouts (and dtypes jax cannot hold bit-exactly,
        e.g. float64 without ``jax_enable_x64``) take the documented
        host-decode fallback. ``slices`` matches :meth:`read_slice`;
        ``with_info=True`` additionally returns the
        :class:`~repro.lake.device.DeviceReadInfo` accounting.
        """
        codec = self.codec
        if slices is None:
            out, info = codec.decode_device(self._groups(),
                                            use_pallas=use_pallas)
        else:
            if not codec.supports_slice:
                raise NotImplementedError(
                    f"layout {self.layout!r} does not support slice reads")
            spec = normalize_slices(self.shape,
                                    [_as_spec_item(s) for s in slices])
            filters = codec.slice_filters(self.header, spec)
            out, info = codec.decode_device(self._groups(filters or None),
                                            spec, use_pallas=use_pallas)
        if info.on_device:
            self._catalog._store.io.stats.bump(
                bytes_to_device=info.device_bytes)
        return (out, info) if with_info else out

    def __getitem__(self, item: Any) -> np.ndarray:
        """Numpy-style lazy slicing: ints, contiguous slices, Ellipsis.

        ``ref[3]``, ``ref[1:4, :, 2]``, ``ref[..., 0:2]`` all map onto
        :meth:`read_slice`; integer axes are squeezed like numpy would.
        """
        spec, squeeze = self._item_to_spec(item)
        out = self.read_slice(spec)
        return out[tuple(0 if d in squeeze else slice(None)
                         for d in range(out.ndim))] if squeeze else out

    def _item_to_spec(self, item: Any):
        shape = self.shape
        items = list(item) if isinstance(item, tuple) else [item]
        if items.count(Ellipsis) > 1:
            raise IndexError("an index can only have a single ellipsis")
        if Ellipsis in items:
            i = items.index(Ellipsis)
            fill = len(shape) - (len(items) - 1)
            if fill < 0:
                raise IndexError(f"too many indices for rank {len(shape)}")
            items[i:i + 1] = [slice(None)] * fill
        if len(items) > len(shape):
            raise IndexError(f"too many indices for rank {len(shape)}")
        spec: List[Optional[Tuple[int, int]]] = []
        squeeze: List[int] = []
        for d, it in enumerate(items):
            dim = shape[d]
            if isinstance(it, (int, np.integer)):
                i = int(it) + dim if int(it) < 0 else int(it)
                if not 0 <= i < dim:
                    raise IndexError(
                        f"index {int(it)} out of bounds for axis {d} (size {dim})")
                spec.append((i, i + 1))
                squeeze.append(d)
            elif isinstance(it, slice):
                if it.step not in (None, 1):
                    raise IndexError("TensorRef slicing is contiguous (step=1)")
                lo = 0 if it.start is None else int(it.start)
                hi = dim if it.stop is None else int(it.stop)
                spec.append((lo, hi))
            else:
                raise TypeError(f"unsupported index {it!r}")
        return spec, squeeze

    # -- async -----------------------------------------------------------------

    def read_async(self, slices: Optional[Sequence] = None) -> "Future[np.ndarray]":
        """Future of :meth:`read` (or :meth:`read_slice`) on the executor.

        Runs in the executor's work pool; the chunk fetches inside fan out
        on the I/O pool, so many refs can be resolved concurrently (serve
        weight loads, checkpoint restores) without private threads.
        """
        io = self._catalog._store.io
        if slices is None:
            return io.submit(self.read)
        return io.submit(self.read_slice, slices)

    def read_coo_async(self) -> "Future[SparseCOO]":
        """Future of :meth:`read_coo` on the executor work pool."""
        return self._catalog._store.io.submit(self.read_coo)
