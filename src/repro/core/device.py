"""Device-side (jit-compatible) tensor encodings.

JAX programs need static shapes, so the device variants of the paper's
codecs carry a fixed ``capacity`` plus a live count — the standard TPU
treatment of dynamic sparsity. These are the pure-jnp reference paths; the
Pallas kernels in ``repro.kernels`` implement the same contracts with
explicit VMEM tiling and are validated against these functions.

Used in-training by:
* gradient compression (``bsgs_topk`` + ``bsgs_decode``) before the
  cross-pod all-reduce;
* on-device materialization of sparse batches read from the store.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class DeviceCOO(NamedTuple):
    """Fixed-capacity on-device COO carrier (padding = index == size)."""

    flat_indices: jax.Array  # (capacity,) int32/int64; == size => padding
    values: jax.Array        # (capacity,)
    nnz: jax.Array           # () int32, clamped to capacity


class DeviceBlocks(NamedTuple):
    """Fixed-capacity on-device block-sparse carrier (BSGS)."""

    block_ids: jax.Array     # (capacity,) flattened block-grid ids; == n_blocks => pad
    blocks: jax.Array        # (capacity, block_elems)
    count: jax.Array         # () int32


# ---------------------------------------------------------------------------
# COO
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("capacity",))
def coo_encode(x: jax.Array, capacity: int) -> DeviceCOO:
    """Dense -> fixed-capacity COO (extra non-zeros are truncated)."""
    flat = x.reshape(-1)
    size = flat.shape[0]
    idx = jnp.flatnonzero(flat != 0, size=capacity, fill_value=size)
    vals = jnp.where(idx < size, flat[jnp.clip(idx, 0, size - 1)], 0)
    nnz = jnp.minimum(jnp.sum(flat != 0), capacity).astype(jnp.int32)
    return DeviceCOO(idx.astype(jnp.int32) if size < 2**31 else idx, vals, nnz)


@partial(jax.jit, static_argnames=("shape",))
def coo_decode(coo: DeviceCOO, shape: Tuple[int, ...]) -> jax.Array:
    """COO -> dense of ``shape`` (padding entries dropped)."""
    size = math.prod(shape)
    flat = jnp.zeros((size,), dtype=coo.values.dtype)
    # mode="drop" discards the out-of-range padding entries
    flat = flat.at[coo.flat_indices].set(coo.values, mode="drop")
    return flat.reshape(shape)


# ---------------------------------------------------------------------------
# blocks: shared reshape helpers
# ---------------------------------------------------------------------------


def _block_view_shape(shape: Sequence[int], bs: Sequence[int]):
    """Interleaved (g0,b0,g1,b1,...) shape + permutation to (g..., b...)."""
    nd = len(shape)
    grid = tuple(-(-s // b) for s, b in zip(shape, bs))
    inter = tuple(v for d in range(nd) for v in (grid[d], bs[d]))
    perm = tuple(2 * d for d in range(nd)) + tuple(2 * d + 1 for d in range(nd))
    return grid, inter, perm


def blockify(x: jax.Array, block_shape: Sequence[int]) -> jax.Array:
    """(… dense …) -> (n_blocks, block_elems), zero-padding ragged edges."""
    bs = tuple(block_shape)
    shape = x.shape
    grid, inter, perm = _block_view_shape(shape, bs)
    pads = [(0, g * b - s) for g, b, s in zip(grid, bs, shape)]
    xp = jnp.pad(x, pads)
    xv = xp.reshape(inter).transpose(perm)
    return xv.reshape(math.prod(grid), math.prod(bs))


def unblockify(blocks: jax.Array, shape: Sequence[int],
               block_shape: Sequence[int]) -> jax.Array:
    """Inverse of :func:`blockify`; crops the zero padding back off."""
    bs = tuple(block_shape)
    grid, inter, perm = _block_view_shape(shape, bs)
    inv = np.argsort(perm)
    xv = blocks.reshape(grid + bs).transpose(tuple(inv))
    xp = xv.reshape(tuple(g * b for g, b in zip(grid, bs)))
    return xp[tuple(slice(0, s) for s in shape)]


# ---------------------------------------------------------------------------
# BSGS: exact non-zero-block encoding
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("block_shape", "capacity"))
def bsgs_encode(x: jax.Array, block_shape: Tuple[int, ...], capacity: int) -> DeviceBlocks:
    """Keep every non-zero block, up to ``capacity`` (exact encoding)."""
    bv = blockify(x, block_shape)
    n_blocks = bv.shape[0]
    nonzero = jnp.any(bv != 0, axis=1)
    ids = jnp.flatnonzero(nonzero, size=capacity, fill_value=n_blocks)
    gathered = bv[jnp.clip(ids, 0, n_blocks - 1)]
    gathered = jnp.where((ids < n_blocks)[:, None], gathered, 0)
    count = jnp.minimum(jnp.sum(nonzero), capacity).astype(jnp.int32)
    return DeviceBlocks(ids.astype(jnp.int32), gathered, count)


@partial(jax.jit, static_argnames=("shape", "block_shape"))
def bsgs_decode(db: DeviceBlocks, shape: Tuple[int, ...],
                block_shape: Tuple[int, ...]) -> jax.Array:
    """Scatter kept blocks back into a dense tensor of ``shape``."""
    grid, _, _ = _block_view_shape(shape, block_shape)
    n_blocks = math.prod(grid)
    bv = jnp.zeros((n_blocks, db.blocks.shape[1]), dtype=db.blocks.dtype)
    bv = bv.at[db.block_ids].set(db.blocks, mode="drop")
    return unblockify(bv, shape, block_shape)


# ---------------------------------------------------------------------------
# block top-k (gradient compression): keep the k highest-energy blocks
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("block_shape", "k"))
def bsgs_topk(x: jax.Array, block_shape: Tuple[int, ...], k: int) -> DeviceBlocks:
    """Lossy top-k: keep the k highest-energy blocks (grad compression)."""
    bv = blockify(x, block_shape)
    norms = jnp.sum(jnp.square(bv.astype(jnp.float32)), axis=1)
    _, ids = jax.lax.top_k(norms, k)
    ids = ids.astype(jnp.int32)
    return DeviceBlocks(ids, bv[ids], jnp.asarray(k, jnp.int32))


def compression_ratio(db: DeviceBlocks, shape: Sequence[int]) -> float:
    """Bytes kept / dense bytes — the paper's Cr, device-side."""
    kept = db.blocks.size * db.blocks.dtype.itemsize + db.block_ids.size * 4
    dense = math.prod(shape) * db.blocks.dtype.itemsize
    return kept / dense
