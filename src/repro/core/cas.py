"""Content-addressed chunk store: dedup index + refcounted lifecycle.

Serving millions of users means storing millions of fine-tuned/quantized
variants of a few base models, and the dominant space win there is
*cross-tensor* redundancy, not per-chunk codecs: NeurStore stores
identical tensor blocks once across models, and TStore delta-encodes a
variant against its base so the residue compresses to almost nothing.
This module is that layer for the lake:

* :func:`chunk_hash` (re-exported from :mod:`repro.lake.table`) addresses
  every part file by the blake2b-160 of its **decoded** bytes — codec and
  level changes never break the address;
* :class:`ChunkIndex` maps ``content hash -> ChunkEntry`` (object key,
  stored/raw sizes, codec, delta-base) per delta table.
  ``DeltaTable.append`` consults it before uploading: a hit commits an
  add-action whose ``physPath`` references the already-stored object and
  moves **zero** bytes. The index is persisted at
  ``<table>/_cas/chunks.index.json`` (under the ``_`` metadata prefix, so
  vacuum never treats it as data) alongside the ``_catalog/`` indexes,
  and reloads lazily in fresh processes;
* reference counting falls out of the delta log itself: an object is
  live while any retained/leased snapshot holds an add-action whose
  ``path``/``physPath``/``deltaBase`` names it — ``DeltaTable.vacuum``
  computes exactly that closure, so deleting a tensor reclaims only the
  chunks nothing else shares. After a vacuum the store drops the deleted
  paths from the index (:meth:`ChunkIndex.drop_paths`) and respills it.

Collision paranoia: the index stores ``(hash, raw_size)`` and a reuse hit
must match both; entries loaded from a spilled index are additionally
verified against the object store (one HEAD) the first time they are
reused, so a stale index can never alias new data onto a vanished object.
The in-process race against a concurrent vacuum is closed by
``UploadGuard.reserve`` — vacuum *condemns* its doomed paths before
deleting, and a reuse attempt on a condemned path falls back to a fresh
upload.

Existing (pre-dedup) tables migrate with
:meth:`repro.core.store.DeltaTensorStore.build_chunk_index`
(``repro.launch.gc --build-chunk-index``), which backfills the index from
the live snapshot.
"""

from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple
from weakref import WeakValueDictionary

from ..lake.io import store_scope
from ..lake.object_store import ObjectNotFoundError
from ..lake.table import UploadGuard, chunk_hash, physical_path  # noqa: F401

CHUNK_INDEX_FORMAT = 1


def chunk_index_key(table_path: str) -> str:
    """Object key of a table's spilled chunk index.

    Lives under the ``_`` metadata prefix so vacuum's data-file scan
    skips it, next to ``_catalog/`` and ``_delta_log/``.
    """
    return f"{table_path.rstrip('/')}/_cas/chunks.index.json"


@dataclass
class ChunkEntry:
    """One stored chunk: where its bytes live and how they were encoded.

    ``path`` is relative to the owning table; ``size`` the stored length
    (what an aliasing add-action must record as ``size``); ``raw_size``
    the decoded length (paired with the hash for collision paranoia).
    ``codec``/``itemsize`` mirror the original add-action so an alias
    reports honest physical accounting. Delta-stored chunks carry their
    ``delta_base`` object key (+ hash) so an alias preserves the base
    dependency vacuum's liveness scan walks. ``verified`` is False for
    entries reloaded from a spilled index until their object's existence
    has been re-checked once.
    """

    path: str
    size: int
    raw_size: int
    codec: Optional[str] = None
    itemsize: int = 1
    delta_base: Optional[str] = None
    delta_base_hash: Optional[str] = None
    verified: bool = True

    def as_record(self) -> Dict[str, Any]:
        """JSON-spillable form (verification state is not persisted)."""
        rec: Dict[str, Any] = {"path": self.path, "size": int(self.size),
                               "rawSize": int(self.raw_size)}
        if self.codec:
            rec["codec"] = self.codec
            rec["itemsize"] = int(self.itemsize)
        if self.delta_base:
            rec["deltaBase"] = self.delta_base
            if self.delta_base_hash:
                rec["deltaBaseHash"] = self.delta_base_hash
        return rec

    @classmethod
    def from_record(cls, rec: Dict[str, Any]) -> "ChunkEntry":
        """Inverse of :meth:`as_record`; loaded entries start unverified."""
        return cls(path=rec["path"], size=int(rec["size"]),
                   raw_size=int(rec.get("rawSize", rec["size"])),
                   codec=rec.get("codec"),
                   itemsize=int(rec.get("itemsize", 1)),
                   delta_base=rec.get("deltaBase"),
                   delta_base_hash=rec.get("deltaBaseHash"),
                   verified=False)


class ChunkIndex:
    """Thread-safe ``content hash -> ChunkEntry`` map for one table.

    Writers consult it through :meth:`reuse` (dedup hit = add-action
    aliasing an existing object) and feed it through :meth:`record`
    (every fresh content-hashed upload). Maintenance keeps it honest:
    vacuum calls :meth:`drop_paths` for deleted objects, and
    :meth:`spill`/:meth:`ensure_loaded` persist it across processes.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._by_hash: Dict[str, ChunkEntry] = {}
        self._by_path: Dict[str, str] = {}
        self._loaded = False
        self._dirty = False
        self.stats: Dict[str, int] = {
            "hits": 0, "misses": 0, "inserts": 0, "collisions": 0,
            "verified": 0, "verify_failures": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._by_hash)

    @property
    def dirty(self) -> bool:
        """Whether in-memory state has diverged from the spilled index."""
        with self._lock:
            return self._dirty

    # -- persistence ---------------------------------------------------------

    def ensure_loaded(self, table: Any) -> None:
        """Merge the spilled index (if any) under in-memory entries.

        One 404-tolerant get, once per process lifetime of this index.
        In-memory entries win on conflict — they are verified facts from
        this process's own uploads; spilled entries arrive unverified and
        get one existence check on first reuse.
        """
        with self._lock:
            if self._loaded:
                return
            self._loaded = True
            try:
                raw = table.store.get(chunk_index_key(table.path))
            except ObjectNotFoundError:
                return
            try:
                doc = json.loads(raw.decode("utf-8"))
            except (ValueError, UnicodeDecodeError):
                return  # corrupt index: ignore; a respill will replace it
            for h, rec in doc.get("chunks", {}).items():
                if h in self._by_hash:
                    continue
                try:
                    entry = ChunkEntry.from_record(rec)
                except (KeyError, TypeError, ValueError):
                    continue
                self._by_hash[h] = entry
                self._by_path[entry.path] = h

    def spill(self, table: Any, *, force: bool = False) -> Optional[str]:
        """Persist the index next to the table's other metadata.

        Loads the spilled state first (so a partially-warm process never
        clobbers entries it hasn't seen), skips the put when nothing
        changed since the last spill (unless ``force``), and returns the
        object key written (None when skipped).
        """
        self.ensure_loaded(table)
        with self._lock:
            if not self._dirty and not force:
                return None
            doc = {"format": CHUNK_INDEX_FORMAT,
                   "chunks": {h: e.as_record()
                              for h, e in sorted(self._by_hash.items())}}
            self._dirty = False
        key = chunk_index_key(table.path)
        table.store.put(key, json.dumps(doc, separators=(",", ":"))
                        .encode("utf-8"))
        return key

    # -- write-path hooks ----------------------------------------------------

    def reuse(self, table: Any, content_hash: str, raw_size: int, *,
              guard: Optional[UploadGuard] = None
              ) -> Optional[Dict[str, Any]]:
        """Add-action fields aliasing an existing chunk, or None.

        A hit requires the hash AND raw size to match (collision
        paranoia), the entry's object to verifiably exist (spill-loaded
        entries get one HEAD here), and — when a ``guard`` is given — a
        successful reservation of the physical path (and, for
        delta-stored chunks, of the base object in the same table), which
        a concurrently-running vacuum can refuse for paths it is about to
        delete. Any failure returns None and the caller uploads fresh
        bytes; verification failures also evict the stale entry.
        """
        self.ensure_loaded(table)
        with self._lock:
            entry = self._by_hash.get(content_hash)
            if entry is None:
                self.stats["misses"] += 1
                return None
            if entry.raw_size != int(raw_size):
                self.stats["collisions"] += 1
                return None
        if not entry.verified:
            if table.store.exists(f"{table.path}/{entry.path}"):
                with self._lock:
                    entry.verified = True
                    self.stats["verified"] += 1
            else:
                with self._lock:
                    self.stats["verify_failures"] += 1
                    if self._by_hash.get(content_hash) is entry:
                        del self._by_hash[content_hash]
                        self._by_path.pop(entry.path, None)
                        self._dirty = True
                return None
        if guard is not None:
            if not guard.reserve(entry.path):
                with self._lock:
                    self.stats["misses"] += 1
                return None
        if entry.delta_base:
            # an alias of a delta-stored chunk depends on the base object
            # too; it must be pinnable in the same table or we upload fresh
            pfx = f"{table.path}/"
            if not entry.delta_base.startswith(pfx):
                return None
            if guard is not None and \
                    not guard.reserve(entry.delta_base[len(pfx):]):
                return None
        with self._lock:
            self.stats["hits"] += 1
        fields: Dict[str, Any] = {"physPath": entry.path,
                                  "size": int(entry.size)}
        if entry.codec:
            fields["codec"] = entry.codec
            fields["rawSize"] = int(entry.raw_size)
            fields["itemsize"] = int(entry.itemsize)
        if entry.delta_base:
            fields["deltaBase"] = entry.delta_base
            if entry.delta_base_hash:
                fields["deltaBaseHash"] = entry.delta_base_hash
        return fields

    def record(self, add: Dict[str, Any]) -> None:
        """Index a freshly-uploaded add-action (first entry per hash wins).

        Aliases (``physPath``) and hash-less adds are ignored — only an
        add that physically stored its own bytes defines where a content
        hash lives.
        """
        h = add.get("contentHash")
        if not h or add.get("physPath"):
            return
        with self._lock:
            if h in self._by_hash:
                return
            entry = ChunkEntry(
                path=add["path"], size=int(add["size"]),
                raw_size=int(add.get("rawSize", add["size"])),
                codec=add.get("codec"),
                itemsize=int(add.get("itemsize", 1)),
                delta_base=add.get("deltaBase"),
                delta_base_hash=add.get("deltaBaseHash"),
                verified=True)
            self._by_hash[h] = entry
            self._by_path[entry.path] = h
            self.stats["inserts"] += 1
            self._dirty = True

    # -- maintenance hooks ---------------------------------------------------

    def drop_paths(self, paths: Iterable[str]) -> List[str]:
        """Forget entries whose objects were deleted; returns their hashes.

        Called after a vacuum with the deleted relative paths, so the
        index never hands out references to reclaimed objects (and the
        caller can evict the matching content-cache entries).
        """
        dropped: List[str] = []
        with self._lock:
            for p in paths:
                h = self._by_path.pop(p, None)
                if h is None:
                    continue
                if h in self._by_hash:
                    del self._by_hash[h]
                    dropped.append(h)
                    self._dirty = True
        return dropped

    def build_from_snapshot(self, table: Any, snapshot: Any) -> int:
        """Backfill the index from a live snapshot (store migration).

        Indexes every non-header data file the snapshot references:
        adds that recorded a ``contentHash`` enter directly; older adds
        are fetched (decoded bytes) and hashed. Existing entries win —
        rerunning is idempotent. Returns the number of new entries.
        """
        self.ensure_loaded(table)
        inserted = 0
        for a in snapshot.add_actions():
            if a.get("physPath"):
                continue  # alias: its target indexes itself
            pv = a.get("partitionValues", {}) or {}
            if pv.get("kind") == "header":
                continue  # headers are tiny, latency-critical, never dedup'd
            h = a.get("contentHash")
            raw_size = int(a.get("rawSize", a.get("size", 0)))
            if h is None:
                data = table.io.fetch(table.store,
                                      f"{table.path}/{a['path']}")
                h = chunk_hash(data)
                raw_size = len(data)
            with self._lock:
                if h in self._by_hash:
                    continue
                entry = ChunkEntry(
                    path=a["path"], size=int(a.get("size", 0)),
                    raw_size=raw_size, codec=a.get("codec"),
                    itemsize=int(a.get("itemsize", 1)),
                    delta_base=a.get("deltaBase"),
                    delta_base_hash=a.get("deltaBaseHash"),
                    verified=True)
                self._by_hash[h] = entry
                self._by_path[entry.path] = h
                self.stats["inserts"] += 1
                self._dirty = True
                inserted += 1
        return inserted


# -- per-table registry ------------------------------------------------------

_registry_lock = threading.Lock()
_chunk_indexes: "WeakValueDictionary[Tuple[Any, str], ChunkIndex]" = \
    WeakValueDictionary()


def chunk_index_for(table: Any) -> ChunkIndex:
    """The shared :class:`ChunkIndex` for one physical table.

    Keyed by ``(store scope, table path)`` — two store handles over the
    same directory dedup against one index, exactly like the lease
    registry. Weakly held: it lives as long as some table/store keeps a
    reference (``DeltaTable.cas``).
    """
    key = (store_scope(table.store), table.path.rstrip("/"))
    with _registry_lock:
        idx = _chunk_indexes.get(key)
        if idx is None:
            idx = ChunkIndex()
            _chunk_indexes[key] = idx
        return idx
