"""DeltaTensorStore — the paper's system: tensors in a delta table.

``put`` encodes a tensor with one of the five codecs and lands the row
groups as parq-lite files in a single atomic commit, partitioned by
``(tensor, kind)``. Reads go through the handle API: ``open`` returns a
snapshot-pinned lazy :class:`~repro.core.catalog.TensorRef` whose
``read``/``read_slice``/``read_coo``/``read_async`` are the paper's
read-tensor / read-slice operations; ``version=`` arguments give Delta time
travel. The legacy eager calls (``get``/``get_slice``/``get_coo``/...) are
kept as thin wrappers over ``open``.

Per-read metadata cost is O(1): a :class:`~repro.core.catalog.Catalog` is
built once per table version (one pass over ``table.files()``) and cached,
so a burst of reads shares one snapshot walk instead of paying it per call.
All chunk fetches flow through the table's shared ``ReadExecutor``
(``repro.lake.io``): surviving chunk files are fetched concurrently, decode
streams in plan order as gets complete, repeat reads hit the block cache.

Writes batch through :class:`~repro.core.batch.WriteBatch`
(``with store.batch() as b: b.put(...)``): many tensors plus deletes land
in ONE atomic commit, and headers are cached only after that commit
succeeds (an abandoned batch leaves no stale state behind).

**Write scale-out**: ``DeltaTensorStore(obj, root, shards=N)`` splits the
logical store across N shard tables, each with its own delta log — an
independent commit domain, so concurrent writers whose tensors hash to
different shards never race each other's commits (see
``repro.core.sharding``). Reads are transparent: the catalog merges all
shards into one namespace pinned to a per-shard *version vector*, and
refs route fetches to the right shard table. ``shards=1`` (the default)
keeps the exact pre-sharding byte layout: the table lives at ``root``
with no manifest, so every existing table opens unchanged.
"""

from __future__ import annotations

import json
import time
import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..lake import DeltaTable, ObjectStore, ReadExecutor, columnar
from ..lake.compression import (CompressionSpec, DeltaBase, UnknownCodecError,
                                parse_compression)
from ..lake.io import content_cache_key, get_default_executor
from ..lake.log import ObjectNotFoundError, catalog_index_key
from ..lake.table import (CompactResult, VacuumResult, chunk_hash,
                          physical_path)
from .batch import WriteBatch
from .cas import ChunkIndex, chunk_index_for
from .catalog import Catalog, ShardSource, TensorRef, build_catalog_index
from .encodings.base import SparseCOO, first_scalar, get_codec
from .leases import Lease, RetentionPolicy, lease_scope, registry_for
from .sharding import (ROUTER_ALGO, ShardRouter, load_or_init_manifest,
                       resolve_version_vector, shard_table_path)
from .sparsity import choose_layout

TARGET_FILE_BYTES = 4 << 20

MAX_CACHED_CATALOGS = 16
MAX_CACHED_HEADERS = 1024

# shard snapshots at or past this many files spill a catalog index next to
# the delta log on commit, so later Catalog.builds are one O(1) index load
# instead of an O(files) snapshot walk (None disables spilling)
DEFAULT_SPILL_THRESHOLD = 512


def _select_rows(columns: Dict[str, Any],
                 idx: Sequence[int]) -> Dict[str, Any]:
    """Row selection by (possibly reordered) index list — the variant
    path uses it to mirror a base file's chunk order exactly."""
    idx = list(idx)
    out: Dict[str, Any] = {}
    for k, v in columns.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[np.asarray(idx, dtype=np.int64)] if idx else v[:0]
        else:
            out[k] = [v[i] for i in idx]
    return out


VersionArg = Union[None, int, Sequence[int]]


class DeltaTensorStore:
    """The paper's tensor store: codec-encoded tensors in delta tables.

    See the module docstring for the architecture; ``compression`` sets
    the store's default chunk-blob codec spec (e.g. ``"zlib+shuffle"``,
    see :mod:`repro.lake.compression`) — recorded in the store manifest at
    create time so every later client agrees, overridable per ``put``.
    ``None`` defers to the manifest (raw bytes when it records nothing).

    ``dedup=True`` (the default) attaches a content-addressed chunk index
    (:mod:`repro.core.cas`) to every shard table: an upload whose decoded
    bytes hash to an already-stored chunk commits a reference to the
    existing object instead of re-uploading, and :meth:`put_variant`
    stores fine-tuned variants as XOR deltas against their base tensor.
    Deletes stay safe either way — vacuum reference-counts physical
    objects across every retained/leased snapshot.
    """

    def __init__(self, object_store: ObjectStore, root: str = "tensor_store",
                 io: Optional[ReadExecutor] = None,
                 shards: Optional[int] = None,
                 retention: Optional[RetentionPolicy] = None,
                 spill_threshold: Optional[int] = DEFAULT_SPILL_THRESHOLD,
                 compression: Union[None, str, CompressionSpec] = None,
                 dedup: bool = True):
        root = root.rstrip("/")
        self.root = root
        spec = parse_compression(compression)
        manifest = load_or_init_manifest(
            object_store, root, shards,
            retention=None if retention is None else
            {"keep_versions": retention.keep_versions,
             "ttl_s": retention.ttl_s},
            compression=None if spec is None else spec.id)
        self.shards: int = int(manifest["shards"])
        # default chunk-blob codec: explicit ctor arg > manifest > raw.
        # Reads never consult this — frames are self-describing — so a
        # store opened with any default reads any mix of codecs. A
        # manifest naming an optional codec this process lacks (zstd on
        # a stdlib-only client) therefore must not block opening: this
        # client degrades to raw writes; only an EXPLICIT ctor arg (or
        # actually decoding such a frame) raises for a missing codec.
        if spec is None and manifest.get("compression"):
            try:
                spec = parse_compression(manifest["compression"])
            except UnknownCodecError:
                spec = None
        self.compression: Optional[CompressionSpec] = \
            spec if spec is not None and spec.active else None
        # default vacuum policy: explicit ctor arg > what the store manifest
        # records (sharded stores) > keep-latest-only
        if retention is None and manifest.get("retention"):
            r = manifest["retention"]
            retention = RetentionPolicy(
                keep_versions=int(r.get("keep_versions", 1)),
                ttl_s=r.get("ttl_s"))
        self.retention = retention or RetentionPolicy()
        self.spill_threshold = spill_threshold
        # live snapshot pins: shared across every client of this physical
        # store in the process, consumed by vacuum's retention horizon
        self.leases = registry_for(lease_scope(object_store), root)
        self.router = ShardRouter(self.shards,
                                  manifest.get("router", ROUTER_ALGO))
        io = io or get_default_executor()
        if self.shards == 1:
            # unsharded: table at root itself — the pre-sharding layout
            self.tables: List[DeltaTable] = [
                DeltaTable.create(object_store, root, io=io)]
        else:
            self.tables = [
                DeltaTable.create(object_store, shard_table_path(root, i),
                                  io=io)
                for i in range(self.shards)]
        self.dedup = bool(dedup)
        if self.dedup:
            # one shared index per physical table (registry-keyed like the
            # lease registry): every client of this table in the process
            # dedups against the same map, loaded lazily from _cas/
            for t in self.tables:
                t.cas = chunk_index_for(t)
        # per-version-vector catalogs: snapshots are immutable, so a catalog
        # never goes stale; LRU-capped for long-lived many-version clients
        self._catalogs: "OrderedDict[Tuple[int, ...], Catalog]" = OrderedDict()
        # parsed headers keyed by immutable data-file path (seeded on
        # successful commits, filled on reads) — staleness-free by naming;
        # part-file names are uuid-unique, so one map covers all shards
        self._headers_by_path: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        # catalog_stats shows the O(1) metadata claim: `builds` counts
        # catalog constructions, `hits` reads served by a cached catalog,
        # `snapshot_walks` shard sources resolved by an O(files) snapshot
        # walk, `index_loads` sources resolved by a spilled catalog index
        self.catalog_stats: Dict[str, int] = {"builds": 0, "hits": 0,
                                              "snapshot_walks": 0,
                                              "index_loads": 0}
        # commit_stats shows the scale-out claim: `commits` = landed shard
        # commits, `conflicts` = CommitConflicts observed by batches,
        # `retries` = rebased re-commit attempts (see WriteBatch)
        self.commit_stats: Dict[str, int] = {"commits": 0, "conflicts": 0,
                                             "retries": 0}

    @property
    def table(self) -> DeltaTable:
        """The first (or only) shard table.

        Unsharded stores keep the old single-table API intact through this
        alias; on sharded stores it doubles as the **meta shard** that holds
        non-tensor rows (checkpoint manifests) via ``WriteBatch.add_rows``.
        """
        return self.tables[0]

    @property
    def io(self) -> ReadExecutor:
        """Shared read executor all fetches for this store go through."""
        return self.tables[0].io

    # -- catalog / handles ---------------------------------------------------

    def _concrete_vector(self, version: VersionArg) -> Tuple[int, ...]:
        """Resolve a user-facing ``version=`` to one concrete int per shard
        (``None`` entries -> that shard's latest, probed concurrently)."""
        vv = resolve_version_vector(self.shards, version)
        if all(v is not None for v in vv):
            return tuple(int(v) for v in vv)
        if self.shards == 1:
            return (self.tables[0].version() if vv[0] is None else int(vv[0]),)
        return tuple(self.io.map(
            lambda tv: tv[0].version() if tv[1] is None else int(tv[1]),
            list(zip(self.tables, vv))))

    def _shard_source(self, shard: int, version: int) -> ShardSource:
        """One shard's catalog source: spilled index if present, else walk.

        A snapshot already replayed by this client is free — use it without
        probing for an index. Otherwise try the one-get spilled index
        (written at commit time past ``spill_threshold``); on miss, fall
        back to the O(files) snapshot walk. The accounting feeds
        ``catalog_stats['snapshot_walks'/'index_loads']``.
        """
        table = self.tables[shard]
        if version < 0:
            raise ObjectNotFoundError(f"no delta table at {table.path}")
        snap = table.log.cached_snapshot(version)
        if snap is not None:
            return ShardSource(version=version, snapshot=snap)
        if self.spill_threshold is not None:
            try:
                body = self.io.fetch(table.store,
                                     catalog_index_key(table.path, version))
            except ObjectNotFoundError:
                pass
            else:
                self.catalog_stats["index_loads"] += 1
                return ShardSource(version=version, index=json.loads(body))
        self.catalog_stats["snapshot_walks"] += 1
        return ShardSource(version=version, snapshot=table.snapshot(version))

    def catalog(self, version: VersionArg = None) -> Catalog:
        """The merged tensor index at ``version`` (latest if None).

        ``version`` is an int on 1-shard stores, a per-shard version vector
        on sharded stores. O(1) when the vector is already cached; a cold
        build resolves each shard from its spilled catalog index when one
        exists (one get), else by walking the snapshot.
        """
        key = self._concrete_vector(version)
        cat = self._catalogs.get(key)
        if cat is not None:
            self.catalog_stats["hits"] += 1
            self._catalogs.move_to_end(key)
            return cat
        if self.shards == 1:
            sources = [self._shard_source(0, key[0])]
        else:
            sources = self.io.map(lambda sv: self._shard_source(*sv),
                                  list(enumerate(key)))
        cat = Catalog(self, sources)
        self.catalog_stats["builds"] += 1
        self._catalogs[key] = cat
        while len(self._catalogs) > MAX_CACHED_CATALOGS:
            self._catalogs.popitem(last=False)
        return cat

    def lease(self, version: VersionArg = None) -> Lease:
        """Pin ``version`` (latest if None) against vacuum until released.

        The refcounted pin every :class:`TensorRef` takes implicitly,
        exposed for holders that outlive any single ref — e.g. the
        checkpointer retaining its last K checkpoints.
        """
        return self.leases.acquire(self._concrete_vector(version))

    def open(self, tid: str, *, version: VersionArg = None) -> TensorRef:
        """Lazy snapshot-pinned handle; fetches nothing until read."""
        return self.catalog(version).open(tid)

    def _header_for_path(self, path: str, shard: int = 0) -> Dict[str, Any]:
        cols = self._headers_by_path.get(path)
        if cols is not None:
            self._headers_by_path.move_to_end(path)
            return cols
        table = self.tables[shard]
        data = self.io.fetch(table.store, f"{table.path}/{path}")
        cols = columnar.read_table(data)
        self._seed_header(path, cols)
        return cols

    def _seed_header(self, path: str, cols: Dict[str, Any]) -> None:
        self._headers_by_path[path] = cols
        while len(self._headers_by_path) > MAX_CACHED_HEADERS:
            self._headers_by_path.popitem(last=False)

    # -- maintenance ---------------------------------------------------------

    def _maybe_spill(self, shard: int, version: int,
                     adds_hint: Optional[int] = None) -> bool:
        """Spill the catalog index for a freshly committed shard version
        when the snapshot has crossed ``spill_threshold`` files.

        Cheap guard first: when the committer's previous snapshot is still
        cached and ``adds_hint`` (how many files the commit added) proves
        the threshold cannot have been crossed, skip without any replay —
        small stores never pay a spill probe on their commit path.
        """
        if self.spill_threshold is None:
            return False
        table = self.tables[shard]
        if adds_hint is not None:
            prev = table.log.cached_snapshot(version - 1)
            if prev is not None and \
                    len(prev.files) + adds_hint < self.spill_threshold:
                return False
        snap = table.snapshot(version)
        if len(snap.files) < self.spill_threshold:
            return False
        self._spill_index(table, snap)
        return True

    def _spill_index(self, table: DeltaTable, snap) -> None:
        body = json.dumps(build_catalog_index(snap),
                          separators=(",", ":")).encode("utf-8")
        # plain put: content is deterministic per version, so a racing
        # re-spill writes identical bytes — last writer wins harmlessly
        table.store.put(catalog_index_key(table.path, snap.version), body)
        # the chunk index spills alongside the catalog indexes, so a fresh
        # process dedups against everything this one stored
        idx = getattr(table, "cas", None)
        if idx is not None:
            idx.spill(table)

    def spill_catalog(self, version: VersionArg = None) -> List[str]:
        """Force-write the per-shard catalog index at ``version`` (latest
        if None), regardless of threshold; returns the keys written.
        Operators use this to backfill indexes onto pre-existing tables."""
        key = self._concrete_vector(version)
        written = []
        for shard, v in enumerate(key):
            table = self.tables[shard]
            self._spill_index(table, table.snapshot(v))
            written.append(catalog_index_key(table.path, v))
        return written

    def _evict_headers(self, paths: Sequence[str]) -> None:
        for p in paths:
            self._headers_by_path.pop(p, None)

    def compact(self, *, recompress: Union[None, str, CompressionSpec] = None,
                ) -> List[CompactResult]:
        """OPTIMIZE every shard table (fanned out on the executor).

        Rewritten files keep their codec; ``recompress="zlib+shuffle"``
        re-encodes every non-header data file under that codec instead —
        the in-place migration path for stores written before compression
        existed (exposed as ``repro.launch.gc --recompress``). Live leased
        snapshots keep reading their original bytes: compact adds files,
        vacuum is what eventually deletes the old generation.

        Compacted-away paths are evicted from the header and block caches —
        their bytes survive until vacuum, but a stale cache entry must not
        mask a storage-level problem. No-op shards commit nothing.
        """
        spec = parse_compression(recompress)
        if self.shards == 1:
            results = [self.tables[0].compact(recompress=spec)]
        else:
            results = self.io.map(lambda t: t.compact(recompress=spec),
                                  self.tables)
        for shard, res in enumerate(results):
            if not res:
                continue
            table = self.tables[shard]
            self._evict_headers(res.removed_paths)
            table.io.invalidate(table.store,
                                [f"{table.path}/{p}" for p in res.removed_paths])
            self._maybe_spill(shard, res.version)
        return results

    def _retention_horizon(self, shard: int, latest: int,
                           keep_versions: int,
                           ttl_s: Optional[float]) -> int:
        """Oldest version this shard must keep under the policy (leases are
        added on top by the caller)."""
        horizon = max(0, latest - (keep_versions - 1))
        if ttl_s is not None:
            cutoff = time.time() - ttl_s
            log = self.tables[shard].log
            v = horizon
            while v > 0:
                ts = log.commit_ts(v - 1)
                if ts is None or ts < cutoff:
                    break
                v -= 1
            horizon = v
        return horizon

    def vacuum(self, *, keep_versions: Optional[int] = None,
               ttl_s: Optional[float] = None,
               dry_run: bool = False) -> List[VacuumResult]:
        """Delete files unreachable from any retained or leased snapshot.

        Per shard, the retention horizon keeps the newest
        ``keep_versions`` versions (default: the store's
        :class:`~repro.core.leases.RetentionPolicy`) plus every version
        younger than ``ttl_s``; versions pinned by live leases — every open
        :class:`TensorRef`, every checkpoint retained by the checkpointer —
        are kept whatever their age, so pinned reads and time travel within
        the horizon keep working. Deleted paths are evicted from the block
        and header caches, and catalogs cached for now-unreachable versions
        are dropped. ``dry_run`` reports without deleting.

        With dedup, deletes are effectively **reference-counted**: each
        shard table keeps an object while any retained/leased add-action
        references it by path, ``physPath`` alias, or ``deltaBase``.
        Sharded stores additionally pre-scan every shard's retained
        snapshots for *cross-shard* delta-base references (a variant's
        files may delta against a base tensor routed to another shard)
        and pass them to the owning shard as extra live paths. After
        deleting, each shard's chunk index drops the reclaimed paths (so
        dedup never hands out dangling references), the matching
        content-cache entries are evicted, and the index respills.
        """
        keep = self.retention.keep_versions if keep_versions is None \
            else max(1, int(keep_versions))
        ttl = self.retention.ttl_s if ttl_s is None else ttl_s

        plans = []
        for shard in range(self.shards):
            table = self.tables[shard]
            latest = table.version()
            horizon = self._retention_horizon(shard, latest, keep, ttl)
            leased = sorted(self.leases.leased_versions(shard))
            plans.append((table, horizon, leased))

        extra_live: Dict[int, set] = {i: set() for i in range(self.shards)}
        if self.shards > 1:
            # cross-shard delta-base closure: deltaBase keys are absolute,
            # so prefix-match them to the owning shard table (the trailing
            # "/" keeps shard-1 from matching shard-10)
            prefixes = [(t.path + "/", i) for i, t in enumerate(self.tables)]
            for shard, (table, horizon, leased) in enumerate(plans):
                retained = table.retained_versions(horizon=horizon,
                                                   extra_versions=leased)
                for v in sorted(retained):
                    for a in table.log.snapshot(v).files.values():
                        db = a.get("deltaBase")
                        if not db:
                            continue
                        for pfx, owner in prefixes:
                            if owner != shard and db.startswith(pfx):
                                extra_live[owner].add(db[len(pfx):])
                                break

        def one(shard: int) -> VacuumResult:
            table, horizon, leased = plans[shard]
            return table.vacuum(horizon=horizon,
                                extra_versions=leased,
                                extra_live=sorted(extra_live[shard]),
                                dry_run=dry_run)

        if self.shards == 1:
            results = [one(0)]
        else:
            results = self.io.map(one, list(range(self.shards)))
        if not dry_run:
            for shard, res in enumerate(results):
                table = self.tables[shard]
                self._evict_headers(res.deleted_paths)
                # catalogs pinned outside this shard's retained set now
                # reference deleted files — drop them from the cache
                # (pop, not del: a concurrent reader may race the LRU)
                retained = set(res.retained_versions)
                for key in [k for k in self._catalogs
                            if k[shard] not in retained]:
                    self._catalogs.pop(key, None)
                idx = getattr(table, "cas", None)
                if idx is None:
                    continue
                if res.deleted_paths:
                    idx.ensure_loaded(table)
                    dropped = idx.drop_paths(res.deleted_paths)
                    if dropped:
                        self.io.invalidate(
                            table.store,
                            [content_cache_key(h) for h in dropped])
                if idx.dirty:
                    idx.spill(table)
        return results

    # -- write -------------------------------------------------------------

    def _resolve_tid(self, tensor: Any, layout: str,
                     tensor_id: Optional[str]) -> Tuple[str, str]:
        """Resolve (layout, tensor_id) without encoding or uploading anything,
        so callers can run existence checks before paying any upload."""
        if layout == "auto":
            layout = choose_layout(tensor)
        get_codec(layout)  # fail fast on unknown layouts
        return layout, tensor_id or f"{layout}-{uuid.uuid4().hex[:12]}"

    def shard_of(self, tensor_id: str) -> int:
        """Shard index the router assigns ``tensor_id`` (0 when unsharded)."""
        return self.router.shard_of(tensor_id)

    def _tensor_itemsize(self, tensor: Any) -> int:
        """Dtype width of ``tensor`` — what the byte-shuffle filter
        transposes on. SparseCOO carriers report their values' dtype."""
        dt = getattr(tensor, "dtype", None)
        if dt is None:
            dt = getattr(getattr(tensor, "values", None), "dtype", None)
        if dt is None:
            dt = np.asarray(tensor).dtype
        return np.dtype(dt).itemsize

    def _encode_and_upload(self, tensor: Any, *, layout: str,
                           tensor_id: str,
                           target_file_bytes: Optional[int] = None,
                           guard=None,
                           compression: Union[None, str, CompressionSpec] = None,
                           **codec_params):
        """Encode + upload part files (no commit). ``layout``/``tensor_id``
        must already be resolved (see :meth:`_resolve_tid`). Returns
        ``(shard, add_actions, header_seed)`` where ``shard`` is the router-
        assigned shard the files were uploaded into and header_seed is
        ``(path, columns)`` for post-commit caching, or None. ``guard`` (an
        :class:`~repro.lake.table.UploadGuard`) registers each upload so
        concurrent vacuum spares the not-yet-committed files.

        ``compression`` overrides the store default for this tensor's
        chunk files; headers always land raw (tiny, latency-critical, and
        a codec-less client must still be able to stat shapes).

        When the store dedups, every non-header file is offered to the
        shard table's chunk index: content already stored commits as a
        reference, moving zero bytes (checkpoint re-uploads of unchanged
        tensors collapse this way). One ``dedup_seen`` set spans the whole
        tensor so its own files never alias each other."""
        codec = get_codec(layout)
        tid = tensor_id
        shard = self.router.shard_of(tid)
        table = self.tables[shard]
        target = TARGET_FILE_BYTES if target_file_bytes is None else target_file_bytes
        spec = parse_compression(compression)
        if spec is None:
            spec = self.compression
        itemsize = self._tensor_itemsize(tensor) if spec is not None else 1
        groups = codec.encode(tensor, **{k: v for k, v in codec_params.items()
                                         if v is not None})
        adds: List[Dict[str, Any]] = []
        header_seed = None
        dedup_seen: set = set()
        for grp in groups:
            grp_spec = spec if grp.kind != "header" else None
            cas = table.cas if grp.kind != "header" else None
            adds.extend(self._append_rows(
                table, grp.columns, tid=tid, kind=grp.kind, layout=layout,
                spec=grp_spec, itemsize=itemsize, target=target, guard=guard,
                cas=cas, dedup_seen=dedup_seen))
            if grp.kind == "header":
                header_seed = (adds[-1]["path"], grp.columns)
        return shard, adds, header_seed

    def _append_rows(self, table: DeltaTable, columns: Dict[str, Any], *,
                     tid: str, kind: str, layout: str, spec, itemsize: int,
                     target: int, guard=None, cas: Optional[ChunkIndex] = None,
                     dedup_seen: Optional[set] = None) -> List[Dict[str, Any]]:
        """Split ``columns`` into ~``target``-byte part files and upload
        them (no commit) under the tensor's partition values — a thin
        wrapper over :meth:`~repro.lake.table.DeltaTable.append_split`."""
        return table.append_split(
            columns, target_bytes=target, guard=guard, compression=spec,
            shuffle_itemsize=itemsize, cas=cas, dedup_seen=dedup_seen,
            partition_values={"tensor": tid, "kind": kind, "layout": layout})

    def _encode_and_upload_variant(self, tensor: Any, *, base_tid: str,
                                   tensor_id: str, guard_for,
                                   target_file_bytes: Optional[int] = None,
                                   compression: Union[None, str,
                                                      CompressionSpec] = None):
        """Encode ``tensor`` as a delta-stored variant of ``base_tid``.

        The variant's chunk rows are re-partitioned to mirror the base
        tensor's chunk files (aligned row-by-row on ``chunk_index``), so
        each variant file XOR-diffs against exactly one existing base
        object — a fine-tune that perturbs a few percent of values
        compresses to near-nothing, and chunks identical to the base
        dedup into pure references before any delta is even encoded.
        Rows no base file covers (grown tensors, layouts without a
        ``chunk_index`` column) fall back to the plain upload path, as
        does the header. Delta-stored files never target another delta
        (vacuum's liveness closure stays single-hop by construction:
        only base adds without ``deltaBase`` are eligible anchors).

        ``guard_for(shard)`` supplies the upload guard per shard — the
        base tensor may route to a different shard than the variant, and
        its referenced objects must stay pinned through the commit
        window. Returns ``(shard, adds, header_seed)`` like
        :meth:`_encode_and_upload`.
        """
        cat = self.catalog()
        entry = cat.entry(base_tid)
        layout = entry.layout
        codec = get_codec(layout)
        tid = tensor_id
        shard = self.router.shard_of(tid)
        table = self.tables[shard]
        base_table = self.tables[entry.shard]
        target = TARGET_FILE_BYTES if target_file_bytes is None \
            else target_file_bytes
        spec = parse_compression(compression)
        if spec is None:
            spec = self.compression
        if spec is None or not spec.active:
            spec = parse_compression("zlib")  # deltas need a codec to win
        itemsize = self._tensor_itemsize(tensor)
        params: Dict[str, Any] = {}
        try:
            header = cat.header(base_tid)
        except (KeyError, ObjectNotFoundError):
            header = None
        if header is not None and "chunk_dim_count" in header:
            # chunk the variant exactly like its base, or rows won't align
            params["chunk_dims"] = int(first_scalar(header["chunk_dim_count"]))
        guard = guard_for(shard)
        base_guard = guard_for(entry.shard) if entry.shard != shard else guard
        lease = self.leases.acquire(cat.version_vector)
        try:
            groups = codec.encode(tensor, **params)
            dedup_seen: set = set()
            adds: List[Dict[str, Any]] = []
            header_seed = None
            eligible = [a for a in entry.chunk_adds if not a.get("deltaBase")]
            base_keys = [f"{base_table.path}/{physical_path(a)}"
                         for a in eligible]
            base_names = [content_cache_key(a["contentHash"])
                          if a.get("contentHash") else None for a in eligible]
            base_blobs = list(self.io.fetch_ordered(
                base_table.store, base_keys,
                cache_names=base_names)) if eligible else []
            for grp in groups:
                if grp.kind == "header":
                    add = table.append(
                        grp.columns, commit=False, guard=guard,
                        partition_values={"tensor": tid, "kind": "header",
                                          "layout": layout})
                    adds.append(add)
                    header_seed = (add["path"], grp.columns)
                    continue
                cols = grp.columns
                rows = len(next(iter(cols.values())))
                covered = np.zeros(rows, dtype=bool)
                order_col = cols.get("chunk_index")
                if order_col is not None and len(base_blobs):
                    index_of = {int(ci): i
                                for i, ci in enumerate(order_col)}
                    for base_add, base_key, blob in zip(eligible, base_keys,
                                                        base_blobs):
                        base_order = columnar.read_table(
                            blob, ["chunk_index"]).get("chunk_index")
                        if base_order is None or len(base_order) == 0:
                            continue
                        sel = [index_of.get(int(ci)) for ci in base_order]
                        if any(i is None or covered[i] for i in sel):
                            # this base file covers rows the variant lacks
                            # (or rows already taken): no clean 1:1 diff
                            continue
                        aligned = _select_rows(cols, sel)
                        bh = base_add.get("contentHash") or chunk_hash(blob)
                        add = table.append(
                            aligned, commit=False, guard=guard,
                            compression=spec, shuffle_itemsize=itemsize,
                            cas=table.cas, dedup_seen=dedup_seen,
                            delta_base=DeltaBase(key=base_key, data=blob,
                                                 content_hash=bh),
                            partition_values={"tensor": tid,
                                              "kind": grp.kind,
                                              "layout": layout})
                        if add.get("deltaBase") == base_key:
                            # the commit will reference the base object:
                            # pin it through the commit window even if the
                            # base tensor is concurrently deleted+vacuumed
                            base_guard.add(physical_path(base_add))
                        adds.append(add)
                        covered[np.asarray(sel, dtype=np.int64)] = True
                if not covered.all():
                    leftover = cols if not covered.any() else \
                        _select_rows(cols, np.flatnonzero(~covered))
                    adds.extend(self._append_rows(
                        table, leftover, tid=tid, kind=grp.kind,
                        layout=layout, spec=spec, itemsize=itemsize,
                        target=target, guard=guard, cas=table.cas,
                        dedup_seen=dedup_seen))
            return shard, adds, header_seed
        finally:
            lease.release()

    def put_deferred(self, tensor: Any, *, layout: str = "auto",
                     tensor_id: Optional[str] = None,
                     target_file_bytes: int = TARGET_FILE_BYTES,
                     compression: Union[None, str, CompressionSpec] = None,
                     **codec_params) -> List[Dict[str, Any]]:
        """Upload part files WITHOUT committing; returns add-actions.

        Low-level two-phase building block (callers pass the adds to
        ``table.commit_adds`` themselves — on a sharded store that table is
        ``store.tables[store.shard_of(tid)]``). Prefer :meth:`batch`, which
        also handles overwrites/deletes, shard routing, and post-commit
        header caching. Note no header is cached here — an abandoned upload
        must leave no trace.
        """
        layout, tid = self._resolve_tid(tensor, layout, tensor_id)
        _shard, adds, _ = self._encode_and_upload(
            tensor, layout=layout, tensor_id=tid,
            target_file_bytes=target_file_bytes, compression=compression,
            **codec_params)
        return adds

    def batch(self, *, op: str = "WRITE BATCH",
              commit_retries: Optional[int] = None) -> WriteBatch:
        """Stage many puts/deletes; commit atomically per shard.

        On an unsharded store the whole batch is ONE commit. On a sharded
        store staged actions split by shard and land as one atomic commit
        per touched shard, each fenced against the batch's base snapshot
        with a bounded commit-retry/rebase loop on ``CommitConflict``
        (``commit_retries`` bounds it; see :class:`WriteBatch`).
        """
        return WriteBatch(self, op=op, commit_retries=commit_retries)

    def put(self, tensor: Any, *, layout: str = "auto", tensor_id: Optional[str] = None,
            overwrite: bool = False, target_file_bytes: int = TARGET_FILE_BYTES,
            compression: Union[None, str, CompressionSpec] = None,
            **codec_params) -> str:
        """Store one tensor in its own atomic commit; returns its id.

        ``layout`` picks the encoding codec (``"auto"`` = the 10% sparsity
        policy); ``compression`` overrides the store's default chunk-blob
        codec for this tensor (e.g. ``"zlib+shuffle"``). Raises
        ``ValueError`` if ``tensor_id`` exists and ``overwrite`` is False.
        Sugar for a one-put :meth:`batch`.
        """
        with self.batch(op="PUT TENSOR") as b:
            tid = b.put(tensor, layout=layout, tensor_id=tensor_id,
                        overwrite=overwrite, target_file_bytes=target_file_bytes,
                        compression=compression, **codec_params)
        return tid

    def put_variant(self, tensor: Any, *, base_tid: str,
                    tensor_id: Optional[str] = None,
                    overwrite: bool = False,
                    target_file_bytes: int = TARGET_FILE_BYTES,
                    compression: Union[None, str, CompressionSpec] = None,
                    ) -> str:
        """Store ``tensor`` as a delta-encoded variant of ``base_tid``.

        The fine-tuned-model write path: chunks identical to the base
        dedup into pure references, differing chunks store as XOR deltas
        against the base's objects (reconstructed transparently on read).
        The variant is an ordinary tensor afterwards — same handles, same
        reads, same deletes; vacuum keeps the base objects alive while
        any retained variant references them. Returns the variant's id
        (default ``"<base_tid>~<hex>"``). Sugar for a one-put
        :meth:`batch` using :meth:`WriteBatch.put_variant`.
        """
        with self.batch(op="PUT VARIANT") as b:
            tid = b.put_variant(tensor, base_tid=base_tid,
                                tensor_id=tensor_id, overwrite=overwrite,
                                target_file_bytes=target_file_bytes,
                                compression=compression)
        return tid

    def delete(self, tid: str) -> None:
        """Remove ``tid``'s files from the latest snapshot (one commit).

        Older snapshots still see the tensor until :meth:`vacuum`; missing
        ids are a no-op (sugar for a one-delete :meth:`batch`).
        """
        with self.batch(op="DELETE TENSOR") as b:
            b.delete(tid, missing_ok=True)

    # -- read (legacy eager wrappers over the handle API) --------------------

    def get(self, tid: str, *, version: VersionArg = None) -> np.ndarray:
        """Eager full read of ``tid`` at ``version`` (latest if None)."""
        with self.open(tid, version=version) as ref:
            return ref.read()

    def get_coo(self, tid: str, *, version: VersionArg = None) -> SparseCOO:
        """Eager sparse read (native when the layout supports COO)."""
        with self.open(tid, version=version) as ref:
            return ref.read_coo()

    def get_slice(self, tid: str, slices: Sequence[Optional[Tuple[int, int]]], *,
                  version: VersionArg = None) -> np.ndarray:
        """Eager read-slice (the paper's Eq. (2) leading-dims window)."""
        with self.open(tid, version=version) as ref:
            return ref.read_slice(slices)

    def get_device(self, tid: str,
                   slices: Optional[Sequence[Optional[Tuple[int, int]]]] = None,
                   *, version: VersionArg = None):
        """Eager device read: the tensor (or leading-dims slice) as a jax
        device buffer, assembled without an ordered full-tensor host copy
        (see :meth:`~repro.core.catalog.TensorRef.read_device`)."""
        with self.open(tid, version=version) as ref:
            return ref.read_device(slices)

    def read_many(self, requests: Sequence[Tuple[str, Optional[Sequence]]], *,
                  version: VersionArg = None,
                  window: Optional[int] = None,
                  io: Optional[ReadExecutor] = None,
                  cache_partition: Optional[str] = None,
                  device: bool = False) -> List[np.ndarray]:
        """Read many ``(tid, slices)`` requests through ONE merged fetch
        plan (see :meth:`~repro.core.catalog.Catalog.read_many`): shared
        chunk keys are fetched once, adjacent requests' files stream
        through the windowed executor, and each request decodes as soon
        as its last file lands. ``slices=None`` reads a tensor in full.
        Results come back in request order, all pinned to one snapshot.
        ``io`` overrides the shared executor; ``cache_partition`` names
        the block-cache priority class the fetched blocks land in;
        ``device=True`` assembles each result on the accelerator device.
        """
        return self.catalog(version).read_many(
            requests, window=window, io=io, cache_partition=cache_partition,
            device=device)

    def ingest(self, tensor_id: str, *, watermark_rows: int = 64,
               watermark_s: Optional[float] = None,
               target_file_bytes: Optional[int] = None,
               compression: Union[None, str, CompressionSpec] = None,
               commit_retries: Optional[int] = None,
               clock=None):
        """A streaming :class:`~repro.data.ingest.IngestWriter` on ``tensor_id``.

        ``writer.append_rows(rows)`` buffers sample rows and commits them
        as grown FTSF chunk files whenever ``watermark_rows`` rows (or
        ``watermark_s`` seconds of buffer age) accumulate — each flush is
        one fenced atomic commit through the two-phase upload path, so
        concurrent batch writers, ``compact``, ``vacuum``, and epoch-pinned
        readers all keep working. The tensor is created on first flush if
        it does not exist (row shape/dtype inferred from the first rows).
        """
        from ..data.ingest import IngestWriter  # data sits above core
        return IngestWriter(self, tensor_id, watermark_rows=watermark_rows,
                            watermark_s=watermark_s,
                            target_file_bytes=target_file_bytes,
                            compression=compression,
                            commit_retries=commit_retries, clock=clock)

    def models(self, prefix: str, *, version: VersionArg = None):
        """A :class:`~repro.serve.repo.ModelRepo` handle over ``prefix``.

        The serving-weights API: ``repo.save(params)`` persists a param
        pytree (one tensor per leaf, one atomic commit),
        ``repo.load(template)`` reads it back through one merged fetch
        plan, ``repo.open_variant(name)`` stores fine-tunes as delta
        variants of this repo's leaves. The repo is snapshot-pinned and
        lease-holding like :class:`~repro.core.catalog.TensorRef`.
        """
        from ..serve.repo import ModelRepo  # serve sits above core
        return ModelRepo(self, prefix, version=version)

    # -- catalog conveniences -------------------------------------------------

    def list_tensors(self, version: VersionArg = None) -> List[Tuple[str, str]]:
        """Sorted ``(tensor_id, layout)`` pairs at ``version``."""
        return self.catalog(version).tensors()

    def shape_of(self, tid: str, *, version: VersionArg = None) -> Tuple[int, ...]:
        """Dense shape from the header only (one tiny fetch, cached)."""
        with self.open(tid, version=version) as ref:
            return ref.shape

    def tensor_bytes(self, tid: str, *, version: VersionArg = None) -> int:
        """Stored bytes across the tensor's files (no data fetches)."""
        with self.open(tid, version=version) as ref:
            return ref.nbytes

    def storage_stats(self, version: VersionArg = None) -> Dict[str, Any]:
        """Logical vs physical vs *deduplicated* bytes at ``version`` —
        the paper's space-efficiency claim, measurable.

        Walks the (cached) catalog's add-actions, so it costs no data
        fetches. Physical bytes count each stored object **once**, however
        many add-actions reference it — the honest answer dedup demands.
        Returns::

            {"tensors": int, "files": int,
             "physical_bytes": int,   # unique stored objects, stored size
             "referenced_bytes": int, # sum over references (pre-dedup view)
             "logical_bytes": int,    # pre-compression file bytes
             "ratio": float,          # logical / physical  (>= 1.0 good)
             "compression": str,      # the store's default codec spec
             "by_codec": {codec_id: {"files", "physical_bytes",
                                     "logical_bytes", "ratio"}},
             "dedup": {"unique_chunks", "references", "deduped_refs",
                       "saved_bytes",   # referenced - physical
                       "delta_files"}}  # files stored as XOR deltas

        Files written before compression existed count under codec
        ``"none"`` with ratio 1.0 — so a half-migrated store shows exactly
        how much of it still holds raw bytes (what ``gc --recompress``
        would win).
        """
        cat = self.catalog(version)
        by_codec: Dict[str, Dict[str, Any]] = {}
        seen_objects: set = set()
        files = physical = referenced = logical = 0
        deduped_refs = delta_files = 0
        for tid in cat:
            entry = cat.entry(tid)
            for add in entry.header_adds + entry.chunk_adds:
                codec = add.get("codec", "none")
                phys = int(add.get("size", 0))
                logi = int(add.get("rawSize", phys))
                obj = (entry.shard, physical_path(add))
                unique = obj not in seen_objects
                seen_objects.add(obj)
                rec = by_codec.setdefault(
                    codec, {"files": 0, "physical_bytes": 0,
                            "logical_bytes": 0})
                rec["files"] += 1
                rec["logical_bytes"] += logi
                files += 1
                referenced += phys
                logical += logi
                if unique:
                    rec["physical_bytes"] += phys
                    physical += phys
                else:
                    deduped_refs += 1
                if add.get("deltaBase") and unique:
                    delta_files += 1
        for rec in by_codec.values():
            rec["ratio"] = (rec["logical_bytes"] / rec["physical_bytes"]
                            if rec["physical_bytes"] else 1.0)
        return {"tensors": len(cat), "files": files,
                "physical_bytes": physical,
                "referenced_bytes": referenced,
                "logical_bytes": logical,
                "ratio": logical / physical if physical else 1.0,
                "compression": self.compression.id if self.compression
                else "none",
                "by_codec": by_codec,
                "dedup": {"unique_chunks": len(seen_objects),
                          "references": files,
                          "deduped_refs": deduped_refs,
                          "saved_bytes": referenced - physical,
                          "delta_files": delta_files}}

    def dedup_stats(self) -> Dict[str, Any]:
        """Chunk-index counters aggregated across shards::

            {"enabled": bool, "entries": int,
             "hits", "misses", "inserts", "collisions",
             "verified", "verify_failures"}

        ``hits`` are uploads that became pure references (zero bytes
        moved); ``collisions`` are hash matches rejected on raw-size
        mismatch (the paranoia check firing).
        """
        out: Dict[str, Any] = {"enabled": self.dedup, "entries": 0,
                               "hits": 0, "misses": 0, "inserts": 0,
                               "collisions": 0, "verified": 0,
                               "verify_failures": 0}
        for t in self.tables:
            idx = getattr(t, "cas", None)
            if idx is None:
                continue
            out["entries"] += len(idx)
            for k, v in idx.stats.items():
                out[k] += v
        return out

    def build_chunk_index(self) -> List[int]:
        """Backfill every shard's chunk index from its live snapshot.

        The migration path for stores written before dedup existed
        (``repro.launch.gc --build-chunk-index``): adds without a
        recorded ``contentHash`` are fetched and hashed, the index is
        spilled, and — when the store dedups — future uploads reuse the
        backfilled chunks. Idempotent. Returns per-shard counts of new
        entries.
        """
        counts: List[int] = []
        for table in self.tables:
            idx = getattr(table, "cas", None) or chunk_index_for(table)
            n = idx.build_from_snapshot(table, table.snapshot())
            idx.spill(table)
            counts.append(n)
        return counts

    def io_stats(self) -> Dict[str, Any]:
        """Read-path counters + per-request latency percentiles — the
        ``catalog_stats``-style report for the executor this store's
        fetches run through (shared across stores when it is the process
        default executor). Latencies are virtual-clock durations on a
        modeled object store, wall clock otherwise::

            {"gets", "cache_hits", "cache_misses",
             "hedges_launched", "hedges_won",
             "plans", "plan_requests",          # read_many scheduling
             "plan_keys_fetched", "plan_keys_deduped",
             "decode_s", "decode_overlap_frac", # staged frame decode
             "decodes_offloaded", "bytes_to_device",
             "latency": {"count", "mean_s", "p50_s", "p95_s",
                         "p99_s", "max_s"}}
        """
        s = self.io.stats
        return {"gets": s.gets, "cache_hits": s.cache_hits,
                "cache_misses": s.cache_misses,
                "hedges_launched": s.hedges_launched,
                "hedges_won": s.hedges_won,
                "plans": s.plans, "plan_requests": s.plan_requests,
                "plan_keys_fetched": s.plan_keys_fetched,
                "plan_keys_deduped": s.plan_keys_deduped,
                "deltas_reconstructed": s.deltas_reconstructed,
                "decode_s": s.decode_s,
                "decode_overlap_frac": s.decode_overlap_frac,
                "decodes_offloaded": s.decodes_offloaded,
                "bytes_to_device": s.bytes_to_device,
                "latency": s.latency.summary()}

    def version(self) -> Union[int, Tuple[int, ...]]:
        """Latest version: an int (1-shard) or the per-shard version vector."""
        if self.shards == 1:
            return self.tables[0].version()
        return self.version_vector()

    def version_vector(self) -> Tuple[int, ...]:
        """Latest per-shard versions, probed concurrently on the executor."""
        if self.shards == 1:
            return (self.tables[0].version(),)
        return tuple(self.io.map(lambda t: t.version(), self.tables))
