"""DeltaTensorStore — the paper's system: tensors in a delta table.

``put`` encodes a tensor with one of the five codecs and lands the row
groups as parq-lite files in a single atomic commit, partitioned by
``(tensor, kind)``. Reads go through the handle API: ``open`` returns a
snapshot-pinned lazy :class:`~repro.core.catalog.TensorRef` whose
``read``/``read_slice``/``read_coo``/``read_async`` are the paper's
read-tensor / read-slice operations; ``version=`` arguments give Delta time
travel. The legacy eager calls (``get``/``get_slice``/``get_coo``/...) are
kept as thin wrappers over ``open``.

Per-read metadata cost is O(1): a :class:`~repro.core.catalog.Catalog` is
built once per table version (one pass over ``table.files()``) and cached,
so a burst of reads shares one snapshot walk instead of paying it per call.
All chunk fetches flow through the table's shared ``ReadExecutor``
(``repro.lake.io``): surviving chunk files are fetched concurrently, decode
streams in plan order as gets complete, repeat reads hit the block cache.

Writes batch through :class:`~repro.core.batch.WriteBatch`
(``with store.batch() as b: b.put(...)``): many tensors plus deletes land
in ONE atomic commit, and headers are cached only after that commit
succeeds (an abandoned batch leaves no stale state behind).
"""

from __future__ import annotations

import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..lake import DeltaTable, ObjectStore, ReadExecutor, columnar
from .batch import WriteBatch
from .catalog import Catalog, TensorRef
from .encodings.base import SparseCOO, get_codec
from .sparsity import choose_layout

TARGET_FILE_BYTES = 4 << 20

MAX_CACHED_CATALOGS = 16
MAX_CACHED_HEADERS = 1024


def _approx_row_bytes(columns: Dict[str, Any], rows: int) -> float:
    total = 0
    for v in columns.values():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            total += v.nbytes
        else:
            for item in v:
                if isinstance(item, (bytes, bytearray)):
                    total += len(item)
                elif isinstance(item, np.ndarray):
                    total += item.nbytes
                else:
                    total += 8
    return total / max(rows, 1)


def _slice_columns(columns: Dict[str, Any], lo: int, hi: int) -> Dict[str, Any]:
    out = {}
    for k, v in columns.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[lo:hi]
        else:
            out[k] = list(v[lo:hi])
    return out


class DeltaTensorStore:
    def __init__(self, object_store: ObjectStore, root: str = "tensor_store",
                 io: Optional[ReadExecutor] = None):
        self.table = DeltaTable.create(object_store, root, io=io)
        # per-version catalogs: snapshots are immutable, so a catalog never
        # goes stale; LRU-capped for long-lived many-version clients
        self._catalogs: "OrderedDict[int, Catalog]" = OrderedDict()
        # parsed headers keyed by immutable data-file path (seeded on
        # successful commits, filled on reads) — staleness-free by naming
        self._headers_by_path: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        # catalog_stats shows the O(1) metadata claim: `builds` counts full
        # snapshot walks, `hits` counts reads served by a cached catalog
        self.catalog_stats: Dict[str, int] = {"builds": 0, "hits": 0}

    @property
    def io(self) -> ReadExecutor:
        """Shared read executor all fetches for this store go through."""
        return self.table.io

    # -- catalog / handles ---------------------------------------------------

    def catalog(self, version: Optional[int] = None) -> Catalog:
        """The tensor index at ``version`` (latest if None); O(1) when cached."""
        snap = self.table.snapshot(version)
        cat = self._catalogs.get(snap.version)
        if cat is not None:
            self.catalog_stats["hits"] += 1
            self._catalogs.move_to_end(snap.version)
            return cat
        cat = Catalog(self, snap)
        self.catalog_stats["builds"] += 1
        self._catalogs[snap.version] = cat
        while len(self._catalogs) > MAX_CACHED_CATALOGS:
            self._catalogs.popitem(last=False)
        return cat

    def open(self, tid: str, *, version: Optional[int] = None) -> TensorRef:
        """Lazy snapshot-pinned handle; fetches nothing until read."""
        return self.catalog(version).open(tid)

    def _header_for_path(self, path: str) -> Dict[str, Any]:
        cols = self._headers_by_path.get(path)
        if cols is not None:
            self._headers_by_path.move_to_end(path)
            return cols
        data = self.io.fetch(self.table.store, f"{self.table.path}/{path}")
        cols = columnar.read_table(data)
        self._seed_header(path, cols)
        return cols

    def _seed_header(self, path: str, cols: Dict[str, Any]) -> None:
        self._headers_by_path[path] = cols
        while len(self._headers_by_path) > MAX_CACHED_HEADERS:
            self._headers_by_path.popitem(last=False)

    # -- write -------------------------------------------------------------

    def _resolve_tid(self, tensor: Any, layout: str,
                     tensor_id: Optional[str]) -> Tuple[str, str]:
        """Resolve (layout, tensor_id) without encoding or uploading anything,
        so callers can run existence checks before paying any upload."""
        if layout == "auto":
            layout = choose_layout(tensor)
        get_codec(layout)  # fail fast on unknown layouts
        return layout, tensor_id or f"{layout}-{uuid.uuid4().hex[:12]}"

    def _encode_and_upload(self, tensor: Any, *, layout: str,
                           tensor_id: str,
                           target_file_bytes: Optional[int] = None,
                           **codec_params):
        """Encode + upload part files (no commit). ``layout``/``tensor_id``
        must already be resolved (see :meth:`_resolve_tid`). Returns
        ``(add_actions, header_seed)`` where header_seed is
        ``(path, columns)`` for post-commit caching, or None."""
        codec = get_codec(layout)
        tid = tensor_id
        target = TARGET_FILE_BYTES if target_file_bytes is None else target_file_bytes
        groups = codec.encode(tensor, **{k: v for k, v in codec_params.items()
                                         if v is not None})
        adds: List[Dict[str, Any]] = []
        header_seed = None
        for grp in groups:
            rows = len(next(iter(grp.columns.values())))
            per_file = max(1, int(target //
                                  max(_approx_row_bytes(grp.columns, rows), 1)))
            for lo in range(0, rows, per_file):
                cols = _slice_columns(grp.columns, lo, min(rows, lo + per_file))
                adds.append(self.table.append(
                    cols, commit=False,
                    partition_values={"tensor": tid, "kind": grp.kind,
                                      "layout": layout}))
            if grp.kind == "header":
                header_seed = (adds[-1]["path"], grp.columns)
        return adds, header_seed

    def put_deferred(self, tensor: Any, *, layout: str = "auto",
                     tensor_id: Optional[str] = None,
                     target_file_bytes: int = TARGET_FILE_BYTES,
                     **codec_params) -> List[Dict[str, Any]]:
        """Upload part files WITHOUT committing; returns add-actions.

        Low-level two-phase building block (callers pass the adds to
        ``table.commit_adds`` themselves). Prefer :meth:`batch`, which also
        handles overwrites/deletes and post-commit header caching. Note no
        header is cached here — an abandoned upload must leave no trace.
        """
        layout, tid = self._resolve_tid(tensor, layout, tensor_id)
        adds, _ = self._encode_and_upload(
            tensor, layout=layout, tensor_id=tid,
            target_file_bytes=target_file_bytes, **codec_params)
        return adds

    def batch(self, *, op: str = "WRITE BATCH") -> WriteBatch:
        """Stage many puts/deletes, commit them as ONE atomic version."""
        return WriteBatch(self, op=op)

    def put(self, tensor: Any, *, layout: str = "auto", tensor_id: Optional[str] = None,
            overwrite: bool = False, target_file_bytes: int = TARGET_FILE_BYTES,
            **codec_params) -> str:
        with self.batch(op="PUT TENSOR") as b:
            tid = b.put(tensor, layout=layout, tensor_id=tensor_id,
                        overwrite=overwrite, target_file_bytes=target_file_bytes,
                        **codec_params)
        return tid

    def delete(self, tid: str) -> None:
        with self.batch(op="DELETE TENSOR") as b:
            b.delete(tid, missing_ok=True)

    # -- read (legacy eager wrappers over the handle API) --------------------

    def get(self, tid: str, *, version: Optional[int] = None) -> np.ndarray:
        return self.open(tid, version=version).read()

    def get_coo(self, tid: str, *, version: Optional[int] = None) -> SparseCOO:
        return self.open(tid, version=version).read_coo()

    def get_slice(self, tid: str, slices: Sequence[Optional[Tuple[int, int]]], *,
                  version: Optional[int] = None) -> np.ndarray:
        return self.open(tid, version=version).read_slice(slices)

    # -- catalog conveniences -------------------------------------------------

    def list_tensors(self, version: Optional[int] = None) -> List[Tuple[str, str]]:
        return self.catalog(version).tensors()

    def shape_of(self, tid: str, *, version: Optional[int] = None) -> Tuple[int, ...]:
        return self.open(tid, version=version).shape

    def tensor_bytes(self, tid: str, *, version: Optional[int] = None) -> int:
        return self.open(tid, version=version).nbytes

    def version(self) -> int:
        return self.table.version()
