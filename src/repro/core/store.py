"""DeltaTensorStore — the paper's system: tensors in a delta table.

``put`` encodes a tensor with one of the five codecs and lands the row
groups as parq-lite files in a single atomic commit, partitioned by
``(tensor, kind)``. Reads go through the handle API: ``open`` returns a
snapshot-pinned lazy :class:`~repro.core.catalog.TensorRef` whose
``read``/``read_slice``/``read_coo``/``read_async`` are the paper's
read-tensor / read-slice operations; ``version=`` arguments give Delta time
travel. The legacy eager calls (``get``/``get_slice``/``get_coo``/...) are
kept as thin wrappers over ``open``.

Per-read metadata cost is O(1): a :class:`~repro.core.catalog.Catalog` is
built once per table version (one pass over ``table.files()``) and cached,
so a burst of reads shares one snapshot walk instead of paying it per call.
All chunk fetches flow through the table's shared ``ReadExecutor``
(``repro.lake.io``): surviving chunk files are fetched concurrently, decode
streams in plan order as gets complete, repeat reads hit the block cache.

Writes batch through :class:`~repro.core.batch.WriteBatch`
(``with store.batch() as b: b.put(...)``): many tensors plus deletes land
in ONE atomic commit, and headers are cached only after that commit
succeeds (an abandoned batch leaves no stale state behind).

**Write scale-out**: ``DeltaTensorStore(obj, root, shards=N)`` splits the
logical store across N shard tables, each with its own delta log — an
independent commit domain, so concurrent writers whose tensors hash to
different shards never race each other's commits (see
``repro.core.sharding``). Reads are transparent: the catalog merges all
shards into one namespace pinned to a per-shard *version vector*, and
refs route fetches to the right shard table. ``shards=1`` (the default)
keeps the exact pre-sharding byte layout: the table lives at ``root``
with no manifest, so every existing table opens unchanged.
"""

from __future__ import annotations

import json
import time
import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..lake import DeltaTable, ObjectStore, ReadExecutor, columnar
from ..lake.compression import (CompressionSpec, UnknownCodecError,
                                parse_compression)
from ..lake.io import get_default_executor
from ..lake.log import ObjectNotFoundError, catalog_index_key
from ..lake.table import CompactResult, VacuumResult
from .batch import WriteBatch
from .catalog import Catalog, ShardSource, TensorRef, build_catalog_index
from .encodings.base import SparseCOO, get_codec
from .leases import Lease, RetentionPolicy, lease_scope, registry_for
from .sharding import (ROUTER_ALGO, ShardRouter, load_or_init_manifest,
                       resolve_version_vector, shard_table_path)
from .sparsity import choose_layout

TARGET_FILE_BYTES = 4 << 20

MAX_CACHED_CATALOGS = 16
MAX_CACHED_HEADERS = 1024

# shard snapshots at or past this many files spill a catalog index next to
# the delta log on commit, so later Catalog.builds are one O(1) index load
# instead of an O(files) snapshot walk (None disables spilling)
DEFAULT_SPILL_THRESHOLD = 512


def _approx_row_bytes(columns: Dict[str, Any], rows: int) -> float:
    total = 0
    for v in columns.values():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            total += v.nbytes
        else:
            for item in v:
                if isinstance(item, (bytes, bytearray)):
                    total += len(item)
                elif isinstance(item, np.ndarray):
                    total += item.nbytes
                else:
                    total += 8
    return total / max(rows, 1)


def _slice_columns(columns: Dict[str, Any], lo: int, hi: int) -> Dict[str, Any]:
    out = {}
    for k, v in columns.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[lo:hi]
        else:
            out[k] = list(v[lo:hi])
    return out


VersionArg = Union[None, int, Sequence[int]]


class DeltaTensorStore:
    """The paper's tensor store: codec-encoded tensors in delta tables.

    See the module docstring for the architecture; ``compression`` sets
    the store's default chunk-blob codec spec (e.g. ``"zlib+shuffle"``,
    see :mod:`repro.lake.compression`) — recorded in the store manifest at
    create time so every later client agrees, overridable per ``put``.
    ``None`` defers to the manifest (raw bytes when it records nothing).
    """

    def __init__(self, object_store: ObjectStore, root: str = "tensor_store",
                 io: Optional[ReadExecutor] = None,
                 shards: Optional[int] = None,
                 retention: Optional[RetentionPolicy] = None,
                 spill_threshold: Optional[int] = DEFAULT_SPILL_THRESHOLD,
                 compression: Union[None, str, CompressionSpec] = None):
        root = root.rstrip("/")
        self.root = root
        spec = parse_compression(compression)
        manifest = load_or_init_manifest(
            object_store, root, shards,
            retention=None if retention is None else
            {"keep_versions": retention.keep_versions,
             "ttl_s": retention.ttl_s},
            compression=None if spec is None else spec.id)
        self.shards: int = int(manifest["shards"])
        # default chunk-blob codec: explicit ctor arg > manifest > raw.
        # Reads never consult this — frames are self-describing — so a
        # store opened with any default reads any mix of codecs. A
        # manifest naming an optional codec this process lacks (zstd on
        # a stdlib-only client) therefore must not block opening: this
        # client degrades to raw writes; only an EXPLICIT ctor arg (or
        # actually decoding such a frame) raises for a missing codec.
        if spec is None and manifest.get("compression"):
            try:
                spec = parse_compression(manifest["compression"])
            except UnknownCodecError:
                spec = None
        self.compression: Optional[CompressionSpec] = \
            spec if spec is not None and spec.active else None
        # default vacuum policy: explicit ctor arg > what the store manifest
        # records (sharded stores) > keep-latest-only
        if retention is None and manifest.get("retention"):
            r = manifest["retention"]
            retention = RetentionPolicy(
                keep_versions=int(r.get("keep_versions", 1)),
                ttl_s=r.get("ttl_s"))
        self.retention = retention or RetentionPolicy()
        self.spill_threshold = spill_threshold
        # live snapshot pins: shared across every client of this physical
        # store in the process, consumed by vacuum's retention horizon
        self.leases = registry_for(lease_scope(object_store), root)
        self.router = ShardRouter(self.shards,
                                  manifest.get("router", ROUTER_ALGO))
        io = io or get_default_executor()
        if self.shards == 1:
            # unsharded: table at root itself — the pre-sharding layout
            self.tables: List[DeltaTable] = [
                DeltaTable.create(object_store, root, io=io)]
        else:
            self.tables = [
                DeltaTable.create(object_store, shard_table_path(root, i),
                                  io=io)
                for i in range(self.shards)]
        # per-version-vector catalogs: snapshots are immutable, so a catalog
        # never goes stale; LRU-capped for long-lived many-version clients
        self._catalogs: "OrderedDict[Tuple[int, ...], Catalog]" = OrderedDict()
        # parsed headers keyed by immutable data-file path (seeded on
        # successful commits, filled on reads) — staleness-free by naming;
        # part-file names are uuid-unique, so one map covers all shards
        self._headers_by_path: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        # catalog_stats shows the O(1) metadata claim: `builds` counts
        # catalog constructions, `hits` reads served by a cached catalog,
        # `snapshot_walks` shard sources resolved by an O(files) snapshot
        # walk, `index_loads` sources resolved by a spilled catalog index
        self.catalog_stats: Dict[str, int] = {"builds": 0, "hits": 0,
                                              "snapshot_walks": 0,
                                              "index_loads": 0}
        # commit_stats shows the scale-out claim: `commits` = landed shard
        # commits, `conflicts` = CommitConflicts observed by batches,
        # `retries` = rebased re-commit attempts (see WriteBatch)
        self.commit_stats: Dict[str, int] = {"commits": 0, "conflicts": 0,
                                             "retries": 0}

    @property
    def table(self) -> DeltaTable:
        """The first (or only) shard table.

        Unsharded stores keep the old single-table API intact through this
        alias; on sharded stores it doubles as the **meta shard** that holds
        non-tensor rows (checkpoint manifests) via ``WriteBatch.add_rows``.
        """
        return self.tables[0]

    @property
    def io(self) -> ReadExecutor:
        """Shared read executor all fetches for this store go through."""
        return self.tables[0].io

    # -- catalog / handles ---------------------------------------------------

    def _concrete_vector(self, version: VersionArg) -> Tuple[int, ...]:
        """Resolve a user-facing ``version=`` to one concrete int per shard
        (``None`` entries -> that shard's latest, probed concurrently)."""
        vv = resolve_version_vector(self.shards, version)
        if all(v is not None for v in vv):
            return tuple(int(v) for v in vv)
        if self.shards == 1:
            return (self.tables[0].version() if vv[0] is None else int(vv[0]),)
        return tuple(self.io.map(
            lambda tv: tv[0].version() if tv[1] is None else int(tv[1]),
            list(zip(self.tables, vv))))

    def _shard_source(self, shard: int, version: int) -> ShardSource:
        """One shard's catalog source: spilled index if present, else walk.

        A snapshot already replayed by this client is free — use it without
        probing for an index. Otherwise try the one-get spilled index
        (written at commit time past ``spill_threshold``); on miss, fall
        back to the O(files) snapshot walk. The accounting feeds
        ``catalog_stats['snapshot_walks'/'index_loads']``.
        """
        table = self.tables[shard]
        if version < 0:
            raise ObjectNotFoundError(f"no delta table at {table.path}")
        snap = table.log.cached_snapshot(version)
        if snap is not None:
            return ShardSource(version=version, snapshot=snap)
        if self.spill_threshold is not None:
            try:
                body = self.io.fetch(table.store,
                                     catalog_index_key(table.path, version))
            except ObjectNotFoundError:
                pass
            else:
                self.catalog_stats["index_loads"] += 1
                return ShardSource(version=version, index=json.loads(body))
        self.catalog_stats["snapshot_walks"] += 1
        return ShardSource(version=version, snapshot=table.snapshot(version))

    def catalog(self, version: VersionArg = None) -> Catalog:
        """The merged tensor index at ``version`` (latest if None).

        ``version`` is an int on 1-shard stores, a per-shard version vector
        on sharded stores. O(1) when the vector is already cached; a cold
        build resolves each shard from its spilled catalog index when one
        exists (one get), else by walking the snapshot.
        """
        key = self._concrete_vector(version)
        cat = self._catalogs.get(key)
        if cat is not None:
            self.catalog_stats["hits"] += 1
            self._catalogs.move_to_end(key)
            return cat
        if self.shards == 1:
            sources = [self._shard_source(0, key[0])]
        else:
            sources = self.io.map(lambda sv: self._shard_source(*sv),
                                  list(enumerate(key)))
        cat = Catalog(self, sources)
        self.catalog_stats["builds"] += 1
        self._catalogs[key] = cat
        while len(self._catalogs) > MAX_CACHED_CATALOGS:
            self._catalogs.popitem(last=False)
        return cat

    def lease(self, version: VersionArg = None) -> Lease:
        """Pin ``version`` (latest if None) against vacuum until released.

        The refcounted pin every :class:`TensorRef` takes implicitly,
        exposed for holders that outlive any single ref — e.g. the
        checkpointer retaining its last K checkpoints.
        """
        return self.leases.acquire(self._concrete_vector(version))

    def open(self, tid: str, *, version: VersionArg = None) -> TensorRef:
        """Lazy snapshot-pinned handle; fetches nothing until read."""
        return self.catalog(version).open(tid)

    def _header_for_path(self, path: str, shard: int = 0) -> Dict[str, Any]:
        cols = self._headers_by_path.get(path)
        if cols is not None:
            self._headers_by_path.move_to_end(path)
            return cols
        table = self.tables[shard]
        data = self.io.fetch(table.store, f"{table.path}/{path}")
        cols = columnar.read_table(data)
        self._seed_header(path, cols)
        return cols

    def _seed_header(self, path: str, cols: Dict[str, Any]) -> None:
        self._headers_by_path[path] = cols
        while len(self._headers_by_path) > MAX_CACHED_HEADERS:
            self._headers_by_path.popitem(last=False)

    # -- maintenance ---------------------------------------------------------

    def _maybe_spill(self, shard: int, version: int,
                     adds_hint: Optional[int] = None) -> bool:
        """Spill the catalog index for a freshly committed shard version
        when the snapshot has crossed ``spill_threshold`` files.

        Cheap guard first: when the committer's previous snapshot is still
        cached and ``adds_hint`` (how many files the commit added) proves
        the threshold cannot have been crossed, skip without any replay —
        small stores never pay a spill probe on their commit path.
        """
        if self.spill_threshold is None:
            return False
        table = self.tables[shard]
        if adds_hint is not None:
            prev = table.log.cached_snapshot(version - 1)
            if prev is not None and \
                    len(prev.files) + adds_hint < self.spill_threshold:
                return False
        snap = table.snapshot(version)
        if len(snap.files) < self.spill_threshold:
            return False
        self._spill_index(table, snap)
        return True

    def _spill_index(self, table: DeltaTable, snap) -> None:
        body = json.dumps(build_catalog_index(snap),
                          separators=(",", ":")).encode("utf-8")
        # plain put: content is deterministic per version, so a racing
        # re-spill writes identical bytes — last writer wins harmlessly
        table.store.put(catalog_index_key(table.path, snap.version), body)

    def spill_catalog(self, version: VersionArg = None) -> List[str]:
        """Force-write the per-shard catalog index at ``version`` (latest
        if None), regardless of threshold; returns the keys written.
        Operators use this to backfill indexes onto pre-existing tables."""
        key = self._concrete_vector(version)
        written = []
        for shard, v in enumerate(key):
            table = self.tables[shard]
            self._spill_index(table, table.snapshot(v))
            written.append(catalog_index_key(table.path, v))
        return written

    def _evict_headers(self, paths: Sequence[str]) -> None:
        for p in paths:
            self._headers_by_path.pop(p, None)

    def compact(self, *, recompress: Union[None, str, CompressionSpec] = None,
                ) -> List[CompactResult]:
        """OPTIMIZE every shard table (fanned out on the executor).

        Rewritten files keep their codec; ``recompress="zlib+shuffle"``
        re-encodes every non-header data file under that codec instead —
        the in-place migration path for stores written before compression
        existed (exposed as ``repro.launch.gc --recompress``). Live leased
        snapshots keep reading their original bytes: compact adds files,
        vacuum is what eventually deletes the old generation.

        Compacted-away paths are evicted from the header and block caches —
        their bytes survive until vacuum, but a stale cache entry must not
        mask a storage-level problem. No-op shards commit nothing.
        """
        spec = parse_compression(recompress)
        if self.shards == 1:
            results = [self.tables[0].compact(recompress=spec)]
        else:
            results = self.io.map(lambda t: t.compact(recompress=spec),
                                  self.tables)
        for shard, res in enumerate(results):
            if not res:
                continue
            table = self.tables[shard]
            self._evict_headers(res.removed_paths)
            table.io.invalidate(table.store,
                                [f"{table.path}/{p}" for p in res.removed_paths])
            self._maybe_spill(shard, res.version)
        return results

    def _retention_horizon(self, shard: int, latest: int,
                           keep_versions: int,
                           ttl_s: Optional[float]) -> int:
        """Oldest version this shard must keep under the policy (leases are
        added on top by the caller)."""
        horizon = max(0, latest - (keep_versions - 1))
        if ttl_s is not None:
            cutoff = time.time() - ttl_s
            log = self.tables[shard].log
            v = horizon
            while v > 0:
                ts = log.commit_ts(v - 1)
                if ts is None or ts < cutoff:
                    break
                v -= 1
            horizon = v
        return horizon

    def vacuum(self, *, keep_versions: Optional[int] = None,
               ttl_s: Optional[float] = None,
               dry_run: bool = False) -> List[VacuumResult]:
        """Delete files unreachable from any retained or leased snapshot.

        Per shard, the retention horizon keeps the newest
        ``keep_versions`` versions (default: the store's
        :class:`~repro.core.leases.RetentionPolicy`) plus every version
        younger than ``ttl_s``; versions pinned by live leases — every open
        :class:`TensorRef`, every checkpoint retained by the checkpointer —
        are kept whatever their age, so pinned reads and time travel within
        the horizon keep working. Deleted paths are evicted from the block
        and header caches, and catalogs cached for now-unreachable versions
        are dropped. ``dry_run`` reports without deleting.
        """
        keep = self.retention.keep_versions if keep_versions is None \
            else max(1, int(keep_versions))
        ttl = self.retention.ttl_s if ttl_s is None else ttl_s

        def one(shard: int) -> VacuumResult:
            table = self.tables[shard]
            latest = table.version()
            horizon = self._retention_horizon(shard, latest, keep, ttl)
            leased = self.leases.leased_versions(shard)
            return table.vacuum(horizon=horizon,
                                extra_versions=sorted(leased),
                                dry_run=dry_run)

        if self.shards == 1:
            results = [one(0)]
        else:
            results = self.io.map(one, list(range(self.shards)))
        if not dry_run:
            for shard, res in enumerate(results):
                self._evict_headers(res.deleted_paths)
                # catalogs pinned outside this shard's retained set now
                # reference deleted files — drop them from the cache
                # (pop, not del: a concurrent reader may race the LRU)
                retained = set(res.retained_versions)
                for key in [k for k in self._catalogs
                            if k[shard] not in retained]:
                    self._catalogs.pop(key, None)
        return results

    # -- write -------------------------------------------------------------

    def _resolve_tid(self, tensor: Any, layout: str,
                     tensor_id: Optional[str]) -> Tuple[str, str]:
        """Resolve (layout, tensor_id) without encoding or uploading anything,
        so callers can run existence checks before paying any upload."""
        if layout == "auto":
            layout = choose_layout(tensor)
        get_codec(layout)  # fail fast on unknown layouts
        return layout, tensor_id or f"{layout}-{uuid.uuid4().hex[:12]}"

    def shard_of(self, tensor_id: str) -> int:
        """Shard index the router assigns ``tensor_id`` (0 when unsharded)."""
        return self.router.shard_of(tensor_id)

    def _tensor_itemsize(self, tensor: Any) -> int:
        """Dtype width of ``tensor`` — what the byte-shuffle filter
        transposes on. SparseCOO carriers report their values' dtype."""
        dt = getattr(tensor, "dtype", None)
        if dt is None:
            dt = getattr(getattr(tensor, "values", None), "dtype", None)
        if dt is None:
            dt = np.asarray(tensor).dtype
        return np.dtype(dt).itemsize

    def _encode_and_upload(self, tensor: Any, *, layout: str,
                           tensor_id: str,
                           target_file_bytes: Optional[int] = None,
                           guard=None,
                           compression: Union[None, str, CompressionSpec] = None,
                           **codec_params):
        """Encode + upload part files (no commit). ``layout``/``tensor_id``
        must already be resolved (see :meth:`_resolve_tid`). Returns
        ``(shard, add_actions, header_seed)`` where ``shard`` is the router-
        assigned shard the files were uploaded into and header_seed is
        ``(path, columns)`` for post-commit caching, or None. ``guard`` (an
        :class:`~repro.lake.table.UploadGuard`) registers each upload so
        concurrent vacuum spares the not-yet-committed files.

        ``compression`` overrides the store default for this tensor's
        chunk files; headers always land raw (tiny, latency-critical, and
        a codec-less client must still be able to stat shapes)."""
        codec = get_codec(layout)
        tid = tensor_id
        shard = self.router.shard_of(tid)
        table = self.tables[shard]
        target = TARGET_FILE_BYTES if target_file_bytes is None else target_file_bytes
        spec = parse_compression(compression)
        if spec is None:
            spec = self.compression
        itemsize = self._tensor_itemsize(tensor) if spec is not None else 1
        groups = codec.encode(tensor, **{k: v for k, v in codec_params.items()
                                         if v is not None})
        adds: List[Dict[str, Any]] = []
        header_seed = None
        for grp in groups:
            rows = len(next(iter(grp.columns.values())))
            per_file = max(1, int(target //
                                  max(_approx_row_bytes(grp.columns, rows), 1)))
            grp_spec = spec if grp.kind != "header" else None
            for lo in range(0, rows, per_file):
                cols = _slice_columns(grp.columns, lo, min(rows, lo + per_file))
                adds.append(table.append(
                    cols, commit=False, guard=guard,
                    compression=grp_spec, shuffle_itemsize=itemsize,
                    partition_values={"tensor": tid, "kind": grp.kind,
                                      "layout": layout}))
            if grp.kind == "header":
                header_seed = (adds[-1]["path"], grp.columns)
        return shard, adds, header_seed

    def put_deferred(self, tensor: Any, *, layout: str = "auto",
                     tensor_id: Optional[str] = None,
                     target_file_bytes: int = TARGET_FILE_BYTES,
                     compression: Union[None, str, CompressionSpec] = None,
                     **codec_params) -> List[Dict[str, Any]]:
        """Upload part files WITHOUT committing; returns add-actions.

        Low-level two-phase building block (callers pass the adds to
        ``table.commit_adds`` themselves — on a sharded store that table is
        ``store.tables[store.shard_of(tid)]``). Prefer :meth:`batch`, which
        also handles overwrites/deletes, shard routing, and post-commit
        header caching. Note no header is cached here — an abandoned upload
        must leave no trace.
        """
        layout, tid = self._resolve_tid(tensor, layout, tensor_id)
        _shard, adds, _ = self._encode_and_upload(
            tensor, layout=layout, tensor_id=tid,
            target_file_bytes=target_file_bytes, compression=compression,
            **codec_params)
        return adds

    def batch(self, *, op: str = "WRITE BATCH",
              commit_retries: Optional[int] = None) -> WriteBatch:
        """Stage many puts/deletes; commit atomically per shard.

        On an unsharded store the whole batch is ONE commit. On a sharded
        store staged actions split by shard and land as one atomic commit
        per touched shard, each fenced against the batch's base snapshot
        with a bounded commit-retry/rebase loop on ``CommitConflict``
        (``commit_retries`` bounds it; see :class:`WriteBatch`).
        """
        return WriteBatch(self, op=op, commit_retries=commit_retries)

    def put(self, tensor: Any, *, layout: str = "auto", tensor_id: Optional[str] = None,
            overwrite: bool = False, target_file_bytes: int = TARGET_FILE_BYTES,
            compression: Union[None, str, CompressionSpec] = None,
            **codec_params) -> str:
        """Store one tensor in its own atomic commit; returns its id.

        ``layout`` picks the encoding codec (``"auto"`` = the 10% sparsity
        policy); ``compression`` overrides the store's default chunk-blob
        codec for this tensor (e.g. ``"zlib+shuffle"``). Raises
        ``ValueError`` if ``tensor_id`` exists and ``overwrite`` is False.
        Sugar for a one-put :meth:`batch`.
        """
        with self.batch(op="PUT TENSOR") as b:
            tid = b.put(tensor, layout=layout, tensor_id=tensor_id,
                        overwrite=overwrite, target_file_bytes=target_file_bytes,
                        compression=compression, **codec_params)
        return tid

    def delete(self, tid: str) -> None:
        """Remove ``tid``'s files from the latest snapshot (one commit).

        Older snapshots still see the tensor until :meth:`vacuum`; missing
        ids are a no-op (sugar for a one-delete :meth:`batch`).
        """
        with self.batch(op="DELETE TENSOR") as b:
            b.delete(tid, missing_ok=True)

    # -- read (legacy eager wrappers over the handle API) --------------------

    def get(self, tid: str, *, version: VersionArg = None) -> np.ndarray:
        """Eager full read of ``tid`` at ``version`` (latest if None)."""
        with self.open(tid, version=version) as ref:
            return ref.read()

    def get_coo(self, tid: str, *, version: VersionArg = None) -> SparseCOO:
        """Eager sparse read (native when the layout supports COO)."""
        with self.open(tid, version=version) as ref:
            return ref.read_coo()

    def get_slice(self, tid: str, slices: Sequence[Optional[Tuple[int, int]]], *,
                  version: VersionArg = None) -> np.ndarray:
        """Eager read-slice (the paper's Eq. (2) leading-dims window)."""
        with self.open(tid, version=version) as ref:
            return ref.read_slice(slices)

    def read_many(self, requests: Sequence[Tuple[str, Optional[Sequence]]], *,
                  version: VersionArg = None,
                  window: Optional[int] = None) -> List[np.ndarray]:
        """Read many ``(tid, slices)`` requests through ONE merged fetch
        plan (see :meth:`~repro.core.catalog.Catalog.read_many`): shared
        chunk keys are fetched once, adjacent requests' files stream
        through the windowed executor, and each request decodes as soon
        as its last file lands. ``slices=None`` reads a tensor in full.
        Results come back in request order, all pinned to one snapshot.
        """
        return self.catalog(version).read_many(requests, window=window)

    # -- catalog conveniences -------------------------------------------------

    def list_tensors(self, version: VersionArg = None) -> List[Tuple[str, str]]:
        """Sorted ``(tensor_id, layout)`` pairs at ``version``."""
        return self.catalog(version).tensors()

    def shape_of(self, tid: str, *, version: VersionArg = None) -> Tuple[int, ...]:
        """Dense shape from the header only (one tiny fetch, cached)."""
        with self.open(tid, version=version) as ref:
            return ref.shape

    def tensor_bytes(self, tid: str, *, version: VersionArg = None) -> int:
        """Stored bytes across the tensor's files (no data fetches)."""
        with self.open(tid, version=version) as ref:
            return ref.nbytes

    def storage_stats(self, version: VersionArg = None) -> Dict[str, Any]:
        """Logical vs physical bytes of the store at ``version`` — the
        paper's space-efficiency claim, measurable.

        Walks the (cached) catalog's add-actions, so it costs no data
        fetches. Returns::

            {"tensors": int, "files": int,
             "physical_bytes": int,   # stored (possibly compressed)
             "logical_bytes": int,    # pre-compression file bytes
             "ratio": float,          # logical / physical  (>= 1.0 good)
             "compression": str,      # the store's default codec spec
             "by_codec": {codec_id: {"files", "physical_bytes",
                                     "logical_bytes", "ratio"}}}

        Files written before compression existed count under codec
        ``"none"`` with ratio 1.0 — so a half-migrated store shows exactly
        how much of it still holds raw bytes (what ``gc --recompress``
        would win).
        """
        cat = self.catalog(version)
        by_codec: Dict[str, Dict[str, Any]] = {}
        files = physical = logical = 0
        for tid in cat:
            entry = cat.entry(tid)
            for add in entry.header_adds + entry.chunk_adds:
                codec = add.get("codec", "none")
                phys = int(add.get("size", 0))
                logi = int(add.get("rawSize", phys))
                rec = by_codec.setdefault(
                    codec, {"files": 0, "physical_bytes": 0,
                            "logical_bytes": 0})
                rec["files"] += 1
                rec["physical_bytes"] += phys
                rec["logical_bytes"] += logi
                files += 1
                physical += phys
                logical += logi
        for rec in by_codec.values():
            rec["ratio"] = (rec["logical_bytes"] / rec["physical_bytes"]
                            if rec["physical_bytes"] else 1.0)
        return {"tensors": len(cat), "files": files,
                "physical_bytes": physical, "logical_bytes": logical,
                "ratio": logical / physical if physical else 1.0,
                "compression": self.compression.id if self.compression
                else "none",
                "by_codec": by_codec}

    def io_stats(self) -> Dict[str, Any]:
        """Read-path counters + per-request latency percentiles — the
        ``catalog_stats``-style report for the executor this store's
        fetches run through (shared across stores when it is the process
        default executor). Latencies are virtual-clock durations on a
        modeled object store, wall clock otherwise::

            {"gets", "cache_hits", "cache_misses",
             "hedges_launched", "hedges_won",
             "plans", "plan_requests",          # read_many scheduling
             "plan_keys_fetched", "plan_keys_deduped",
             "latency": {"count", "mean_s", "p50_s", "p95_s",
                         "p99_s", "max_s"}}
        """
        s = self.io.stats
        return {"gets": s.gets, "cache_hits": s.cache_hits,
                "cache_misses": s.cache_misses,
                "hedges_launched": s.hedges_launched,
                "hedges_won": s.hedges_won,
                "plans": s.plans, "plan_requests": s.plan_requests,
                "plan_keys_fetched": s.plan_keys_fetched,
                "plan_keys_deduped": s.plan_keys_deduped,
                "latency": s.latency.summary()}

    def version(self) -> Union[int, Tuple[int, ...]]:
        """Latest version: an int (1-shard) or the per-shard version vector."""
        if self.shards == 1:
            return self.tables[0].version()
        return self.version_vector()

    def version_vector(self) -> Tuple[int, ...]:
        """Latest per-shard versions, probed concurrently on the executor."""
        if self.shards == 1:
            return (self.tables[0].version(),)
        return tuple(self.io.map(lambda t: t.version(), self.tables))
