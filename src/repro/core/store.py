"""DeltaTensorStore — the paper's system: tensors in a delta table.

``put`` encodes a tensor with one of the five codecs and lands the row
groups as parq-lite files in a single atomic commit, partitioned by
``(tensor, kind)``. ``get``/``get_slice`` are the paper's read-tensor /
read-slice operations: slice reads fetch the 1-row header, derive pushdown
filters from the codec, and touch only the chunk files whose min/max stats
overlap the slice. ``version=`` arguments give Delta time travel.

All chunk fetches flow through the table's shared ``ReadExecutor``
(``repro.lake.io``): surviving chunk files are fetched concurrently, decode
streams in plan order as gets complete, repeat reads hit the block cache.
"""

from __future__ import annotations

import uuid
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..lake import DeltaTable, ObjectStore, ReadExecutor
from .encodings import base as enc_base
from .encodings.base import (RowGroup, SparseCOO, get_codec, header_shape,
                             is_header, normalize_slices)
from .sparsity import choose_layout

TARGET_FILE_BYTES = 4 << 20


def _approx_row_bytes(columns: Dict[str, Any], rows: int) -> float:
    total = 0
    for v in columns.values():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            total += v.nbytes
        else:
            for item in v:
                if isinstance(item, (bytes, bytearray)):
                    total += len(item)
                elif isinstance(item, np.ndarray):
                    total += item.nbytes
                else:
                    total += 8
    return total / max(rows, 1)


def _slice_columns(columns: Dict[str, Any], lo: int, hi: int) -> Dict[str, Any]:
    out = {}
    for k, v in columns.items():
        if isinstance(v, np.ndarray) and v.dtype.kind != "O":
            out[k] = v[lo:hi]
        else:
            out[k] = list(v[lo:hi])
    return out


class DeltaTensorStore:
    def __init__(self, object_store: ObjectStore, root: str = "tensor_store",
                 io: Optional[ReadExecutor] = None):
        self.table = DeltaTable.create(object_store, root, io=io)
        self._header_cache: Dict[str, Dict[str, Any]] = {}

    @property
    def io(self) -> ReadExecutor:
        """Shared read executor all fetches for this store go through."""
        return self.table.io

    # -- write -------------------------------------------------------------

    def put_deferred(self, tensor: Any, *, layout: str = "auto",
                     tensor_id: Optional[str] = None,
                     target_file_bytes: int = TARGET_FILE_BYTES,
                     **codec_params) -> List[Dict[str, Any]]:
        """Upload part files WITHOUT committing; returns add-actions.

        Callers batch many tensors into one atomic ``table.commit_adds``
        (the distributed-checkpoint two-phase commit).
        """
        if layout == "auto":
            layout = choose_layout(tensor)
        codec = get_codec(layout)
        tid = tensor_id or f"{layout}-{uuid.uuid4().hex[:12]}"
        groups = codec.encode(tensor, **{k: v for k, v in codec_params.items()
                                         if v is not None})
        adds = []
        for grp in groups:
            rows = len(next(iter(grp.columns.values())))
            per_file = max(1, int(target_file_bytes //
                                  max(_approx_row_bytes(grp.columns, rows), 1)))
            for lo in range(0, rows, per_file):
                cols = _slice_columns(grp.columns, lo, min(rows, lo + per_file))
                adds.append(self.table.append(
                    cols, commit=False,
                    partition_values={"tensor": tid, "kind": grp.kind,
                                      "layout": layout}))
            if grp.kind == "header":
                self._header_cache[tid] = grp.columns
        return adds

    def put(self, tensor: Any, *, layout: str = "auto", tensor_id: Optional[str] = None,
            overwrite: bool = False, target_file_bytes: int = TARGET_FILE_BYTES,
            **codec_params) -> str:
        if layout == "auto":
            layout = choose_layout(tensor)
        tid = tensor_id or f"{layout}-{uuid.uuid4().hex[:12]}"

        existing = [a["path"] for a in self.table.files()
                    if a.get("partitionValues", {}).get("tensor") == tid]
        if existing and not overwrite:
            raise ValueError(f"tensor {tid!r} already exists (use overwrite=True)")

        adds = self.put_deferred(tensor, layout=layout, tensor_id=tid,
                                 target_file_bytes=target_file_bytes,
                                 **codec_params)
        self.table.commit_adds(adds, removes=existing, op="PUT TENSOR")
        return tid

    # -- read --------------------------------------------------------------

    def _layout_of(self, tid: str, version: Optional[int]) -> str:
        for a in self.table.files(version):
            pv = a.get("partitionValues", {})
            if pv.get("tensor") == tid:
                return pv["layout"]
        raise KeyError(f"tensor {tid!r} not found")

    def _header(self, tid: str, version: Optional[int]) -> Dict[str, Any]:
        if version is None and tid in self._header_cache:
            return self._header_cache[tid]
        batches = list(self.table.scan(
            partition_filters={"tensor": tid, "kind": "header"}, version=version))
        if not batches:
            raise KeyError(f"tensor {tid!r}: no header")
        if version is None:
            self._header_cache[tid] = batches[0]
        return batches[0]

    def get(self, tid: str, *, version: Optional[int] = None) -> np.ndarray:
        layout = self._layout_of(tid, version)
        codec = get_codec(layout)
        groups = [self._header(tid, version)]
        groups += list(self.table.scan(
            partition_filters={"tensor": tid, "kind": "chunk"}, version=version))
        return codec.decode(groups)

    def get_coo(self, tid: str, *, version: Optional[int] = None) -> SparseCOO:
        layout = self._layout_of(tid, version)
        codec = get_codec(layout)
        groups = [self._header(tid, version)]
        groups += list(self.table.scan(
            partition_filters={"tensor": tid, "kind": "chunk"}, version=version))
        if hasattr(codec, "decode_coo"):
            return codec.decode_coo(groups)
        return SparseCOO.from_dense(codec.decode(groups))

    def get_slice(self, tid: str, slices: Sequence[Optional[Tuple[int, int]]], *,
                  version: Optional[int] = None) -> np.ndarray:
        layout = self._layout_of(tid, version)
        codec = get_codec(layout)
        header = self._header(tid, version)
        spec = normalize_slices(header_shape(header), slices)
        filters = codec.slice_filters(header, spec)
        groups: List[Dict[str, Any]] = [header]
        groups += list(self.table.scan(
            filters=filters or None,
            partition_filters={"tensor": tid, "kind": "chunk"}, version=version))
        return codec.decode_slice(groups, spec)

    # -- catalog -------------------------------------------------------------

    def list_tensors(self, version: Optional[int] = None) -> List[Tuple[str, str]]:
        seen = {}
        for a in self.table.files(version):
            pv = a.get("partitionValues", {})
            if "tensor" in pv:
                seen[pv["tensor"]] = pv["layout"]
        return sorted(seen.items())

    def shape_of(self, tid: str, *, version: Optional[int] = None) -> Tuple[int, ...]:
        return header_shape(self._header(tid, version))

    def tensor_bytes(self, tid: str, *, version: Optional[int] = None) -> int:
        return sum(a["size"] for a in self.table.files(version)
                   if a.get("partitionValues", {}).get("tensor") == tid)

    def delete(self, tid: str) -> None:
        removes = [a["path"] for a in self.table.files()
                   if a.get("partitionValues", {}).get("tensor") == tid]
        if removes:
            self.table.commit_adds([], removes=removes, op="DELETE TENSOR")
        self._header_cache.pop(tid, None)

    def version(self) -> int:
        return self.table.version()
