"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  Fig. 12  -> bench_dense_ftsf      (dense: binary vs FTSF)
  Fig. 13-16 -> bench_sparse_formats (sparse: COO/CSR/CSF/BSGS vs PT)
  Eq. 8 hot loops -> bench_kernels
  DESIGN §2 wire compression -> bench_grad_compress
  §Roofline -> roofline (from dry-run artifacts, if present)
  read-path scaling -> bench_read_path (serial vs parallel vs cached)
  shard scale-out -> bench_shard_scale (commit throughput vs shard count)
  maintenance lifecycle -> bench_maintenance (churn reclaim, spilled index)
"""


def main() -> None:
    from . import (bench_dense_ftsf, bench_grad_compress, bench_kernels,
                   bench_maintenance, bench_read_path, bench_shard_scale,
                   bench_sparse_formats, roofline)
    print("name,us_per_call,derived")
    for mod in (bench_dense_ftsf, bench_sparse_formats, bench_kernels,
                bench_grad_compress, roofline, bench_read_path,
                bench_shard_scale, bench_maintenance):
        try:
            for line in mod.run():
                print(line)
        except Exception as e:  # keep the harness running end to end
            print(f"{mod.__name__}_ERROR,0.0,{type(e).__name__}: {e}")


if __name__ == '__main__':
    main()
