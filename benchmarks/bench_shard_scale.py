"""Shard scale-out: commit throughput vs shard count under concurrent writers.

The single-table store serializes every writer on one delta log's
put-if-absent race; ``DeltaTensorStore(shards=N)`` splits the logical store
into N independent commit domains. This bench measures, on the paper's
modeled object store (1 Gbps, 10 ms RTT, virtual clock):

* **commit throughput** — W concurrent writer threads (1/4/8), each landing
  batches of tensors through the fenced commit-retry/rebase loop, against
  stores with 1/4/8 shards. Writers on one shard conflict and pay rebase
  round-trips; writers spread over N shards mostly don't. Expected shape:
  >= 2x throughput at 4 shards vs 1 shard under 8 writers, and **zero lost
  writes** in every configuration (all conflicts resolved by retry/rebase);
* **conflict/retry counts** — how many CommitConflicts the rebase loop
  absorbed per configuration (the cost the sharding removes);
* **cross-shard read makespan** — a cold reader fanning one pinned
  version-vector catalog + all tensor reads out on the shared executor,
  showing reads stay flat as the shard count grows.

With ``--json`` (or :func:`run`'s ``json_path``) results land in
``BENCH_shard_scale.json`` so ``check_regression.py`` can gate PRs.
"""

from __future__ import annotations

import json
import threading

import numpy as np

from repro.configs.paper_store import PAPER_STORE
from repro.core import DeltaTensorStore
from repro.lake import InMemoryObjectStore, LatencyModel, ReadExecutor

from .common import row

SHARD_COUNTS = (1, 4, 8)
WRITER_COUNTS = (1, 4, 8)
COMMITS_PER_WRITER = 6
TENSORS_PER_COMMIT = 1         # 1 tensor/commit => each commit hits 1 shard
TENSOR_SHAPE = (8, 8)          # tiny payloads: the commit race dominates
COMMIT_RETRIES = 64            # generous bound — zero lost writes required
READ_TENSORS = 16
READ_SHAPE = (64, 64)


def _modeled_store(channels: int):
    lm = LatencyModel(rtt_s=PAPER_STORE["object_store"]["rtt_s"],
                      bandwidth_bps=PAPER_STORE["object_store"]["bandwidth_bps"],
                      virtual_clock=True, parallelism=max(channels, 8),
                      occupancy_scale=0.02)
    return InMemoryObjectStore(latency=lm), lm


def _write_workload(shards: int, writers: int):
    obj, lm = _modeled_store(channels=writers)
    io = ReadExecutor(max_workers=8, cache_bytes=0)
    try:
        DeltaTensorStore(obj, "tensors", io=io, shards=shards)  # create once
        # one client per writer thread, as real concurrent writers would be
        clients = [DeltaTensorStore(obj, "tensors", io=io)
                   for _ in range(writers)]
        start = threading.Barrier(writers + 1)
        errors = []

        def run_writer(wid: int, client: DeltaTensorStore):
            try:
                start.wait(timeout=60)
                for k in range(COMMITS_PER_WRITER):
                    with client.batch(commit_retries=COMMIT_RETRIES) as b:
                        for j in range(TENSORS_PER_COMMIT):
                            b.put(np.full(TENSOR_SHAPE, float(wid), np.float32),
                                  layout="ftsf",
                                  tensor_id=f"w{wid}-c{k}-t{j}")
            except BaseException as e:  # a lost write — reported below
                errors.append((wid, repr(e)))

        threads = [threading.Thread(target=run_writer, args=(w, c))
                   for w, c in enumerate(clients)]
        for t in threads:
            t.start()
        lm.reset()                      # measure the write traffic only
        start.wait(timeout=60)
        for t in threads:
            t.join(timeout=600)
        elapsed, requests = lm.elapsed_s, lm.requests

        # zero-lost-writes audit: every staged tensor must be readable with
        # its writer's value through a fresh client
        reader = DeltaTensorStore(obj, "tensors", io=io)
        lost = len(errors)
        for wid in range(writers):
            for k in range(COMMITS_PER_WRITER):
                for j in range(TENSORS_PER_COMMIT):
                    try:
                        got = reader.open(f"w{wid}-c{k}-t{j}").read()
                        if not np.array_equal(
                                got, np.full(TENSOR_SHAPE, float(wid),
                                             np.float32)):
                            lost += 1
                    except KeyError:
                        lost += 1

        batches = writers * COMMITS_PER_WRITER
        return {
            "batch_commits": batches,
            "shard_commits": sum(c.commit_stats["commits"] for c in clients),
            "conflicts": sum(c.commit_stats["conflicts"] for c in clients),
            "retries": sum(c.commit_stats["retries"] for c in clients),
            "elapsed_s": elapsed,
            "requests": requests,
            "throughput_cps": batches / elapsed if elapsed > 0 else float("inf"),
            "lost_writes": lost,
        }
    finally:
        io.shutdown()


def _read_workload(shards: int):
    obj, lm = _modeled_store(channels=8)
    io = ReadExecutor(max_workers=8, cache_bytes=0)
    try:
        store = DeltaTensorStore(obj, "tensors", io=io, shards=shards)
        rng = np.random.default_rng(0)
        with store.batch() as b:
            for i in range(READ_TENSORS):
                b.put(rng.standard_normal(READ_SHAPE).astype(np.float32),
                      layout="ftsf", tensor_id=f"r{i}")
        # cold reader: fresh client, empty block cache — pays the full
        # cross-shard snapshot + fetch fan-out
        reader = DeltaTensorStore(obj, "tensors",
                                  io=ReadExecutor(max_workers=8,
                                                  cache_bytes=0))
        lm.reset()
        cat = reader.catalog()
        futures = [cat.open(f"r{i}").read_async() for i in range(READ_TENSORS)]
        for f in futures:
            f.result()
        return {"tensors": READ_TENSORS, "makespan_s": lm.elapsed_s,
                "requests": lm.requests}
    finally:
        io.shutdown()


def run(json_path=None):
    lines = []
    results = {"bench": "shard_scale", "commits_per_writer": COMMITS_PER_WRITER,
               "tensors_per_commit": TENSORS_PER_COMMIT,
               "writers": {}, "read": {}, "throughput_ratio_vs_1shard_w8": {}}

    for writers in WRITER_COUNTS:
        per_shards = {}
        for shards in SHARD_COUNTS:
            r = _write_workload(shards, writers)
            per_shards[str(shards)] = r
            lines.append(row(
                f"shard_scale_commit_s{shards}_w{writers}",
                r["elapsed_s"] * 1e6 / max(r["batch_commits"], 1),
                f"throughput={r['throughput_cps']:.2f}cps "
                f"conflicts={r['conflicts']} retries={r['retries']} "
                f"lost={r['lost_writes']}"))
        results["writers"][str(writers)] = per_shards

    w8 = results["writers"].get("8", {})
    if "1" in w8:
        base = w8["1"]["throughput_cps"]
        for shards, r in sorted(w8.items(), key=lambda kv: int(kv[0])):
            if shards == "1":
                continue
            ratio = r["throughput_cps"] / base
            results["throughput_ratio_vs_1shard_w8"][shards] = ratio
            lines.append(row(f"shard_scale_speedup_s{shards}_w8", 0.0,
                             f"throughput={ratio:.2f}x_vs_1shard"))

    for shards in SHARD_COUNTS:
        r = _read_workload(shards)
        results["read"][str(shards)] = r
        lines.append(row(f"shard_scale_read_s{shards}",
                         r["makespan_s"] * 1e6,
                         f"tensors={r['tensors']} requests={r['requests']}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_shard_scale.json"):
        print(line)
