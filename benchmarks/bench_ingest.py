"""Streaming-ingest benchmark: watermark-commit throughput, live-reader
interference, and crash-at-every-seam recovery.

Three claims about :class:`~repro.data.ingest.IngestWriter` on the paper's
modeled object store (1 Gbps, 10 ms RTT):

* **Watermark parity** — appending row-at-a-time with a 64-row watermark
  costs no more modeled I/O per row than the batch baseline that ``put``s
  each 64-row group eagerly: the writer amortizes its commit overhead
  (header rewrite + fenced log entry) across the whole micro-batch, so
  streaming ingest is not a throughput tax (gate: >= 1.0x batch-put).
* **Readers never blocked** — a ``StreamLoader`` epoch over a training
  tensor, measured on the virtual clock while a writer commits watermark
  batches into the same store the whole time, finishes within 1.2x of the
  quiesced epoch: ingest commits are invisible to the pinned snapshot and
  only channel occupancy is shared.
* **Crash consistency** — a writer killed at every seam of a flush
  (mid-seal upload, after upload / before commit, torn data upload) tears
  ZERO visible versions, and vacuum reclaims EXACTLY the crash's orphans.

Run as ``python -m benchmarks.bench_ingest`` to (re)write
``BENCH_ingest.json`` for the regression gate.
"""

from __future__ import annotations

import json
import threading

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.stream import StreamLoader
from repro.lake import (FaultInjectingObjectStore, FaultRule, InjectedFault,
                        InMemoryObjectStore)

from .common import fresh_store, row

N_ROWS = 512
ROW_SHAPE = (256,)              # 1 KiB float32 rows
WATERMARK = 64
APPEND_CHUNK = 8                # producer hands the writer 8 rows at a time
READER_ROWS = 384
BATCH = 16
SEED = 7


def _rows(lo, hi):
    out = np.arange(lo * ROW_SHAPE[0], hi * ROW_SHAPE[0], dtype=np.float32)
    return out.reshape(hi - lo, *ROW_SHAPE)


def _run_ingest(parallelism=1):
    obj, lm = fresh_store(parallelism=parallelism)
    store = DeltaTensorStore(obj, "tensors")
    lm.reset()
    with store.ingest("t", watermark_rows=WATERMARK) as w:
        for lo in range(0, N_ROWS, APPEND_CHUNK):
            w.append_rows(_rows(lo, lo + APPEND_CHUNK))
    return lm.elapsed_s, w.stats(), store


def _run_batch_put(parallelism=1):
    """The eager baseline: every watermark-sized group lands as its own
    ``put`` (own header, own commit) the moment it is complete."""
    obj, lm = fresh_store(parallelism=parallelism)
    store = DeltaTensorStore(obj, "tensors")
    lm.reset()
    for g, lo in enumerate(range(0, N_ROWS, WATERMARK)):
        store.put(_rows(lo, lo + WATERMARK), tensor_id=f"g{g}",
                  layout="ftsf")
    return lm.elapsed_s


def _live_reader(parallelism=12, repeats=3):
    # The channel pool is wider than the reader's 8-wide executor — an
    # object store admits more concurrent streams than one host opens —
    # so the writer contends for the shared link, not for reader slots.
    def _seeded():
        obj, lm = fresh_store(parallelism=parallelism)
        store = DeltaTensorStore(obj, "tensors")
        store.put(_rows(0, READER_ROWS), tensor_id="train", layout="ftsf",
                  target_file_bytes=8 << 10)
        return lm, store

    def _epoch(lm, store, exclude_tids=(), on_pinned=None):
        # exclude_tids is read AFTER the epoch so late-started threads count
        """Reader-experienced virtual makespan of one epoch: the latest
        request completion across every thread working for the reader.
        Channel time booked by the concurrent writer delays those
        completions (queueing), but the writer's own chain is excluded —
        ``elapsed_s`` is the makespan over ALL threads and would report
        the writer's runtime instead."""
        loader = StreamLoader(store, "train", batch_size=BATCH, epochs=1,
                              seed=SEED, clock=lambda: lm.elapsed_s)
        if on_pinned is not None:
            on_pinned()
        batches = sum(1 for _ in loader)
        done = dict(lm._thread_done)
        skip = {t for t in exclude_tids if t is not None}
        dt = max((d for t, d in done.items() if t not in skip),
                 default=0.0)
        loader.close()
        assert batches == READER_ROWS // BATCH
        return dt

    # quiesced: the epoch with nothing else on the wire (best of repeats)
    quiesced_s = None
    for _ in range(repeats):
        lm, store = _seeded()
        lm.reset()
        dt = _epoch(lm, store)
        quiesced_s = dt if quiesced_s is None else min(quiesced_s, dt)

    # live: a writer commits 16-row watermark batches into the same store
    # for the whole epoch. It starts once the loader has pinned its
    # snapshot, so both runs replay the same log; everything after that —
    # uploads, header rewrites, fenced commits — races the entire epoch.
    live_s = None
    flushes = [0]
    for _ in range(repeats):
        lm, store = _seeded()
        stop = threading.Event()
        started = threading.Event()
        writer_tid = [None]

        def writer():
            writer_tid[0] = threading.get_ident()
            started.set()
            with store.ingest("events", watermark_rows=16) as w:
                lo = 0
                while not stop.is_set():
                    w.append_rows(_rows(lo, lo + APPEND_CHUNK))
                    lo += APPEND_CHUNK
                flushes[0] = w.flushes

        lm.reset()
        th = threading.Thread(target=writer)

        def go():
            th.start()
            started.wait()

        dt = _epoch(lm, store, exclude_tids=writer_tid, on_pinned=go)
        stop.set()
        th.join()
        live_s = dt if live_s is None else min(live_s, dt)
    return quiesced_s, live_s, flushes[0]


def _crash_seams():
    """Kill a writer at every seam of a flush; count torn versions and
    check vacuum reclaims exactly the crash's orphans."""
    seams = [
        ("mid-seal", FaultRule(op="put", key="part-", nth=2,
                               action="raise")),
        ("torn-upload", FaultRule(op="put", key="part-", nth=2,
                                  action="partial")),
        ("before-commit", FaultRule(op="put", key="_delta_log",
                                    action="raise")),
    ]
    torn = 0
    exact = True
    results = {}
    for name, rule in seams:
        faulty = FaultInjectingObjectStore(InMemoryObjectStore())
        store = DeltaTensorStore(faulty, "tensors")
        store.put(_rows(0, WATERMARK), tensor_id="t", layout="ftsf")
        v0 = store.version()
        live = set(faulty.list(""))
        w = store.ingest("t", watermark_rows=WATERMARK,
                         target_file_bytes=64 << 10)
        faulty.add_rule(rule)
        try:
            w.append_rows(_rows(WATERMARK, 2 * WATERMARK))
        except InjectedFault:
            pass
        else:  # pragma: no cover - the seam must fire
            raise AssertionError(f"seam {name} did not trigger")
        faulty.clear_rules()
        w.close(flush=False)

        # torn = the crash left a new visible version or broke the read
        if store.version() != v0 or \
                not np.array_equal(store.get("t"), _rows(0, WATERMARK)):
            torn += 1
        orphans = {k for k in set(faulty.list("")) - live
                   if "_delta_log" not in k}
        deleted = {p for r in store.vacuum() for p in r.deleted_paths}
        reclaim_ok = deleted == {k.split("/", 1)[1] for k in orphans}
        exact = exact and reclaim_ok and len(orphans) > 0
        results[name] = {"orphans": len(orphans),
                         "reclaimed": len(deleted),
                         "reclaim_exact": reclaim_ok}
    return torn, exact, results


def run(json_path=None):
    lines = []
    results = {"bench": "ingest", "rows": N_ROWS, "row_bytes": 4 * ROW_SHAPE[0],
               "watermark_rows": WATERMARK}

    ingest_s, stats, store = _run_ingest()
    batch_s = _run_batch_put()
    ingest_rps = N_ROWS / ingest_s
    batch_rps = N_ROWS / batch_s
    ratio = ingest_rps / batch_rps
    lines.append(row("ingest_watermark64", ingest_s / N_ROWS * 1e6,
                     f"rows_per_s={ingest_rps:.0f} batch_put={batch_rps:.0f} "
                     f"ratio={ratio:.2f}x flushes={stats['flushes']}"))
    results["ingest"] = {"io_s": ingest_s, "rows_per_s": ingest_rps,
                         "flushes": stats["flushes"],
                         "conflicts": stats["conflicts"]}
    results["batch_put"] = {"io_s": batch_s, "rows_per_s": batch_rps}

    quiesced_s, live_s, flushes = _live_reader()
    overhead = live_s / quiesced_s
    lines.append(row("ingest_live_reader", live_s * 1e6,
                     f"quiesced_s={quiesced_s:.3f} live_s={live_s:.3f} "
                     f"overhead={overhead:.2f}x writer_flushes={flushes}"))
    results["live_reader"] = {"quiesced_s": quiesced_s, "live_s": live_s,
                              "overhead": overhead,
                              "writer_flushes": flushes}

    torn, exact, seams = _crash_seams()
    lines.append(row("ingest_crash_seams", 0.0,
                     f"seams={len(seams)} torn_versions={torn} "
                     f"orphan_reclaim_exact={exact}"))
    results["crash"] = {"seams": seams, "torn_versions": torn,
                        "orphan_reclaim_exact": exact}

    results["gate"] = {
        "ingest_vs_batch_put": ratio,
        "live_reader_overhead": overhead,
        "torn_versions": torn,
        "orphan_reclaim_exact": exact,
    }
    lines.append(row("ingest_gate", 0.0,
                     f"ingest_vs_batch_put={ratio:.2f}x "
                     f"live_reader_overhead={overhead:.2f}x torn={torn}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_ingest.json"):
        print(line)
