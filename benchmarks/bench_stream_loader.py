"""Steady-state streaming-loader throughput vs serial eager gets.

The first *sustained* benchmark: instead of one-shot read makespans it
measures batches/s over a whole epoch on the virtual clock (paper testbed:
1 Gbps, 10 ms RTT object store). A 4-shard store holds four FTSF token
tensors; the :class:`~repro.data.stream.StreamLoader` streams shuffled
batches across all four with a windowed prefetch and ONE merged
``read_many`` fetch plan per batch. The serial baseline replays the exact
same batch plan the way the pre-stream loader fetched it: pinned refs and
one awaited ``read_slice`` per coalesced row-run, in sequence.

Reported:

* sustained loader batches/s vs serial-gets batches/s at widths 1 and 8
  (the gate: loader >= 2x serial at width 8 — cross-batch pipelining plus
  merged plans must beat per-run awaited gets);
* warm-vs-cold epoch ratio with a block cache (epoch 2 streams from
  decoded cache blocks; the modeled store sees ~zero requests);
* per-batch p99 fetch latency (loader histogram, virtual clock) and
  per-request p99 from the executor's new ``ReadStats`` histogram;
* peak prefetch memory vs the ``window x batch_bytes`` bound, and
  ``read_many`` chunk-key dedup counters.

Run as ``python -m benchmarks.bench_stream_loader`` to (re)write
``BENCH_stream_loader.json`` for the regression gate.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.stream import StreamLoader
from repro.data.synthetic import token_stream
from repro.lake import ReadExecutor

from .common import fresh_store, row

N_TENSORS = 4
SAMPLES_PER_TENSOR = 128
SEQ_LEN = 256                   # 1 KiB rows (int32)
TARGET_FILE_BYTES = 8 << 10     # ~8 rows per chunk file
BATCH = 16
WINDOW = 4
SHARDS = 4
SEED = 11


def _loaded_store(width: int, cache_bytes: int = 0):
    obj, lm = fresh_store(parallelism=width)
    io = ReadExecutor(max_workers=width, cache_bytes=cache_bytes)
    store = DeltaTensorStore(obj, "tensors", io=io, shards=SHARDS)
    tids = []
    for i in range(N_TENSORS):
        tid = f"corpus{i}"
        tokens = token_stream(SAMPLES_PER_TENSOR, SEQ_LEN, 50_000, seed=i)
        store.put(tokens.astype(np.int32), layout="ftsf", tensor_id=tid,
                  chunk_dims=1, target_file_bytes=TARGET_FILE_BYTES)
        tids.append(tid)
    return store, lm, tids


def _serial_epoch(store, loader: StreamLoader) -> int:
    """Replay the loader's epoch-0 plan the pre-stream way: pinned refs
    (as the old ``FTSFLoader`` held) with one awaited ``read_slice`` per
    coalesced row-run, batch after batch — no cross-run overlap, no
    cross-request key dedup."""
    offsets = loader._offsets
    refs = {t: loader.catalog.open(tid)
            for t, tid in enumerate(loader.tensor_ids)}
    batches = 0
    for step in range(loader.steps_per_epoch):
        rows = loader._rows_for(0, step)
        tensor_idx = np.searchsorted(offsets, rows, side="right") - 1
        for t in np.unique(tensor_idx):
            local = np.sort(rows[tensor_idx == t] - offsets[t])
            cuts = np.flatnonzero(np.diff(local) != 1) + 1
            for run in np.split(local, cuts):
                refs[int(t)].read_slice([(int(run[0]), int(run[-1]) + 1)])
        batches += 1
    for ref in refs.values():
        ref.close()
    return batches


def run(widths=(1, 8), json_path=None):
    lines = []
    results = {"bench": "stream_loader", "batch": BATCH, "window": WINDOW,
               "shards": SHARDS, "seq_len": SEQ_LEN,
               "samples": N_TENSORS * SAMPLES_PER_TENSOR,
               "target_file_bytes": TARGET_FILE_BYTES,
               "widths": {}, "warm": {}, "gate": {}}

    loader_bps = {}
    for width in widths:
        # serial baseline: same plan, eager per-run gets, same width store
        store, lm, tids = _loaded_store(width)
        plan_ref = StreamLoader(store, tids, batch_size=BATCH, seed=SEED,
                                window=WINDOW, epochs=1)
        lm.reset()
        store.io.stats.reset()
        n = _serial_epoch(store, plan_ref)
        serial_s = lm.elapsed_s
        serial_bps = n / serial_s
        plan_ref.close()

        # streaming loader: windowed prefetch + merged read_many plans
        store, lm, tids = _loaded_store(width)
        loader = StreamLoader(store, tids, batch_size=BATCH, seed=SEED,
                              window=WINDOW, epochs=1,
                              clock=lambda lm=lm: lm.elapsed_s)
        lm.reset()
        store.io.stats.reset()
        batches = sum(1 for _ in loader)
        loader_s = lm.elapsed_s
        bps = batches / loader_s
        loader_bps[width] = bps
        stats = loader.stats()
        iostats = store.io_stats()
        loader.close()

        ratio = bps / serial_bps
        lines.append(row(f"stream_loader_w{width}", loader_s / batches * 1e6,
                         f"batches_per_s={bps:.1f} serial={serial_bps:.1f} "
                         f"ratio={ratio:.2f}x deduped="
                         f"{iostats['plan_keys_deduped']}"))
        results["widths"][str(width)] = {
            "batches": batches,
            "loader_io_s": loader_s,
            "loader_batches_per_s": bps,
            "serial_io_s": serial_s,
            "serial_batches_per_s": serial_bps,
            "loader_vs_serial": ratio,
            "batch_latency": stats["batch_latency"],
            "request_latency": iostats["latency"],
            "peak_inflight_bytes": stats["peak_inflight_bytes"],
            "memory_bound_bytes": stats["memory_bound_bytes"],
            "plan_keys_fetched": iostats["plan_keys_fetched"],
            "plan_keys_deduped": iostats["plan_keys_deduped"],
        }

    # warm-vs-cold: same width-8 store with a block cache; epoch 2 streams
    # from decoded cached blocks (a fresh loader so no prefetch straddles)
    store, lm, tids = _loaded_store(8, cache_bytes=256 << 20)
    cold = StreamLoader(store, tids, batch_size=BATCH, seed=SEED,
                        window=WINDOW, epochs=1)
    lm.reset()
    n_cold = sum(1 for _ in cold)
    cold_s = lm.elapsed_s
    cold.close()
    warm = StreamLoader(store, tids, batch_size=BATCH, seed=SEED,
                        window=WINDOW, epochs=1)
    lm.reset()
    n_warm = sum(1 for _ in warm)
    warm_s = lm.elapsed_s
    warm_requests = lm.requests
    warm.close()
    warm_ratio = cold_s / warm_s if warm_s > 0 else None
    lines.append(row("stream_loader_warm_epoch", warm_s / n_warm * 1e6,
                     f"cold_io_s={cold_s:.3f} warm_io_s={warm_s:.3f} "
                     f"speedup={warm_ratio or 'inf'} requests={warm_requests}"))
    results["warm"] = {"cold_io_s": cold_s, "warm_io_s": warm_s,
                       "warm_requests": warm_requests,
                       "cold_over_warm": warm_ratio}

    w8 = results["widths"].get("8", {})
    results["gate"] = {
        "loader_vs_serial_w8": w8.get("loader_vs_serial"),
        "batch_p99_s": (w8.get("batch_latency") or {}).get("p99_s"),
        "request_p99_s": (w8.get("request_latency") or {}).get("p99_s"),
        "peak_inflight_bytes": w8.get("peak_inflight_bytes"),
        "memory_bound_bytes": w8.get("memory_bound_bytes"),
        "memory_bounded": (w8.get("peak_inflight_bytes", 0) <=
                           w8.get("memory_bound_bytes", 0)),
    }
    lines.append(row("stream_loader_gate", 0.0,
                     f"loader_vs_serial_w8="
                     f"{results['gate']['loader_vs_serial_w8']:.2f}x "
                     f"p99={results['gate']['batch_p99_s']} "
                     f"memory_bounded={results['gate']['memory_bounded']}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_stream_loader.json"):
        print(line)
