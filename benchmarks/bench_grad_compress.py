"""Cross-pod gradient compression: wire bytes and fidelity vs ratio.

The paper's BSGS on the wire (DESIGN.md §2): block-top-k + error feedback.
Reported per compression ratio: bytes on the cross-pod link vs dense
all-reduce, and the relative L2 error of one compressed step (error
feedback re-injects the remainder on later steps — see
tests/test_train_e2e.py for the convergence check).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.train import grad_compress

from .common import row


def run():
    lines = []
    rng = np.random.default_rng(0)
    # two pods' worth of gradients: row-sparse structure (embedding/adapter
    # grads touch few rows per step) + broadband noise floor
    hot_rows = rng.choice(512, 40, replace=False)
    g = 0.03 * rng.standard_normal((2, 512, 1024))
    g[:, hot_rows, :] += rng.standard_normal((2, 40, 1024))
    g = jnp.asarray(g, jnp.float32)
    r = jnp.zeros_like(g)

    # block-shape sensitivity — the paper's §IV.F point that block size is
    # the central tuning knob: (1,128) blocks align with row-sparse grads
    for block in ((8, 128), (1, 128)):
        for ratio in (0.01, 0.05, 0.25):
            mean, new_r, stats = grad_compress.compressed_grad_mean(
                {"w": g}, {"w": r}, ratio=ratio, block=block)
            dense_mean = jnp.mean(g, axis=0)
            err = float(jnp.linalg.norm(mean["w"] - dense_mean) /
                        jnp.linalg.norm(dense_mean))
            wire = grad_compress.compression_ratio_bytes(stats)
            lines.append(row(f"grad_compress_b{block[0]}x{block[1]}_r{ratio}",
                             0.0, f"wire_ratio={wire:.4f};rel_err={err:.4f}"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
