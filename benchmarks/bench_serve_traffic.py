"""Multi-tenant serving gateway under mixed cold-start/warm traffic.

Open-loop traffic (arrivals don't wait for completions) against the
modeled object store (paper testbed: 1 Gbps, 10 ms RTT, virtual clock)
through :class:`~repro.serve.gateway.Gateway`, in three phases:

* **cold-start coalescing** — N tenants simultaneously cold-start the
  same fine-tune variant. Baseline: N independent frontends (separate
  ``DeltaTensorStore`` clients, private cold-cache executors) each
  running its own ``ModelRepo.load`` against the shared object store.
  Gateway: the same N loads single-flighted on ``(prefix, version)`` —
  one merged fetch plan, the variant's delta-base chunks fetched once.
  Gate: the baseline issues >= 2x the store requests.
* **cache partitioning** — a hot tenant's base model is pinned in a
  budgeted "hot" priority class while long-tail tenants churn variant
  reads through an undersized default partition. Gate: the long-tail
  churn evicts constantly, yet a warm re-read of the hot base (pinned
  version vector) issues ZERO object-store requests.
* **fairness + SLO + shedding** — 8 equal-weight tenants burst-submit
  adversarially ordered (tenant 0's whole batch first); weighted fair
  queueing must serve them evenly anyway. Gates: mid-run Jain index over
  per-tenant work done >= 0.8; per-tenant p99 (virtual clock) non-null;
  a flooding tenant with a bounded queue sheds with ``RetryAfter``
  instead of deadlocking.

Run as ``python -m benchmarks.bench_serve_traffic`` to (re)write
``BENCH_serve_traffic.json`` for the regression gate.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DeltaTensorStore
from repro.lake import ReadExecutor
from repro.serve import Gateway, ModelRepo, RetryAfter, TenantPolicy

from .common import fresh_store, row

N_TENANTS = 6          # coalescing phase: tenants cold-starting one model
N_LEAVES = 6
LEAF_SHAPE = (64, 1024)            # 256 KiB float32 per leaf
N_VARIANTS = 8         # long-tail churn working set
FAIR_TENANTS = 8
FAIR_JOBS = 24         # reads each fairness tenant burst-submits
SEED = 23


def _params(seed: int, scale: float = 1.0):
    rng = np.random.default_rng(seed)
    return {f"layer{i}": (scale * rng.standard_normal(LEAF_SHAPE)
                          ).astype(np.float32)
            for i in range(N_LEAVES)}


def _seeded_store(width: int, cache_bytes: int = 0, variants: int = 1):
    """Modeled store holding a base model + ``variants`` fine-tunes."""
    obj, lm = fresh_store(parallelism=width)
    io = ReadExecutor(max_workers=width, cache_bytes=cache_bytes)
    store = DeltaTensorStore(obj, "weights", io=io)
    base = _params(SEED)
    with store.models("base") as repo:
        repo.save(base)
        for v in range(variants):
            # sparse perturbation: most chunks dedup, changed ones XOR-delta
            ft = {k: arr.copy() for k, arr in base.items()}
            ft[f"layer{v % N_LEAVES}"] = ft[f"layer{v % N_LEAVES}"] + 0.01
            with repo.open_variant(f"ft{v}") as var:
                var.save(ft)
    return obj, lm, store, base


# -- phase 1: cold-start coalescing -------------------------------------------

def _bench_coalesce():
    template = _params(SEED)

    # baseline: N independent frontends, each a private client + executor
    obj, lm, store, _ = _seeded_store(width=8)
    clients = [DeltaTensorStore(obj, "weights",
                                io=ReadExecutor(max_workers=8))
               for _ in range(N_TENANTS)]
    lm.reset()
    for client in clients:
        with ModelRepo(client, "base~ft0") as repo:
            repo.load(template)
    uncoalesced_requests = lm.requests
    uncoalesced_io_s = lm.elapsed_s

    # gateway: same N loads, single-flighted on (prefix, pinned version)
    obj, lm, store, _ = _seeded_store(width=8)
    with Gateway(store, max_inflight=8,
                 clock=lambda: lm.elapsed_s) as gw:
        lm.reset()
        futures = [gw.load_model(f"t{i}", "base~ft0", template)
                   for i in range(N_TENANTS)]
        trees = [f.result(60) for f in futures]
        coalesced_requests = lm.requests
        coalesced_io_s = lm.elapsed_s
        stats = gw.stats()
    ref = trees[0]
    identical = all(
        all(np.array_equal(t[k], ref[k]) for k in ref) for t in trees)

    ratio = uncoalesced_requests / max(1, coalesced_requests)
    return {
        "tenants": N_TENANTS,
        "uncoalesced_requests": uncoalesced_requests,
        "uncoalesced_io_s": uncoalesced_io_s,
        "coalesced_requests": coalesced_requests,
        "coalesced_io_s": coalesced_io_s,
        "requests_ratio": ratio,
        "flights_created": stats["flights_created"],
        "coalesced_hits": stats["coalesced_hits"],
        "trees_identical": identical,
    }


# -- phase 2: partitioned cache under long-tail churn -------------------------

def _bench_partition():
    base_bytes = N_LEAVES * int(np.prod(LEAF_SHAPE)) * 4
    # default partition deliberately smaller than the variant working set;
    # hot partition comfortably holds the base model
    obj, lm, store, base = _seeded_store(
        width=8, cache_bytes=2 * base_bytes, variants=N_VARIANTS)
    vec = store.catalog().version_vector
    with Gateway(store, max_inflight=8,
                 partitions={"hot": {"bytes": 4 * base_bytes,
                                     "pinned": True}},
                 clock=lambda: lm.elapsed_s) as gw:
        gw.register("hot", TenantPolicy(weight=4.0, max_inflight=4,
                                        cache_partition="hot"))
        for i in range(4):
            gw.register(f"tail{i}", TenantPolicy(max_inflight=2))

        # hot tenant cold-starts the base into its pinned partition
        gw.load_model("hot", "base", base, version=vec).result(60)

        # long-tail churn: variants cycle through the undersized default
        for rnd in range(3):
            futs = [gw.load_model(f"tail{i % 4}", f"base~ft{v}", base,
                                  version=vec)
                    for i, v in enumerate(range(N_VARIANTS))]
            for f in futs:
                f.result(60)

        parts = store.io.cache.partitions()
        # warm re-read of every hot-base leaf at the pinned vector: the
        # priority class must have protected it through the churn
        lm.reset()
        futs = [gw.read("hot", f"base/layer{i}", version=vec)
                for i in range(N_LEAVES)]
        for f in futs:
            f.result(60)
        warm_requests = lm.requests
        warm_io_s = lm.elapsed_s

    return {
        "base_bytes": base_bytes,
        "default_evictions": parts["default"]["evictions"],
        "hot_evictions": parts["hot"]["evictions"],
        "hot_cached_bytes": parts["hot"]["nbytes"],
        "warm_base_requests": warm_requests,
        "warm_base_io_s": warm_io_s,
    }


# -- phase 3: weighted fairness, SLOs, shedding -------------------------------

def _bench_fairness():
    obj, lm, store, base = _seeded_store(width=8)
    vec = store.catalog().version_vector
    tids = [f"base/layer{i}" for i in range(N_LEAVES)]
    with Gateway(store, max_inflight=4,
                 clock=lambda: lm.elapsed_s) as gw:
        for i in range(FAIR_TENANTS):
            gw.register(f"f{i}", TenantPolicy(weight=1.0, max_inflight=2,
                                              queue_limit=FAIR_JOBS,
                                              p99_target_s=5.0))
        lm.reset()
        # adversarial burst order: tenant 0's entire batch lands first
        futs = []
        for i in range(FAIR_TENANTS):
            for j in range(FAIR_JOBS):
                futs.append(gw.submit(
                    f"f{i}",
                    lambda t=tids[j % N_LEAVES]: store.read_many(
                        [(t, None)], version=vec)[0]))
        # snapshot fairness mid-run (~half done): FIFO would be ~1/k here
        half = FAIR_TENANTS * FAIR_JOBS // 2
        while sum(s["completed"]
                  for s in gw.tenant_stats().values()) < half:
            time.sleep(0.002)
        jain_half = gw.fairness()
        for f in futs:
            f.result(60)
        jain_final = gw.fairness()
        slo = gw.slo_report()
        p99s = [s["p99_s"] for s in slo.values() if s["p99_s"] is not None]

        # shedding: flood a tenant whose queue holds 4 and serves 1 at a
        # time; beyond-capacity submissions must reject, never deadlock
        gw.register("flood", TenantPolicy(max_inflight=1, queue_limit=4))
        accepted, shed = [], 0
        for _ in range(50):
            try:
                accepted.append(gw.submit(
                    "flood",
                    lambda: store.read_many([(tids[0], None)],
                                            version=vec)[0]))
            except RetryAfter:
                shed += 1
        for f in accepted:
            f.result(60)

    return {
        "tenants": FAIR_TENANTS,
        "jobs_per_tenant": FAIR_JOBS,
        "jain_mid_run": jain_half,
        "jain_final": jain_final,
        "p99_max_s": max(p99s) if p99s else None,
        "p99_targets_met": sum(1 for s in slo.values() if s["met"]),
        "shed_submitted": 50,
        "shed_accepted": len(accepted),
        "shed_rejected": shed,
    }


def run(json_path=None):
    lines = []
    results = {"bench": "serve_traffic", "leaves": N_LEAVES,
               "leaf_shape": list(LEAF_SHAPE), "variants": N_VARIANTS}

    co = _bench_coalesce()
    results["coalesce"] = co
    lines.append(row(
        "serve_coldstart_coalesce", co["coalesced_io_s"] * 1e6,
        f"requests {co['uncoalesced_requests']}->"
        f"{co['coalesced_requests']} ratio={co['requests_ratio']:.1f}x "
        f"flights={co['flights_created']} hits={co['coalesced_hits']} "
        f"identical={co['trees_identical']}"))

    pa = _bench_partition()
    results["partition"] = pa
    lines.append(row(
        "serve_partitioned_cache", pa["warm_base_io_s"] * 1e6,
        f"warm_base_requests={pa['warm_base_requests']} "
        f"default_evictions={pa['default_evictions']} "
        f"hot_evictions={pa['hot_evictions']}"))

    fa = _bench_fairness()
    results["fairness"] = fa
    lines.append(row(
        "serve_fair_queueing", 0.0,
        f"jain_mid={fa['jain_mid_run']:.3f} "
        f"jain_final={fa['jain_final']:.3f} "
        f"p99_max_s={fa['p99_max_s']} shed={fa['shed_rejected']}/50"))

    results["gate"] = {
        "coalesce_requests_ratio": co["requests_ratio"],
        "coalesced_dedups": co["coalesced_hits"],
        "trees_identical": co["trees_identical"],
        "warm_base_requests": pa["warm_base_requests"],
        "default_evictions": pa["default_evictions"],
        "jain_mid_run": fa["jain_mid_run"],
        "p99_max_s": fa["p99_max_s"],
        "shed_rejected": fa["shed_rejected"],
    }
    g = results["gate"]
    lines.append(row(
        "serve_traffic_gate", 0.0,
        f"ratio={g['coalesce_requests_ratio']:.1f}x "
        f"warm_requests={g['warm_base_requests']} "
        f"jain={g['jain_mid_run']:.3f} shed={g['shed_rejected']}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_serve_traffic.json"):
        print(line)
