"""Chunk-blob compression: space reduction vs read-makespan cost.

The paper's headline claim is space efficiency; this bench measures what
the :mod:`repro.lake.compression` subsystem buys on the modeled object
store (1 Gbps, 10 ms RTT, virtual clock) and what it costs at read time.

Two dense-float workloads, stored FTSF across ~8 part files each:

* **compressible** — float32 with quantized mantissas (the profile of
  weights trained with reduced effective precision, or any telemetry
  rounded for storage). This is the gated workload: ``zlib+shuffle``
  must keep a >=2x physical-byte reduction vs the raw tensor bytes, and
  the full-read makespan (modeled I/O + real decode CPU) must stay
  within 25% of the uncompressed store's.
* **random** — i.i.d. normal float32, the adversarial case. Plain zlib
  cannot shrink it 10% (so the legacy layout stores it raw); the
  byte-shuffle filter still finds the low-entropy exponent/sign planes.
  Reported for context, not gated.

Honesty note: the pre-compression layout already ran opportunistic
per-block zlib inside parq-lite, so ``reduction_vs_legacy`` (what this
subsystem adds on top of that) is reported alongside ``reduction``
(physical vs raw tensor bytes, the gated number). Bytes-over-wire are
charged by the store at the *stored* size, so the modeled read I/O shows
the bandwidth win with zero hand-waving.

With ``--json`` (or :func:`run`'s ``json_path``) results land in
``BENCH_compression.json`` so ``check_regression.py`` can gate PRs.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DeltaTensorStore
from repro.lake import ReadExecutor, available_codecs

from .common import fresh_store, row

SHAPE = (64, 128, 256)          # 8 MiB float32, 64 FTSF chunks
TARGET_FILE_BYTES = 1 << 20     # ~8 part files -> width-8 parallel fetch
WIDTH = 8
GATED_SPEC = "zlib+shuffle"


def _make(kind: str) -> np.ndarray:
    rng = np.random.default_rng(0)
    x = rng.standard_normal(SHAPE)
    if kind == "compressible":
        x = np.round(x * 64) / 64  # quantized mantissas
    return x.astype(np.float32)


def _data_bytes(obj, root: str) -> int:
    return sum(obj.head(k) for k in obj.list(f"{root}/")
               if "_delta_log" not in k and "_store_manifest" not in k)


def _specs():
    specs = [None, "zlib", GATED_SPEC, "lzma+shuffle"]
    for extra in ("zstd", "lz4"):
        if extra in available_codecs():
            specs.append(f"{extra}+shuffle")
    return specs


def one_codec(x: np.ndarray, spec):
    """Write + cold-read ``x`` under ``spec``; return space + time costs."""
    obj, lm = fresh_store(parallelism=WIDTH)
    io = ReadExecutor(max_workers=WIDTH, cache_bytes=0)
    try:
        store = DeltaTensorStore(obj, "tensors", io=io, compression=spec)
        t0 = time.perf_counter()
        store.put(x, layout="ftsf", tensor_id="t",
                  target_file_bytes=TARGET_FILE_BYTES)
        write_cpu = time.perf_counter() - t0

        physical = _data_bytes(obj, "tensors")
        stats = store.storage_stats()

        store.get("t")  # warmup: first-call numpy/zlib overhead must not
        best = None     # land on whichever codec happens to run first
        for _ in range(3):  # best-of-3: CPU timing on shared boxes is noisy
            lm.reset()
            t0 = time.perf_counter()
            got = store.get("t")
            cpu = time.perf_counter() - t0
            # pure-wire makespan: decode seconds are already in the wall
            # cpu term, and the staged read path charges them into
            # elapsed_s too (the pipelined makespan) — cpu + elapsed_s
            # would count decode twice
            total = cpu + lm.io_elapsed_s
            if best is None or total < best["total_s"]:
                best = {"cpu_s": cpu, "io_s": lm.io_elapsed_s, "total_s": total,
                        "requests": lm.requests, "bytes_moved": lm.bytes_moved}
        assert np.array_equal(got, x)

        return {
            "spec": spec or "none",
            "physical_bytes": physical,
            "logical_bytes": int(x.nbytes),
            "reduction": x.nbytes / physical,
            "stats_ratio": stats["ratio"],
            "write_cpu_s": write_cpu,
            "read": best,
        }
    finally:
        io.shutdown()


def run(json_path=None):
    """Run both workloads across available codecs; emit rows + JSON."""
    results = {"bench": "compression",
               "workloads": {"shape": list(SHAPE), "dtype": "float32",
                             "logical_bytes": int(np.prod(SHAPE)) * 4},
               "codecs": {}}
    lines = []

    for kind in ("compressible", "random"):
        x = _make(kind)
        per = {}
        for spec in _specs():
            r = one_codec(x, spec)
            per[r["spec"]] = r
            lines.append(row(
                f"compression_{kind}_{r['spec']}",
                r["read"]["total_s"] * 1e6,
                f"reduction={r['reduction']:.2f}x "
                f"wire={r['read']['bytes_moved']}B "
                f"io_s={r['read']['io_s']:.4f} cpu_s={r['read']['cpu_s']:.4f}"))
        legacy = per["none"]
        for r in per.values():
            r["reduction_vs_legacy"] = \
                legacy["physical_bytes"] / r["physical_bytes"]
            r["read_makespan_ratio"] = \
                r["read"]["total_s"] / legacy["read"]["total_s"]
        results["codecs"][kind] = per

    gated = results["codecs"]["compressible"][GATED_SPEC]
    results["gate"] = {
        "spec": GATED_SPEC,
        "reduction": gated["reduction"],
        "reduction_vs_legacy": gated["reduction_vs_legacy"],
        "read_makespan_ratio": gated["read_makespan_ratio"],
    }
    lines.append(row("compression_gate", 0.0,
                     f"{GATED_SPEC}: reduction={gated['reduction']:.2f}x "
                     f"(vs_legacy={gated['reduction_vs_legacy']:.2f}x) "
                     f"read_overhead={gated['read_makespan_ratio']:.2f}"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_compression.json"):
        print(line)
