"""Read-path scaling: serial vs parallel vs cached tensor reads.

The paper's testbed (1 Gbps, 10 ms RTT object store) is modeled by
``LatencyModel``; this bench sweeps the read executor width and reports the
modeled I/O makespan for multi-chunk ``get`` / ``get_slice``, plus the
warm-block-cache repeat read. Expected shape of the result:

* width 1 == the old serial read path (sum of per-file RTTs);
* width >= 8 cuts modeled read time >= 2x on multi-chunk tensors (RTTs
  overlap; payload bytes still share the one modeled link);
* a warm cache turns repeat ``get`` of the same tensor into zero
  object-store requests.
"""

from __future__ import annotations

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.synthetic import ffhq_like
from repro.lake import ReadExecutor

from .common import fresh_store, row, timed

SHAPE = (128, 3, 32, 32)
TARGET_FILE_BYTES = 16 << 10     # force a few dozen chunk files


def _loaded_store(width: int, cache_bytes: int = 0):
    obj, lm = fresh_store(parallelism=width)
    io = ReadExecutor(max_workers=width, cache_bytes=cache_bytes)
    store = DeltaTensorStore(obj, "tensors", io=io)
    x = ffhq_like(SHAPE)
    store.put(x, layout="ftsf", tensor_id="x", chunk_dims=3,
              target_file_bytes=TARGET_FILE_BYTES)
    return store, lm, x


def run(widths=(1, 8, 16), repeats=None):
    repeats = repeats or 1
    lines = []
    # half the leading dim: a multi-file slice (the paper's X[0:100] analog
    # spans one file; parallel fetch pays off once a slice covers several)
    sl_hi = max(1, SHAPE[0] // 2)
    elapsed_by_width = {}

    for width in widths:
        store, lm, _ = _loaded_store(width, cache_bytes=0)
        n_files = len([a for a in store.table.files()
                       if a["partitionValues"].get("kind") == "chunk"])
        r = timed(lm, lambda: store.get("x"), repeats)
        s = timed(lm, lambda: store.get_slice("x", [(0, sl_hi)]), repeats)
        elapsed_by_width[width] = (r.io_s, s.io_s)
        lines.append(row(f"read_path_get_w{width}", r.io_s * 1e6,
                         f"n_chunk_files={n_files} bytes={r.bytes_moved}"))
        lines.append(row(f"read_path_slice_w{width}", s.io_s * 1e6,
                         f"bytes={s.bytes_moved}"))

    # warm block cache: repeat get of the same tensor -> zero requests
    # (version-pinned, as a serving reader would: snapshot + blocks cached)
    store, lm, x = _loaded_store(8, cache_bytes=256 << 20)
    v = store.version()
    store.get("x", version=v)            # cold read fills the cache
    lm.reset()
    np.testing.assert_array_equal(store.get("x", version=v), x)
    lines.append(row("read_path_get_cached", lm.elapsed_s * 1e6,
                     f"requests={lm.requests} bytes={lm.bytes_moved} "
                     f"hits={store.io.stats.cache_hits}"))
    lm.reset()
    np.testing.assert_array_equal(store.get("x"), x)   # unpinned warm read
    lines.append(row("read_path_get_cached_unpinned", lm.elapsed_s * 1e6,
                     f"requests={lm.requests} bytes={lm.bytes_moved}"))

    if 1 in elapsed_by_width:
        base_get, base_sl = elapsed_by_width[1]
        for w, (g, s) in sorted(elapsed_by_width.items()):
            if w == 1:
                continue
            lines.append(row(f"read_path_speedup_w{w}", 0.0,
                             f"get={base_get / g:.2f}x slice={base_sl / s:.2f}x"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
