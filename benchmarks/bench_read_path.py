"""Read-path scaling: serial vs parallel vs cached tensor reads.

The paper's testbed (1 Gbps, 10 ms RTT object store) is modeled by
``LatencyModel``; this bench sweeps the read executor width and reports the
modeled I/O makespan for multi-chunk ``TensorRef.read()`` / slice reads,
plus the warm-block-cache repeat read and the catalog's per-read metadata
cost. Expected shape of the result:

* width 1 == the old serial read path (sum of per-file RTTs);
* width >= 8 cuts modeled read time >= 2x on multi-chunk tensors (RTTs
  overlap; payload bytes still share the one modeled link);
* a warm cache turns repeat reads of a pinned tensor into zero
  object-store requests;
* repeated reads build the catalog ONCE (O(1) lookups after), where the
  seed path re-walked the full file list per read.

With ``--json`` (or via :func:`run`'s ``json_path``) the results are also
written machine-readable to ``BENCH_read_path.json`` so the perf trajectory
is tracked across PRs.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core import DeltaTensorStore
from repro.data.synthetic import ffhq_like
from repro.lake import ReadExecutor

from .common import fresh_store, row, timed

SHAPE = (128, 3, 32, 32)
TARGET_FILE_BYTES = 16 << 10     # force a few dozen chunk files
CATALOG_REPEAT_READS = 20

# device read pipeline: big enough that per-file lzma decode is real work,
# chunked into ~49 compressed part files so fetch/decode can interleave
DEVICE_SHAPE = (1024, 3, 32, 32)
DEVICE_FILE_BYTES = 256 << 10
DEVICE_COMPRESSION = "lzma+shuffle"
DEVICE_WIDTH = 8


def _loaded_store(width: int, cache_bytes: int = 0):
    obj, lm = fresh_store(parallelism=width)
    io = ReadExecutor(max_workers=width, cache_bytes=cache_bytes)
    store = DeltaTensorStore(obj, "tensors", io=io)
    x = ffhq_like(SHAPE)
    store.put(x, layout="ftsf", tensor_id="x", chunk_dims=3,
              target_file_bytes=TARGET_FILE_BYTES)
    return store, lm, x


def run(widths=(1, 8, 16), repeats=None, json_path=None):
    repeats = repeats or 1
    lines = []
    results = {"bench": "read_path", "shape": list(SHAPE),
               "target_file_bytes": TARGET_FILE_BYTES, "widths": {},
               "speedup": {}, "cached": {}, "catalog": {}}
    # half the leading dim: a multi-file slice (the paper's X[0:100] analog
    # spans one file; parallel fetch pays off once a slice covers several)
    sl_hi = max(1, SHAPE[0] // 2)
    elapsed_by_width = {}

    for width in widths:
        store, lm, _ = _loaded_store(width, cache_bytes=0)
        ref = store.open("x")
        n_files = ref.n_chunk_files
        r = timed(lm, ref.read, repeats)
        s = timed(lm, lambda: ref.read_slice([(0, sl_hi)]), repeats)
        elapsed_by_width[width] = (r.io_s, s.io_s)
        lines.append(row(f"read_path_get_w{width}", r.io_s * 1e6,
                         f"n_chunk_files={n_files} bytes={r.bytes_moved}"))
        lines.append(row(f"read_path_slice_w{width}", s.io_s * 1e6,
                         f"bytes={s.bytes_moved}"))
        results["widths"][str(width)] = {
            "n_chunk_files": n_files,
            "get_io_s": r.io_s, "get_bytes": r.bytes_moved,
            "slice_io_s": s.io_s, "slice_bytes": s.bytes_moved,
        }

    # warm block cache: repeat read of the same pinned ref -> zero requests
    # (as a serving reader would: snapshot + catalog + blocks all cached)
    store, lm, x = _loaded_store(8, cache_bytes=256 << 20)
    v = store.version()
    ref = store.open("x", version=v)
    np.testing.assert_array_equal(ref.read(), x)       # cold read fills caches
    lm.reset()
    np.testing.assert_array_equal(ref.read(), x)
    lines.append(row("read_path_get_cached", lm.elapsed_s * 1e6,
                     f"requests={lm.requests} bytes={lm.bytes_moved} "
                     f"hits={store.io.stats.cache_hits}"))
    results["cached"]["pinned"] = {
        "io_s": lm.elapsed_s, "requests": lm.requests,
        "bytes": lm.bytes_moved, "block_cache_hits": store.io.stats.cache_hits}
    lm.reset()
    np.testing.assert_array_equal(store.open("x").read(), x)  # unpinned warm
    lines.append(row("read_path_get_cached_unpinned", lm.elapsed_s * 1e6,
                     f"requests={lm.requests} bytes={lm.bytes_moved}"))
    results["cached"]["unpinned"] = {
        "io_s": lm.elapsed_s, "requests": lm.requests, "bytes": lm.bytes_moved}

    # catalog metadata cost: N repeated pinned reads = ONE snapshot walk.
    # The seed-path equivalent walked table.files() on every get (O(files)
    # metadata work per read); the catalog makes repeats O(1) lookups.
    store, lm, x = _loaded_store(8, cache_bytes=256 << 20)
    v = store.version()
    store.catalog_stats.update(builds=0, hits=0)
    for _ in range(CATALOG_REPEAT_READS):
        store.open("x", version=v).read()
    builds, hits = store.catalog_stats["builds"], store.catalog_stats["hits"]
    lines.append(row("read_path_catalog_metadata", 0.0,
                     f"reads={CATALOG_REPEAT_READS} snapshot_walks={builds} "
                     f"o1_lookups={hits}"))
    results["catalog"] = {"repeat_reads": CATALOG_REPEAT_READS,
                          "snapshot_walks": builds, "o1_lookups": hits}

    # device read pipeline: cold compressed read lands on the accelerator,
    # decode of chunk k overlapping the fetch of chunk k+1. Pipelined
    # makespan = LatencyModel.elapsed_s (wire + per-thread charged decode);
    # the un-pipelined baseline is pure wire time + the same decode seconds
    # run back-to-back, measured in the SAME read (no second run needed).
    obj, lm = fresh_store(parallelism=DEVICE_WIDTH)
    io = ReadExecutor(max_workers=DEVICE_WIDTH, cache_bytes=0)
    store = DeltaTensorStore(obj, "tensors", io=io,
                             compression=DEVICE_COMPRESSION)
    x = ffhq_like(DEVICE_SHAPE, dtype=np.float32)
    store.put(x, layout="ftsf", tensor_id="d", chunk_dims=3,
              target_file_bytes=DEVICE_FILE_BYTES)
    ref = store.open("d")
    n_files = ref.n_chunk_files
    io.stats.reset()
    lm.reset()
    out, info = ref.read_device(with_info=True)
    np.testing.assert_array_equal(np.asarray(out), x)
    s = io.stats
    pipelined_s = lm.elapsed_s
    fetch_then_decode_s = lm.io_elapsed_s + s.decode_s
    ratio = pipelined_s / fetch_then_decode_s if fetch_then_decode_s else 1.0
    # fraction of decode seconds hidden under the wire (virtual clock:
    # deterministic; the wall-sampled ReadStats fraction rides along)
    overlap = ((fetch_then_decode_s - pipelined_s) / s.decode_s
               if s.decode_s else 0.0)
    lines.append(row("read_path_device_pipelined", pipelined_s * 1e6,
                     f"serial={fetch_then_decode_s * 1e6:.1f}us "
                     f"ratio={ratio:.2f} overlap={overlap:.2f} "
                     f"n_files={n_files}"))
    results["device"] = {
        "shape": list(DEVICE_SHAPE), "compression": DEVICE_COMPRESSION,
        "width": DEVICE_WIDTH, "n_chunk_files": n_files,
        "pipelined_io_s": pipelined_s,
        "fetch_then_decode_s": fetch_then_decode_s,
        "pipelined_vs_serial": ratio,
        "decode_s": s.decode_s,
        "decode_overlap_frac": overlap,
        "decode_overlap_frac_sampled": s.decode_overlap_frac,
        "decodes_offloaded": s.decodes_offloaded,
        "path": info.path, "on_device": info.on_device,
        "bytes_to_device": s.bytes_to_device,
    }

    # device slice: only the wanted chunks are staged on the host — no
    # intermediate full-tensor host copy (the PR's zero-copy acceptance)
    spec = [(0, DEVICE_SHAPE[0] // 8), None, None, None]
    io.stats.reset()
    lm.reset()
    sout, sinfo = ref.read_device(spec, with_info=True)
    np.testing.assert_array_equal(
        np.asarray(sout), x[: DEVICE_SHAPE[0] // 8])
    zero_copy = bool(sinfo.on_device
                     and sinfo.host_staged_bytes == sinfo.device_bytes
                     and sinfo.host_staged_bytes < x.nbytes)
    lines.append(row("read_path_device_slice", lm.elapsed_s * 1e6,
                     f"staged={sinfo.host_staged_bytes} "
                     f"full={x.nbytes} zero_copy={zero_copy}"))
    results["device"]["slice"] = {
        "io_s": lm.elapsed_s,
        "host_staged_bytes": sinfo.host_staged_bytes,
        "device_bytes": sinfo.device_bytes,
        "full_tensor_bytes": int(x.nbytes),
        "zero_full_tensor_host_copies": zero_copy,
    }

    # device COO: values scatter on-device, so host staging is O(nnz)
    # instead of the densified tensor
    rng = np.random.default_rng(3)
    sp = np.zeros((256, 4096), dtype=np.float32)
    nnz = int(sp.size * 0.01)
    sp.reshape(-1)[rng.choice(sp.size, nnz, replace=False)] = (
        rng.standard_normal(nnz).astype(np.float32))
    store.put(sp, layout="coo", tensor_id="sp")
    cref = store.open("sp")
    io.stats.reset()
    lm.reset()
    cout, cinfo = cref.read_device(with_info=True)
    np.testing.assert_array_equal(np.asarray(cout), sp)
    lines.append(row("read_path_device_coo", lm.elapsed_s * 1e6,
                     f"staged={cinfo.host_staged_bytes} dense={sp.nbytes} "
                     f"path={cinfo.path}"))
    results["device"]["coo"] = {
        "io_s": lm.elapsed_s, "nnz": nnz,
        "host_staged_bytes": cinfo.host_staged_bytes,
        "dense_bytes": int(sp.nbytes),
        "staged_lt_dense": bool(cinfo.host_staged_bytes < sp.nbytes),
        "path": cinfo.path, "on_device": cinfo.on_device,
    }
    if 1 in elapsed_by_width:
        base_get, base_sl = elapsed_by_width[1]
        for w, (g, s) in sorted(elapsed_by_width.items()):
            if w == 1:
                continue
            lines.append(row(f"read_path_speedup_w{w}", 0.0,
                             f"get={base_get / g:.2f}x slice={base_sl / s:.2f}x"))
            results["speedup"][str(w)] = {"get": base_get / g,
                                          "slice": base_sl / s}

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_read_path.json"):
        print(line)
