"""Maintenance lifecycle: space reclaimed after churn, spilled-index catalog.

Two claims from the lifecycle subsystem, measured on the paper's modeled
object store (1 Gbps, 10 ms RTT, virtual clock):

* **churn reclamation** — an overwrite-heavy workload (every tensor
  overwritten R times) leaves R dead generations per tensor. While refs
  pin the original snapshot, ``store.vacuum`` reclaims nothing (lease
  safety); once the leases are released it must reclaim >= 50% of the
  store's data bytes (the acceptance floor; the expected value for R=4
  churn is ~80%).

* **catalog build: walked vs spilled** — a table grown to 1e4 files over
  many commits. A cold client's ``Catalog.build`` either replays the
  snapshot (checkpoint get + trailing commit gets + an O(files)
  classification pass) or loads the spilled ``_catalog/<v>.index.json``
  in one get with zero snapshot walks (``catalog_stats`` proves it).
  Modeled I/O time is deterministic, so ``speedup_io`` is the regression
  gate; CPU time is reported for context.

With ``--json`` (or :func:`run`'s ``json_path``) results land in
``BENCH_maintenance.json`` so ``check_regression.py`` can gate PRs.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DeltaTensorStore
from repro.lake import DeltaTable, ReadExecutor

from .common import fresh_store, row

CHURN_TENSORS = 8
CHURN_ROUNDS = 4
CHURN_SHAPE = (64, 64)

CATALOG_FILES = 10_000
CATALOG_COMMITS = 100          # files land over many commits, as in real life
CATALOG_TENSORS = 200          # distinct tensor ids in the index


def _data_bytes(obj, root: str) -> int:
    return sum(obj.head(k) for k in obj.list(f"{root}/")
               if "_delta_log" not in k and "/_catalog/" not in k)


def churn_workload():
    obj, lm = fresh_store(parallelism=8)
    io = ReadExecutor(max_workers=8, cache_bytes=0)
    try:
        store = DeltaTensorStore(obj, "tensors", io=io)
        rng = np.random.default_rng(0)
        originals = {}
        for i in range(CHURN_TENSORS):
            originals[f"t{i}"] = rng.standard_normal(CHURN_SHAPE).astype(np.float32)
            store.put(originals[f"t{i}"], layout="ftsf", tensor_id=f"t{i}")
        refs = [store.open(f"t{i}") for i in range(CHURN_TENSORS)]

        for _ in range(CHURN_ROUNDS):
            with store.batch() as b:
                for i in range(CHURN_TENSORS):
                    b.put(rng.standard_normal(CHURN_SHAPE).astype(np.float32),
                          layout="ftsf", tensor_id=f"t{i}", overwrite=True)

        before = _data_bytes(obj, "tensors")
        # vacuum under leases: intermediate churn generations (pinned by
        # nobody) are reclaimable, the leased original generation is not
        r1 = store.vacuum(keep_versions=1)
        leased_bytes = _data_bytes(obj, "tensors")
        for i, ref in enumerate(refs):  # pinned reads still byte-identical
            assert np.array_equal(ref.read(), originals[f"t{i}"])
            ref.close()
        # leases released: the next vacuum frees the original generation too
        r2 = store.vacuum(keep_versions=1)
        reclaimed = sum(r.bytes_reclaimed for r in r1 + r2)
        after_release = sum(r.bytes_reclaimed for r in r2)
        assert after_release > 0      # release actually freed bytes
        return {
            "tensors": CHURN_TENSORS, "rounds": CHURN_ROUNDS,
            "data_bytes_before": before,
            "data_bytes_while_leased": leased_bytes,
            "bytes_reclaimed": reclaimed,
            "bytes_reclaimed_after_release": after_release,
            "files_deleted": sum(r.files_deleted for r in r1 + r2),
            "reclaimed_frac": reclaimed / before if before else 0.0,
        }
    finally:
        io.shutdown()


def _grown_table(obj):
    """A table with CATALOG_FILES adds spread over CATALOG_COMMITS commits."""
    t = DeltaTable.create(obj, "tensors",
                          io=ReadExecutor(max_workers=8, cache_bytes=0))
    per_commit = CATALOG_FILES // CATALOG_COMMITS
    n = 0
    for _c in range(CATALOG_COMMITS):
        adds = []
        for _f in range(per_commit):
            tid = f"t{n % CATALOG_TENSORS:04d}"
            kind = "header" if n % 50 == 0 else "chunks"
            adds.append(t.append({"chunk_index": np.arange(1)}, commit=False,
                                 partition_values={"tensor": tid,
                                                   "kind": kind,
                                                   "layout": "ftsf"}))
            n += 1
        t.commit_adds(adds)
    return t


def catalog_workload():
    obj, lm = fresh_store(parallelism=8)
    _grown_table(obj)

    def build(spill_threshold):
        client = DeltaTensorStore(
            obj, "tensors", spill_threshold=spill_threshold,
            io=ReadExecutor(max_workers=8, cache_bytes=0))
        lm.reset()
        t0 = time.perf_counter()
        cat = client.catalog()
        cpu = time.perf_counter() - t0
        assert len(cat) == CATALOG_TENSORS
        return {"cpu_s": cpu, "io_s": lm.elapsed_s, "requests": lm.requests,
                "total_s": cpu + lm.elapsed_s,
                "snapshot_walks": client.catalog_stats["snapshot_walks"],
                "index_loads": client.catalog_stats["index_loads"]}

    walk = build(spill_threshold=None)       # index never consulted
    # spill the index (what a threshold-crossing commit does), then rebuild
    DeltaTensorStore(obj, "tensors",
                     io=ReadExecutor(max_workers=4,
                                     cache_bytes=0)).spill_catalog()
    spilled = build(spill_threshold=512)
    assert spilled["snapshot_walks"] == 0    # the acceptance invariant
    return {
        "files": CATALOG_FILES, "commits": CATALOG_COMMITS,
        "walk": walk, "spilled": spilled,
        "speedup_io": walk["io_s"] / spilled["io_s"] if spilled["io_s"] else 0.0,
        "speedup_total": (walk["total_s"] / spilled["total_s"]
                          if spilled["total_s"] else 0.0),
    }


def run(json_path=None):
    results = {"bench": "maintenance"}
    lines = []

    churn = churn_workload()
    results["churn"] = churn
    lines.append(row("maintenance_churn_reclaim", 0.0,
                     f"reclaimed={churn['reclaimed_frac']:.2f} "
                     f"of {churn['data_bytes_before']}B "
                     f"post_release={churn['bytes_reclaimed_after_release']}B"))

    cat = catalog_workload()
    results["catalog"] = cat
    lines.append(row("maintenance_catalog_walked",
                     cat["walk"]["total_s"] * 1e6,
                     f"files={cat['files']} io_s={cat['walk']['io_s']:.4f} "
                     f"walks={cat['walk']['snapshot_walks']}"))
    lines.append(row("maintenance_catalog_spilled",
                     cat["spilled"]["total_s"] * 1e6,
                     f"files={cat['files']} io_s={cat['spilled']['io_s']:.4f} "
                     f"walks={cat['spilled']['snapshot_walks']} "
                     f"speedup_io={cat['speedup_io']:.2f}x"))

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return lines


if __name__ == "__main__":
    for line in run(json_path="BENCH_maintenance.json"):
        print(line)
