"""Paper Fig. 12: dense tensor — binary blob vs FTSF.

Scenario 1 (§V.A): FFHQ-like (N, 3, H, W) uint8 tensor. Baseline = one
serialized blob in the object store (numpy.save analog: raw C-order bytes).
FTSF = 3-D chunks (one per image) in the delta table. Metrics: storage
size, write, read-tensor, read-slice X[0:100] — compression ratio Cr and
the slice-read speedup are the paper's headline numbers.
"""

from __future__ import annotations

import numpy as np

from repro.configs.paper_store import PAPER_STORE
from repro.core import DeltaTensorStore
from repro.data.synthetic import ffhq_like

from .common import fresh_store, row, timed


def run(shape=None, repeats=None):
    cfgd = PAPER_STORE["dense"]
    shape = shape or cfgd["bench_shape"]
    repeats = repeats or PAPER_STORE["repeats"]
    x = ffhq_like(shape)
    # paper slice is X[0:100] of 5000 images = 2% of the first dim
    sl_lo = 0
    sl_hi = max(1, int(shape[0] * 100 / 5000))

    out = []

    # --- binary baseline -----------------------------------------------------
    obj, lm = fresh_store()
    blob = x.tobytes()
    w = timed(lm, lambda: obj.put("blobs/x", x.tobytes()), repeats)
    size_binary = obj.head("blobs/x")

    def read_all_binary():
        raw = obj.get("blobs/x")
        np.frombuffer(raw, dtype=x.dtype).reshape(shape)

    r = timed(lm, read_all_binary, repeats)

    def read_slice_binary():  # must fetch the whole blob to slice it
        raw = obj.get("blobs/x")
        np.frombuffer(raw, dtype=x.dtype).reshape(shape)[sl_lo:sl_hi]

    s = timed(lm, read_slice_binary, repeats)
    out.append(("binary", size_binary, w, r, s))

    # --- FTSF ------------------------------------------------------------------
    obj, lm = fresh_store()
    store = DeltaTensorStore(obj, "tensors")
    w2 = timed(lm, lambda: store.put(x, layout="ftsf", tensor_id="x",
                                     chunk_dims=cfgd["chunk_dims"],
                                     target_file_bytes=512 << 10,
                                     overwrite=True), repeats)
    size_ftsf = store.tensor_bytes("x")
    r2 = timed(lm, lambda: store.get("x"), repeats)
    s2 = timed(lm, lambda: store.get_slice("x", [(sl_lo, sl_hi)]), repeats)
    out.append(("ftsf", size_ftsf, w2, r2, s2))

    cr = size_ftsf / size_binary
    lines = []
    for name, size, w_, r_, s_ in out:
        lines.append(row(f"dense_{name}_write", w_.total_s * 1e6,
                         f"size_bytes={size}"))
        lines.append(row(f"dense_{name}_read_tensor", r_.total_s * 1e6,
                         f"io_s={r_.io_s:.3f}"))
        lines.append(row(f"dense_{name}_read_slice", s_.total_s * 1e6,
                         f"bytes_moved={s_.bytes_moved}"))
    slice_delta = out[1][4].total_s / out[0][4].total_s - 1
    lines.append(row("dense_ftsf_summary", 0.0,
                     f"Cr={cr:.4f} (paper 0.9109); "
                     f"slice_delta={slice_delta:+.2%} (paper -90.04%)"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
